"""Render EXPERIMENTS.md sections from artifacts:

* §Dry-run / §Roofline tables from experiments/dryrun_results.json
* §Claims summary from bench_output.txt (if present)

Usage: PYTHONPATH=src python scripts/render_experiments.py > /tmp/sections.md
"""
import json
import os
import sys

RESULTS = "experiments/dryrun_results.json"


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n / 2**30:.2f}"


def main() -> None:
    with open(RESULTS) as f:
        recs = json.load(f)
    recs.sort(key=lambda r: (r.get("variant", "baseline") != "baseline",
                             r["arch"], r["shape"], r["multi_pod"]))

    print("### Dry-run + roofline table\n")
    print("| arch | shape | mesh | variant | status | compile s | "
          "args GiB/dev | temp GiB/dev | compute ms | memory ms | "
          "collective ms | dominant | useful-FLOPs |")
    print("|" + "---|" * 13)
    for r in recs:
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        var = r.get("variant", "baseline")
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {mesh} | {var} | "
                  f"{r['status']} | - | - | - | - | - | - | - | - |")
            continue
        roof = r["roofline"]
        mem = r["memory"]
        print(f"| {r['arch']} | {r['shape']} | {mesh} | {var} | ok | "
              f"{r['compile_s']} | {fmt_bytes(mem['argument_bytes'])} | "
              f"{fmt_bytes(mem['temp_bytes'])} | "
              f"{roof['compute_s'] * 1e3:.3f} | "
              f"{roof['memory_s'] * 1e3:.3f} | "
              f"{roof['collective_s'] * 1e3:.3f} | {roof['dominant']} | "
              f"{(r.get('useful_flops_ratio') or 0):.3f} |")

    # dominant-term stats
    doms = {}
    for r in recs:
        if r["status"] == "ok" and r.get("variant", "baseline") == "baseline":
            doms.setdefault(r["roofline"]["dominant"], []).append(
                (r["arch"], r["shape"], "mp" if r["multi_pod"] else "sp"))
    print("\n### Dominant-term distribution (baseline)\n")
    for k, v in sorted(doms.items()):
        print(f"* **{k}**: {len(v)} pairs")


if __name__ == "__main__":
    main()
