"""Train a zoo architecture (reduced config) with the framework's training
substrate — the '~100M-model for a few hundred steps' driver, sized to
this CPU host. Pick any of the 10 assigned architectures.

    PYTHONPATH=src python examples/train_weak_fm.py --arch olmo-1b \
        --steps 200 --batch 8 --seq 64
"""
import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=".cache/example_weak_fm.npz")
    args = ap.parse_args()
    metrics = train(args.arch, smoke=True, steps=args.steps,
                    batch=args.batch, seq=args.seq, lr=1e-3, ckpt=args.ckpt)
    print(f"final metrics: {metrics}")
    assert metrics["loss"] < 4.0, "loss should have dropped well below init"


if __name__ == "__main__":
    main()
