"""Quickstart: the RAR public API in ~60 lines.

Builds (or loads) the trained layered system — weak FM, strong FM,
embedder, static router — wires up the RAR controller, and serves a few
requests, printing the routing decision and cost for each.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.rar import RAR, RARConfig
from repro.experiments.setup import build_system, failing_pool

# 1. A trained layered FM system (cached under .cache/rar_system).
system = build_system()
suite = system.suite

# 2. The RAR controller: weak + strong tiers, embedder, static router.
holder = {}
rar = RAR(
    weak=system.weak,
    strong=system.strong,
    embed_fn=lambda prompt: system.embed_one(prompt),
    route_weak_fn=lambda emb, key: system.router.route_weak(emb),
    cfg=RARConfig(sim_threshold=0.2, guide_sim_threshold=0.2,
                  reprobe_period=1000),
)

# 3. Serve requests the weak FM can't handle alone. Repeats of a skill
#    should migrate from the strong FM to guided weak-FM serving.
pool = failing_pool(system, domain=0, n=20)
print(f"{'case':<14} {'served_by':<9} {'strong_calls':<12} guide_source")
for repeat in range(2):
    print(f"--- pass {repeat + 1} over the same 20 requests ---")
    for d, s, x in pool:
        prompt = np.asarray(suite.vocab.question(d, s, x), np.int32)
        greq = np.asarray(suite.vocab.guide_request(d, s), np.int32)
        out = rar.process(prompt, greq)
        print(f"{out.case:<14} {out.served_by:<9} {out.strong_calls:<12} "
              f"{out.guide_source or '-'}")

print(f"\nweak-FM calls: {system.weak.calls}, strong-FM calls: "
      f"{system.strong.calls}")
print(f"guide memory entries: {rar.memory.size_fast}")
print("Pass 2 should show memory_guide / memory_skill cases with zero "
      "strong calls — that's RAR's continual cost reduction.")
