"""Continual-learning demo: watch the guide memory change routing for ONE
skill family in real time, including the Case-3 re-probe path when the
weak FM 'evolves' (is swapped for a better checkpoint mid-stream) — the
paper's motivating scenario of weaker FMs improving over time.

    PYTHONPATH=src python examples/continual_learning_demo.py
"""
import numpy as np

from repro.core.rar import RAR, RARConfig
from repro.experiments.setup import build_system

system = build_system()
suite = system.suite

rar = RAR(
    weak=system.weak,
    strong=system.strong,
    embed_fn=lambda p: system.embed_one(p),
    route_weak_fn=lambda e, k: False,          # force the shadow path
    cfg=RARConfig(reprobe_period=6),
)

# one skill the weak FM does NOT know unaided
unknown = np.setdiff1d(np.arange(suite.cfg.total_skills), suite.weak_known)
skill = int(unknown[0])
domain = suite.domain_of(skill)
print(f"skill {skill} (domain {domain}): rule answer = "
      f"({suite.alpha[skill]}·x + {suite.beta[skill]}) mod 4\n")

for i, x in enumerate([3, 17, 42, 58, 71, 5, 88, 23]):
    prompt = np.asarray(suite.vocab.question(domain, skill, x), np.int32)
    greq = np.asarray(suite.vocab.guide_request(domain, skill), np.int32)
    out = rar.process(prompt, greq)
    truth = suite.answer(skill, x)
    print(f"x={x:3d} → case={out.case:<13} served_by={out.served_by:<7} "
          f"strong_calls={out.strong_calls} response="
          f"{'ABCD'[out.response] if out.response >= 0 else '?'} "
          f"truth={'ABCD'[truth]}")

print("\nAfter the first request generated a guide (case2), every further "
      "request of this skill is served by the weak FM from guide memory "
      "(memory_guide, zero strong calls) — including operands it has "
      "never seen.")
