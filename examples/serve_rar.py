"""End-to-end serving driver: batched requests through the full RAR stack
with cost accounting — the paper's deployment scenario (weak edge tier +
strong cloud tier).

    PYTHONPATH=src python examples/serve_rar.py --requests 150 --stages 3
"""
import argparse
import time

import numpy as np

from repro.core.rar import RARConfig
from repro.experiments.setup import build_system, failing_pool
from repro.experiments.stages import run_baselines, run_rar_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--domain", type=int, default=0)
    args = ap.parse_args()

    system = build_system()
    pool = failing_pool(system, args.domain, n=args.requests)

    cfg = RARConfig(reprobe_period=2 * len(pool))
    t0 = time.time()
    results, rar = run_rar_experiment(
        system, pool, n_stages=args.stages, rar_cfg=cfg, verbose=True)
    dt = time.time() - t0

    n = args.stages * len(pool)
    aligned = sum(r.aligned for r in results)
    strong = sum(r.strong_calls for r in results)
    base = run_baselines(system, pool, n_stages=args.stages, rar_cfg=cfg)
    oracle_strong = sum(r.strong_calls for r in base["oracle_router"])

    # FLOPs-based cost model (6·N_active per token, per tier config)
    weak_cost = system.weak.flops_spent
    strong_cost = system.strong.flops_spent
    print(f"\nserved {n} requests in {dt:.1f}s "
          f"({1e3 * dt / n:.1f} ms/request on this host)")
    print(f"quality (aligned with strong FM): {100 * aligned / n:.1f}%")
    print(f"strong-FM calls: {strong} vs oracle static router "
          f"{oracle_strong} → {100 * (1 - strong / oracle_strong):.1f}% "
          f"reduction (paper: 50.2%)")
    print(f"FLOPs split: weak {weak_cost:.2e}, strong {strong_cost:.2e} "
          f"(strong tier is {system.strong.cfg.flops_per_token() / system.weak.cfg.flops_per_token():.1f}x cost/token)")


if __name__ == "__main__":
    main()
