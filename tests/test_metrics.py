"""Metrics plane + adaptive drain cadence: registry semantics (typed
instruments, bounded-reservoir histograms, atomic snapshots), snapshot
consistency under the async drainer and N-replica threaded stress
(counters monotone, drained <= enqueued, no torn snapshots), the
adaptive-mode equivalence anchor (a forced always-drain cost model makes
``shadow_mode="adaptive"`` byte-identical to deferred/flush-every-1),
and the fabric's ``metrics()`` contract — per-replica queue depth,
shadow staleness, drain cost and commit lag, all host-side.
"""
import threading
import time

import numpy as np
import pytest
from test_fabric import build_fabric, serve_fabric
from test_pipeline import SCENARIOS, build, make_stream
from test_rar_controller import greq, prompt, skill_emb
from test_shadow import assert_equivalent, serve_stream

from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import AdaptiveDrainPolicy, DrainPolicy
from repro.serving.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a/n").inc()
    reg.counter("a/n").inc(4)
    reg.gauge("a/depth").set(7)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("a/cost").observe(v)
    snap = reg.snapshot()
    assert snap["a/n"] == 5
    assert snap["a/depth"] == 7
    h = snap["a/cost"]
    assert h["count"] == 4 and h["total"] == 10.0 and h["mean"] == 2.5
    assert h["p50"] in (2.0, 3.0) and h["p99"] == 4.0
    # same name, different kind: a registration bug, not a new instrument
    with pytest.raises(TypeError):
        reg.gauge("a/n")
    with pytest.raises(TypeError):
        reg.histogram("a/depth")


def test_histogram_reservoir_bounded_but_counts_exact():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    n = 10_000
    for v in range(n):
        h.observe(float(v))
    assert h.count == n                      # exact, not sampled
    assert h.total == float(sum(range(n)))   # exact, not sampled
    assert len(h._samples) <= 2048           # reservoir stays bounded
    s = h.summary()
    assert s["count"] == n
    # decimated reservoir still tracks the distribution's bulk
    assert 0.2 * n < s["p50"] < 0.8 * n


# ---------------------------------------------------------------------------
# Adaptive-mode equivalence anchor
# ---------------------------------------------------------------------------


def _force_policy(ctrl, policy):
    """Swap the queue's drain policy post-build (the queue consults
    ``drain_policy.due()`` per submit, so a swapped-in policy governs
    every subsequent cadence decision)."""
    policy.register(ctrl.shadow)
    ctrl.shadow.drain_policy = policy
    return policy


@pytest.mark.parametrize("kw", SCENARIOS[:3])
@pytest.mark.parametrize("batch", [1, 4])
def test_adaptive_always_drain_policy_identical_to_deferred(kw, batch):
    """The acceptance anchor for adaptive mode: with the cost model
    replaced by the always-drain base policy (and the cadence cap
    disabled), adaptive runs the identical drain schedule as
    deferred/flush-every-1 — outcomes, memory, FM calls, RQ2 counters
    byte-identical."""
    stream = make_stream()
    ref, _ = build(MicrobatchRAR, shadow_mode="deferred",
                   shadow_flush_every=1, **kw)
    ada, _ = build(MicrobatchRAR, shadow_mode="adaptive",
                   shadow_flush_every=0, **kw)
    pol = _force_policy(ada, DrainPolicy())
    a_outs = serve_stream(ref, stream, batch)
    b_outs = serve_stream(ada, stream, batch)
    assert_equivalent(ref, a_outs, ada, b_outs)
    assert pol.decisions > 0          # the policy really was consulted


def test_adaptive_cold_start_drains_like_deferred():
    """Before the regression has two observations the private adaptive
    policy must always drain (cold start) — so a short stream is
    byte-identical to deferred/1 even with the real cost model."""
    kw = dict(weak_known=set())
    stream = make_stream()[:4]
    ref, _ = build(MicrobatchRAR, shadow_mode="deferred",
                   shadow_flush_every=1, **kw)
    ada, _ = build(MicrobatchRAR, shadow_mode="adaptive",
                   shadow_flush_every=0, **kw)
    a_outs = serve_stream(ref, stream, 2)
    b_outs = serve_stream(ada, stream, 2)
    assert_equivalent(ref, a_outs, ada, b_outs)
    st = ada.shadow.drain_policy.stats()
    assert st["coldstart_drains"] >= 1


def test_adaptive_flush_every_is_a_hard_staleness_cap():
    """In adaptive mode ``flush_every`` is demoted to a cap: even a
    never-drain cost model cannot hold items past N batches."""

    class NeverDrain(DrainPolicy):
        def due(self):
            self.decisions += 1
            return False

    ada, _ = build(MicrobatchRAR, shadow_mode="adaptive",
                   shadow_flush_every=2, weak_known=set())
    _force_policy(ada, NeverDrain())
    stream = make_stream()[:6]
    serve_stream(ada, stream, 1)      # final flush_shadow drains the rest
    # with the cap at 2 batches, drains happened mid-stream, not only at
    # the stage-end barrier
    assert ada.shadow.drains >= 3
    assert ada.shadow.items_enqueued == ada.shadow.items_drained


def test_adaptive_policy_learns_cost_model():
    """After enough drains the decayed regression yields a usable
    (overhead, per-item) model and the policy starts making real
    cost-based decisions."""
    pol = AdaptiveDrainPolicy(decay=1.0)
    for n, secs in ((1, 1.0), (2, 1.5), (4, 2.5), (8, 4.5)):
        pol.note_drain(n, secs)
    a, b = pol.model()
    assert a == pytest.approx(0.5, abs=1e-6)   # fixed overhead
    assert b == pytest.approx(0.5, abs=1e-6)   # per-item cost
    st = pol.stats()
    assert st["overhead_secs"] == pytest.approx(0.5, abs=1e-6)
    assert st["per_item_secs"] == pytest.approx(0.5, abs=1e-6)


# ---------------------------------------------------------------------------
# Snapshot consistency under concurrency
# ---------------------------------------------------------------------------

_COUNTERS = ("items_enqueued", "items_drained", "drains",
             "drain_failures", "items_requeued", "epochs_applied",
             "entries_applied")


def _check_snapshot(snap, prev):
    """One registry snapshot: counters (and histogram counts) monotone
    vs ``prev``, drained <= enqueued within the same snapshot (a torn
    snapshot would break this — drains bump both under one lock hold)."""
    for name, val in snap.items():
        v = val["count"] if isinstance(val, dict) else val
        if isinstance(val, dict) or name.endswith(_COUNTERS):
            assert v >= prev.get(name, 0), f"{name} went backwards"
            prev[name] = v
    by_prefix = {}
    for name, val in snap.items():
        for suffix in ("items_enqueued", "items_drained"):
            if name.endswith(suffix):
                by_prefix.setdefault(name[: -len(suffix)], {})[suffix] = val
    for prefix, d in by_prefix.items():
        assert d["items_drained"] <= d["items_enqueued"], prefix


def test_metrics_consistent_under_async_drainer():
    """The background drainer updates drain counters while the serve
    thread enqueues: every snapshot taken mid-flight must still be
    internally consistent and monotone."""
    ctrl, _ = build(MicrobatchRAR, weak_known=set(), shadow_mode="async",
                    shadow_flush_every=2)
    stop, failures, prev = threading.Event(), [], {}

    def sampler():
        while not stop.is_set():
            try:
                _check_snapshot(ctrl.metrics_registry.snapshot(), prev)
            except AssertionError as e:
                failures.append(e)
                return
            time.sleep(0.0005)

    t = threading.Thread(target=sampler)
    t.start()
    try:
        serve_stream(ctrl, make_stream() * 2, 4)
    finally:
        stop.set()
        t.join()
        ctrl.close_shadow()
    assert not failures, failures[0]
    snap = ctrl.metrics_registry.snapshot()
    assert snap["shadow/items_enqueued"] == snap["shadow/items_drained"]
    assert snap["shadow/depth_items"] == 0


def test_fabric_metrics_consistent_under_threaded_stress():
    """3 replica workers serving submitted microbatches concurrently, a
    sampler thread hammering ``fabric.metrics()`` the whole time: no
    torn snapshots, counters monotone, per-replica invariants hold."""
    fab = build_fabric(3, weak_known=set(), shadow_mode="async",
                       shadow_flush_every=2)
    stop, failures, prev = threading.Event(), [], {}

    def sampler():
        while not stop.is_set():
            try:
                m = fab.metrics()
                for rep in m["replicas"]:
                    assert 0 <= rep["items_drained"] <= rep["items_enqueued"]
                    assert rep["commit_epoch_lag"] >= 0
                    assert rep["shadow_pending"] >= 0
                _check_snapshot(m["registry"], prev)
                assert m["commit"]["epoch"] >= prev.get("__epoch", 0)
                prev["__epoch"] = m["commit"]["epoch"]
            except AssertionError as e:
                failures.append(e)
                return
            time.sleep(0.0005)

    t = threading.Thread(target=sampler)
    t.start()
    try:
        outs = serve_fabric(fab, make_stream() * 3, 4, submit=True)
    finally:
        stop.set()
        t.join()
    assert not failures, failures[0]
    assert len(outs) == len(make_stream()) * 3
    m = fab.metrics()
    learn = m["replicas"][0]
    assert learn["items_enqueued"] == learn["items_drained"]
    assert learn["shadow_pending"] == 0
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Fabric metrics contract
# ---------------------------------------------------------------------------


def test_fabric_metrics_shape():
    """``fabric.metrics()`` carries the observability surface the serve
    CLI and the bench read: per-replica queue depth / staleness / drain
    counters / commit lag, commit progress, engine + breaker counters,
    supervision events, and the raw registry (drain-cost histograms)."""
    fab = build_fabric(2, weak_known={0, 1})
    outs = serve_fabric(fab, make_stream(), 4, submit=True)
    assert all(o.case != "shadow_pending" for o in outs)
    m = fab.metrics()
    assert len(m["replicas"]) == 2
    for rep in m["replicas"]:
        for k in ("replica", "health", "queue_depth", "shadow_pending",
                  "shadow_staleness_batches", "shadow_staleness_logical",
                  "items_enqueued", "items_drained", "items_requeued",
                  "drain_failures", "drains", "commit_epoch_lag"):
            assert k in rep, k
        assert rep["queue_depth"] == 0        # all tickets resolved
        assert rep["shadow_pending"] == 0     # post-flush
        assert rep["commit_epoch_lag"] == 0   # atomic in-process broadcast
    assert m["commit"]["epoch"] >= 1
    assert m["commit"]["entries_applied"] >= 1
    # FakeTier has no ServingEngine stats — the slots still exist (real
    # engines fill them with calls/jit_hits/jit_misses, see test_serving)
    assert set(m["engines"]) == {"weak", "strong"}
    assert m["supervision"]["deaths"] == 0
    assert m["supervision"]["active_replicas"] == 2
    # the learn replica's drain histograms live in the registry under
    # its per-replica prefix
    assert m["registry"]["replica0/shadow/drain_items"]["count"] >= 1
    assert m["registry"]["replica0/shadow/drain_staleness_batches"][
        "count"] >= 1
    fab.close_shadow()


def test_fabric_adaptive_shares_one_policy_across_replicas():
    """``shadow_mode="adaptive"`` on the fabric installs ONE policy that
    every replica queue registers with — the global view the cadence
    decision needs — and serving still resolves everything at the
    barrier."""
    fab = build_fabric(2, weak_known=set(), shadow_mode="adaptive",
                       shadow_flush_every=0)
    assert isinstance(fab.drain_policy, AdaptiveDrainPolicy)
    for r in fab.replicas:
        assert r.shadow.drain_policy is fab.drain_policy
    assert fab.metrics()["drain_policy"] is not None
    outs = serve_fabric(fab, make_stream(), 4, submit=True)
    assert all(o.case != "shadow_pending" for o in outs)
    m = fab.metrics()
    assert m["drain_policy"]["decisions"] > 0
    learn = m["replicas"][0]
    assert learn["items_enqueued"] == learn["items_drained"]
    fab.close_shadow()


# ---------------------------------------------------------------------------
# OpenMetrics text exposition
# ---------------------------------------------------------------------------


def test_to_openmetrics_exposition_format():
    """Counters render as ``_total``, gauges bare, histograms as
    summaries with p50/p99 quantile series + ``_sum``/``_count``;
    registry paths are sanitized to the OpenMetrics charset and the
    exposition ends with ``# EOF``."""
    reg = MetricsRegistry()
    reg.counter("sched/admitted").inc(5)
    reg.gauge("replica0/shadow/depth_items").set(3)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("sched/queue_delay_ms").observe(v)
    text = reg.to_openmetrics()
    lines = text.splitlines()
    assert "# TYPE sched_admitted counter" in lines
    assert "sched_admitted_total 5" in lines
    assert "# TYPE replica0_shadow_depth_items gauge" in lines
    assert "replica0_shadow_depth_items 3" in lines
    assert "# TYPE sched_queue_delay_ms summary" in lines
    assert 'sched_queue_delay_ms{quantile="0.99"} 4' in lines
    assert "sched_queue_delay_ms_sum 10" in lines
    assert "sched_queue_delay_ms_count 4" in lines
    assert lines[-1] == "# EOF"
    assert text.endswith("# EOF\n")
    # every non-comment line is a valid sample of a declared family
    declared = {ln.split()[2] for ln in lines if ln.startswith("# TYPE")}
    for ln in lines:
        if ln.startswith("#"):
            continue
        name = ln.split()[0].split("{")[0]
        base = name
        for suffix in ("_total", "_sum", "_count"):
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                break
        assert base in declared, ln


def test_to_openmetrics_empty_registry_is_just_eof():
    assert MetricsRegistry().to_openmetrics() == "# EOF\n"


def test_fabric_exports_openmetrics():
    fab = build_fabric(2, weak_known={0, 1})
    serve_fabric(fab, make_stream(), 4, submit=True)
    text = fab.metrics_registry.to_openmetrics()
    assert "replica0_shadow_items_enqueued_total" in text
    assert text.endswith("# EOF\n")
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Default autoscaling policy + supervisor tick
# ---------------------------------------------------------------------------


def _synthetic_metrics(active, depths, p99=None, count=100):
    m = {
        "replicas": [{"replica": i, "health": "healthy",
                      "queue_depth": d} for i, d in enumerate(depths)],
        "supervision": {"active_replicas": active},
        "registry": {},
    }
    if p99 is not None:
        m["registry"]["sched/queue_delay_ms"] = {
            "count": count, "total": p99 * count, "mean": p99,
            "p50": p99 / 2, "p99": p99}
    return m


def test_queue_latency_autoscaler_policy_decisions():
    from repro.serving.fabric import QueueLatencyAutoscaler
    pol = QueueLatencyAutoscaler(min_replicas=1, max_replicas=4,
                                 slo_ms=50.0)
    # deep queues: one step up
    assert pol(_synthetic_metrics(2, [5, 6])) == 3
    # p99 breach scales up even with shallow queues
    assert pol(_synthetic_metrics(2, [0, 1], p99=80.0)) == 3
    # idle + comfortable latency: one step down
    assert pol(_synthetic_metrics(3, [0, 0, 0], p99=5.0)) == 2
    # in-band: hold
    assert pol(_synthetic_metrics(2, [1, 1], p99=30.0)) == 2
    # clamps
    assert pol(_synthetic_metrics(1, [0])) == 1
    assert pol(_synthetic_metrics(4, [9, 9, 9, 9])) == 4
    # an SLO breach needs samples: an empty histogram never scales up
    assert pol(_synthetic_metrics(2, [0, 0],
                                  p99=999.0, count=0)) in (1, 2)
    s = pol.stats()
    assert s["decisions"] == 7
    assert s["scale_ups"] >= 2 and s["scale_downs"] >= 1
    with pytest.raises(ValueError):
        QueueLatencyAutoscaler(min_replicas=3, max_replicas=2)


def test_autoscaler_latency_signal_without_slo_ignored():
    from repro.serving.fabric import QueueLatencyAutoscaler
    pol = QueueLatencyAutoscaler(slo_ms=None)
    # no SLO: latency can't trigger a scale-up, depth still can
    assert pol(_synthetic_metrics(2, [0, 0], p99=1e9)) == 1
    assert pol(_synthetic_metrics(2, [9, 9], p99=0.0)) == 3


def test_supervisor_tick_drives_health_gated_autoscale():
    """``start_autoscaler`` turns the policy object into a control
    loop: the tick calls ``fabric.autoscale()`` until the target is
    reached, and ``close_shadow`` stops the thread."""
    fab = build_fabric(1, weak_known={0, 1})
    serve_fabric(fab, make_stream(), 4, submit=True)
    fab.start_autoscaler(interval_s=0.02, policy=lambda m: 3)
    deadline = time.monotonic() + 10
    while fab.active_replicas < 3 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert fab.active_replicas == 3
    assert fab.autoscale_ticks >= 1
    assert fab.metrics()["autoscaler"]["ticks"] >= 1
    fab.close_shadow()
    assert fab._autoscale_thread is None
    # the scaled-up fabric still serves correctly after the tick
    ticks_at_close = fab.autoscale_ticks
    time.sleep(0.1)
    assert fab.autoscale_ticks == ticks_at_close      # really stopped


def test_default_policy_installed_by_start_autoscaler():
    from repro.serving.fabric import QueueLatencyAutoscaler
    fab = build_fabric(2, weak_known={0, 1})
    fab.start_autoscaler(interval_s=30.0)
    assert isinstance(fab.autoscale_policy, QueueLatencyAutoscaler)
    assert fab.metrics()["autoscaler"]["policy"]["policy"] == \
        "QueueLatencyAutoscaler"
    # idle fabric with the default watermarks: scale-down toward min
    assert fab.autoscale() <= 0
    fab.close_shadow()
