"""Dry-run spec construction (pure eval_shape — no devices, no compiles)."""
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import specs as SP
from repro.launch.analytic import analytic_terms

ARCHS = configs.all_archs()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SP.INPUT_SHAPES))
def test_input_specs_structure(arch, shape):
    cfg = configs.get(arch)
    if not SP.supported(cfg, shape):
        assert shape == "long_500k" and arch == "whisper-medium"
        return
    spec = SP.input_specs(cfg, shape)
    seq, batch, kind = SP.INPUT_SHAPES[shape]
    assert spec["kind"] == kind
    if kind == "train":
        assert spec["batch"]["tokens"].shape == (batch, seq)
        assert spec["grad_accum"] >= 1
        assert batch % spec["grad_accum"] == 0
        # optimizer state mirrors params leaf-for-leaf
        import jax
        n_p = len(jax.tree.leaves(spec["params"]))
        n_m = len(jax.tree.leaves(spec["opt_state"]["master"]))
        assert n_p == n_m
    elif kind == "prefill":
        assert spec["batch"]["tokens"].shape == (batch, seq)
        assert spec["max_len"] >= seq
    else:
        assert spec["tokens"].shape == (batch,)
        assert spec["pos"].shape == ()
        # cache sized to the context (ring-aware for long_500k variants)
        if "k" in spec["cache"]:
            M = spec["cache"]["k"].shape[2]
            assert M in (seq, spec["cfg"].decode_window)


def test_long_500k_forces_subquadratic():
    for arch in ARCHS:
        cfg = configs.get(arch)
        if not SP.supported(cfg, "long_500k"):
            continue
        c = SP.config_for_shape(cfg, "long_500k")
        if c.family in ("dense", "moe", "vlm", "hybrid"):
            assert c.decode_window > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_analytic_terms_positive(arch):
    cfg = configs.get(arch)
    for shape, (seq, batch, kind) in SP.INPUT_SHAPES.items():
        if not SP.supported(cfg, shape):
            continue
        c = SP.config_for_shape(cfg, shape)
        t = analytic_terms(c, kind, batch, seq, 256)
        assert t["flops_per_device"] > 0
        assert t["hbm_bytes_per_device"] > 0


def test_ring_cache_shrinks_analytic_memory():
    import dataclasses
    cfg = configs.get("llama3-8b")
    base = dataclasses.replace(cfg, decode_window=4096)
    ring = dataclasses.replace(cfg, decode_window=4096, ring_cache=True)
    tb = analytic_terms(base, "decode", 1, 524_288, 256)
    tr = analytic_terms(ring, "decode", 1, 524_288, 256)
    # at batch=1 the TP-sharded weights are ~half the analytic bytes; the
    # cache term itself collapses to the window (~0)
    assert tr["hbm_bytes_per_device"] < 0.7 * tb["hbm_bytes_per_device"]
