"""Hypothesis shim: re-export the real library when installed, otherwise a
minimal deterministic fallback so the property tests still run (each
``@given`` test executes ``max_examples`` seeded samples).

Only the strategy surface this suite uses is implemented: ``integers``,
``booleans``, ``lists``, ``sampled_from``.
"""
from __future__ import annotations

try:                                     # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import functools

    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

    def settings(*, max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*args, *(s.sample(rng) for s in strats), **kwargs)
            # pytest resolves fixtures through __wrapped__'s signature;
            # drop it so the strategy-filled params aren't fixture-matched.
            del wrapper.__wrapped__
            return wrapper
        return deco
