"""Process-per-replica fabric: byte-identity to the threaded fabric,
cross-process commit broadcast, SIGKILL/hard-exit supervision with
redispatch, lease-expiry detection of hung workers, stale-completion
dedup, ticket timeout re-registration across the process boundary, and
full-state crash recovery (whole-fabric kill + manifest recover()).

Worker factories live at module level so the ``spawn`` start method can
re-import them inside the child processes.
"""
import functools
import os
import time

import numpy as np
import pytest
from test_fabric import build_fabric, serve_fabric
from test_pipeline import MEM_FIELDS, make_stream
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb

from repro.serving.faults import FaultPlan, FaultSpec, random_plan
from repro.serving.procfabric import ProcessServingFabric, WorkerDied


# ---------------------------------------------------------------------------
# Picklable worker factory (spawn re-imports this module in the child)
# ---------------------------------------------------------------------------


class _CountEngine:
    """Minimal engine-counter object speaking the export/restore protocol
    — lets worker-side FakeTier calls ship across the process boundary as
    deltas and survive manifest recovery."""

    def __init__(self):
        self.calls = 0
        self.tokens_processed = 0

    def export_counters(self):
        return {"calls": self.calls,
                "tokens_processed": self.tokens_processed}

    def restore_counters(self, c):
        self.calls = c["calls"]
        self.tokens_processed = c["tokens_processed"]


def _no_embed(p):
    return None


def _route_false(emb, key):
    return False


def _make_parts(weak_known=()):
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    weak.engine = _CountEngine()
    strong.engine = _CountEngine()
    return {"weak": weak, "strong": strong, "embed_fn": _no_embed,
            "route_weak_fn": _route_false}


def build_proc(workers=1, weak_known=(), fault_plan=None,
               lease_interval=0.25, lease_timeout=10.0, **cfg_kw):
    factory = functools.partial(_make_parts, tuple(sorted(weak_known)))
    return ProcessServingFabric(factory, make_cfg(**cfg_kw),
                                workers=workers, fault_plan=fault_plan,
                                lease_interval=lease_interval,
                                lease_timeout=lease_timeout)


def serve_proc(fab, stream, batch):
    """Serve ``stream`` serialized (wait out each ticket before the next
    submit) — the byte-identity path: admission order == serve order ==
    drain order, on any worker count."""
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        t = fab.submit([prompt(s, x) for s, x in chunk],
                       [greq(s) for s, _ in chunk], keys=chunk,
                       embs=np.stack([skill_emb(s) for s, _ in chunk]))
        outs += t.wait(timeout=180)
    fab.flush_shadow(timeout=180)
    return outs


def one(fab, skill, x, replica=None):
    """Submit a single-request microbatch and wait it out."""
    t = fab.submit([prompt(skill, x)], [greq(skill)], keys=[(skill, x)],
                   embs=np.stack([skill_emb(skill)]), replica=replica)
    return t.wait(timeout=180)[0]


def _calls(fab, name):
    """A fabric's total FM calls for one tier: through ``engine_calls``
    on the process fabric (serve calls live in shipped worker deltas),
    directly off the shared tier on the threaded one."""
    if hasattr(fab, "engine_calls"):
        return fab.engine_calls(name)
    tier = {"weak": fab.learn.weak, "strong": fab.learn.strong}[name]
    return tier.engine.calls


def assert_proc_equivalent(ref, ref_outs, fab, outs):
    """``test_shadow.assert_equivalent``, adapted to the process fabric's
    split call accounting."""
    assert ref_outs == outs
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ref.memory, f)),
                                      np.asarray(getattr(fab.memory, f)),
                                      f)
    assert ref.now == fab.now
    assert _calls(ref, "weak") == _calls(fab, "weak")
    assert _calls(ref, "strong") == _calls(fab, "strong")
    assert ref.guides_from_memory == fab.guides_from_memory
    assert ref.guides_generated == fab.guides_generated


# ---------------------------------------------------------------------------
# Equivalence: process fabric ≡ threaded fabric, byte for byte
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [dict(weak_known={0, 1}),
                                dict(weak_known=set())])
def test_one_worker_proc_fabric_identical_to_thread_fabric(kw):
    """The acceptance anchor: dispatch through a real worker *process*
    (pickle transport, epoch broadcasts, done-message funnels) must
    produce the same bytes as the in-process fabric — Outcome stream,
    memory state, FM-call totals, RQ2 counters."""
    stream = make_stream()
    ref = build_fabric(1, **kw)
    ref_outs = serve_fabric(ref, stream, 4)
    fab = build_proc(1, **kw)
    outs = serve_proc(fab, stream, 4)
    assert_proc_equivalent(ref, ref_outs, fab, outs)
    assert fab.stats()["transport"]["frames_sent"] > 0
    ref.close_shadow()
    fab.close_shadow()


def test_two_worker_proc_fabric_serialized_identical():
    """Round-robin across two worker processes, serialized: FIFO channel
    ordering guarantees each worker applies every prior drain epoch
    before its next serve, so the bytes cannot differ from one worker."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_fabric(1, **kw)
    ref_outs = serve_fabric(ref, stream, 4)
    fab = build_proc(2, **kw)
    outs = serve_proc(fab, stream, 4)
    assert_proc_equivalent(ref, ref_outs, fab, outs)
    ref.close_shadow()
    fab.close_shadow()


def test_pipelined_submission_identical_to_serialized():
    """Submit every microbatch up front (deep queue, zero waits): the
    worker's drain-ack gate enforces serve-after-drain, so routing is
    byte-identical to the paced one-ticket-at-a-time run. Without the
    gate a worker would serve a repeat skill against a mirror that has
    not yet applied the first occurrence's commit — routing, and the
    strong-call bill, silently diverge under deep pipelining."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_fabric(1, **kw)
    ref_outs = serve_fabric(ref, stream, 4)
    fab = build_proc(1, **kw)
    tickets = []
    for start in range(0, len(stream), 4):
        chunk = stream[start:start + 4]
        tickets.append(fab.submit(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk], keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk])))
    outs = []
    for t in tickets:
        outs += t.wait(timeout=180)
    fab.flush_shadow(timeout=180)
    assert_proc_equivalent(ref, ref_outs, fab, outs)
    ref.close_shadow()
    fab.close_shadow()


def test_epoch_broadcast_reaches_idle_worker():
    """A worker that never served still learns: pin every serve to
    worker 0, then a repeat skill pinned to worker 1 must route off the
    broadcast store view with zero strong calls."""
    fab = build_proc(2, weak_known={0})
    o1 = one(fab, 0, 1, replica=0)
    assert o1.case == "case1"
    o2 = one(fab, 0, 2, replica=1)
    assert o2.case == "memory_skill" and o2.strong_calls == 0
    assert o2.response == (0 + 2) % 4
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Supervision: SIGKILL / hard-exit / hung-worker detection + redispatch
# ---------------------------------------------------------------------------


def test_sigkill_mid_run_redispatch_byte_identical():
    """SIGKILL one worker process as it picks up a microbatch: EOF
    detection, respawn against the current store, and redispatch with
    the same pre-allocated stamps keep the run byte-identical to a
    no-fault one."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_fabric(1, **kw)
    ref_outs = serve_fabric(ref, stream, 4)
    plan = FaultPlan([FaultPlan.replica_kill(1, at=2)])
    fab = build_proc(2, fault_plan=plan, **kw)
    outs = serve_proc(fab, stream, 4)
    assert_proc_equivalent(ref, ref_outs, fab, outs)
    assert fab.deaths == 1 and fab.restarts == 1
    assert fab.redispatches == 1
    assert fab.stats()["health"] == ["healthy", "healthy"]
    ref.close_shadow()
    fab.close_shadow()


def test_worker_hard_exit_redispatch_byte_identical():
    """The "crash" action makes the worker process hard-exit (no
    cleanup, no farewell message) — same EOF + redispatch path as
    SIGKILL, same bytes."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_fabric(1, **kw)
    ref_outs = serve_fabric(ref, stream, 4)
    plan = FaultPlan([FaultPlan.replica_crash(0, at=1)])
    fab = build_proc(1, fault_plan=plan, **kw)
    outs = serve_proc(fab, stream, 4)
    assert_proc_equivalent(ref, ref_outs, fab, outs)
    assert fab.deaths == 1 and fab.restarts == 1
    assert fab.redispatches == 1
    ref.close_shadow()
    fab.close_shadow()


def test_redispatch_budget_exhausted_surfaces_worker_died():
    """With ``max_redispatch=0`` a worker death surfaces as
    :class:`WorkerDied` at the ticket — and the respawned worker keeps
    the fabric serviceable."""
    plan = FaultPlan([FaultPlan.replica_kill(0, at=1)])
    fab = build_proc(1, fault_plan=plan, weak_known={0, 1},
                     max_redispatch=0)
    t = fab.submit([prompt(0, 1)], [greq(0)], keys=[(0, 1)],
                   embs=np.stack([skill_emb(0)]))
    with pytest.raises(RuntimeError) as ei:
        t.wait(timeout=180)
    assert isinstance(ei.value.__cause__, WorkerDied)
    with pytest.raises(RuntimeError):
        fab.join(timeout=180)          # the barrier surfaces it too
    assert fab.deaths == 1 and fab.restarts == 1
    assert fab.redispatches == 0
    o = one(fab, 0, 2)                 # respawned worker serves
    assert o.case == "case1"
    fab.close_shadow()


def test_lease_expiry_detects_hung_worker():
    """A worker whose heartbeat thread dies (but which keeps serving) is
    exactly the failure EOF cannot see: the lease monitor must declare
    it dead and respawn the slot."""
    plan = FaultPlan([FaultPlan.heartbeat_crash(0, at=1)])
    fab = build_proc(1, fault_plan=plan, weak_known={0, 1},
                     lease_interval=0.1, lease_timeout=0.8)
    o = one(fab, 0, 1)
    assert o.case == "case1"
    deadline = time.monotonic() + 30
    while fab.lease_expiries == 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert fab.lease_expiries >= 1
    assert fab.deaths == 1 and fab.restarts == 1
    o2 = one(fab, 0, 2)                # respawned worker serves
    assert o2.case == "memory_skill"
    fab.close_shadow()


def test_injected_clock_skew_expires_lease_without_waiting():
    """Seeded clock skew advances the monitor's view of time: a healthy,
    beating worker's lease expires purely from the skew — the
    deterministic form of the wall-clock hang test."""
    plan = FaultPlan([FaultPlan.clock_skew(3600.0, at=1)])
    fab = build_proc(1, fault_plan=plan, weak_known={0, 1},
                     lease_interval=0.2, lease_timeout=60.0)
    deadline = time.monotonic() + 30
    while fab.lease_expiries == 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert fab.lease_expiries == 1 and fab.deaths == 1
    o = one(fab, 0, 1)                 # the respawned slot serves fine
    assert o.case == "case1"
    fab.close_shadow()


def test_stale_done_is_dropped_not_double_applied():
    """A completion for a dispatch id the supervisor already
    redispatched must be dropped: a ticket is never resolved twice and a
    batch's authoritative effects land at most once."""
    fab = build_proc(1, weak_known={0, 1})
    before = fab.learn.shadow.items_enqueued
    fab._on_done(fab._handles[0], 999_999, [], [], [], {})
    assert fab.stale_drops == 1
    assert fab.learn.shadow.items_enqueued == before
    o = one(fab, 0, 1)                 # fabric unaffected
    assert o.case == "case1"
    fab.close_shadow()


def test_app_error_in_worker_surfaces_without_redispatch():
    """An application exception inside a worker's serve ships back
    verbatim and is NOT redispatched (its side effects may have landed)
    — parity with the threaded fabric."""
    plan = FaultPlan([FaultSpec("replica_serve", "error",
                                (("replica", 0),), at=1)])
    fab = build_proc(1, fault_plan=plan, weak_known={0, 1})
    t = fab.submit([prompt(0, 1)], [greq(0)], keys=[(0, 1)],
                   embs=np.stack([skill_emb(0)]))
    with pytest.raises(RuntimeError):
        t.wait(timeout=180)
    with pytest.raises(RuntimeError):
        fab.join(timeout=180)
    assert fab.deaths == 0 and fab.redispatches == 0
    o = one(fab, 0, 2)                 # same worker process, still alive
    assert o.case == "case1"
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Ticket timeout re-registration across the process boundary
# ---------------------------------------------------------------------------


def test_ticket_timeout_stays_waitable_across_process_boundary():
    """A timed-out ``wait``/``join`` leaves the ticket fully waitable
    while the batch is in flight in the worker process — and its late
    completion resolves the ticket exactly once (no redispatch, no
    stale drop)."""
    plan = FaultPlan([FaultSpec("replica_serve", "delay",
                                (("replica", 0),), at=1, delay=2.0)])
    fab = build_proc(1, fault_plan=plan, weak_known={0, 1},
                     lease_interval=0.1, lease_timeout=30.0)
    t = fab.submit([prompt(0, 1)], [greq(0)], keys=[(0, 1)],
                   embs=np.stack([skill_emb(0)]))
    with pytest.raises(TimeoutError):
        t.wait(timeout=0.2)
    with pytest.raises(TimeoutError):
        fab.join(timeout=0.2)          # re-registers the ticket
    outs = t.wait(timeout=180)         # same ticket, still live
    assert len(outs) == 1 and outs[0].case == "case1"
    fab.join(timeout=180)              # the re-registered barrier clears
    assert fab.redispatches == 0 and fab.deaths == 0
    assert fab.stale_drops == 0
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Full-state crash recovery (whole-fabric kill + manifest recover)
# ---------------------------------------------------------------------------


def test_whole_fabric_kill_recovers_byte_identical(tmp_path):
    """Kill the entire journaled fabric after a committed epoch, rebuild
    on the same WAL path: the recovery manifest restores the clock, the
    RQ2 counters, the engine cost counters (parent AND shipped worker
    deltas) and the store — resumed serving is byte-identical to an
    unkilled run."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_proc(1, **kw)
    ref_outs = serve_proc(ref, stream, 4)

    path = str(tmp_path / "wal")
    fab = build_proc(1, journal_path=path, snapshot_every=3, **kw)
    outs = serve_proc(fab, stream[:8], 4)
    fab.kill()
    fab2 = build_proc(1, journal_path=path, snapshot_every=3, **kw)
    outs += serve_proc(fab2, stream[8:], 4)
    assert_proc_equivalent(ref, ref_outs, fab2, outs)
    assert fab2.commit_stream.buffer.entries_applied == \
        int(np.asarray(fab2.memory.ptr))
    ref.close_shadow()
    fab2.close_shadow()


def test_clean_shutdown_checkpoint_recovers_full_state(tmp_path):
    """``close_shadow`` journals a manifest checkpoint: a fabric
    rebuilt after a *clean* shutdown resumes with the exact clock,
    counters and store — serving the rest of the stream matches the
    continuous run byte for byte."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    ref = build_proc(1, **kw)
    ref_outs = serve_proc(ref, stream, 4)

    path = str(tmp_path / "wal")
    fab = build_proc(1, journal_path=path, snapshot_every=3, **kw)
    outs = serve_proc(fab, stream[:8], 4)
    fab.close_shadow()
    fab2 = build_proc(1, journal_path=path, snapshot_every=3, **kw)
    outs += serve_proc(fab2, stream[8:], 4)
    assert_proc_equivalent(ref, ref_outs, fab2, outs)
    ref.close_shadow()
    fab2.close_shadow()


# ---------------------------------------------------------------------------
# Soak: seeded SIGKILL + wire jitter + clock skew
# ---------------------------------------------------------------------------


def test_proc_soak_random_kills_jitter_and_skew():
    """Randomized (but seed-reproducible) schedule of process SIGKILLs,
    transport latency jitter and lease clock skew against a pipelined
    request stream. Invariants: every outcome resolves, deaths ==
    restarts, the applied-entries counter matches the ring pointer, and
    no completion is double-applied."""
    seed = int(os.environ.get("REPRO_SOAK_SEED", "0"))
    plan = random_plan(seed, replicas=2, kills=2, transport_delays=2,
                      clock_skews=2, max_jitter=0.03, horizon=12)
    fab = build_proc(2, fault_plan=plan, weak_known={0, 1},
                     lease_interval=0.1, lease_timeout=8.0)
    rng = np.random.default_rng(seed)
    tickets, total = [], 0
    for _ in range(14):
        chunk = [(int(rng.integers(0, 8)), int(rng.integers(0, 8)))
                 for _ in range(int(rng.integers(1, 4)))]
        total += len(chunk)
        tickets.append(fab.submit(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk], keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk])))
    fab.flush_shadow(timeout=300)
    outs = []
    for t in tickets:
        outs += t.wait(timeout=180)
    # at most 2 kills against a redispatch budget of 2 per ticket: every
    # microbatch must resolve
    assert len(outs) == total
    assert all(o.case for o in outs)
    assert fab.deaths == fab.restarts
    assert fab.commit_stream.buffer.entries_applied == \
        int(np.asarray(fab.memory.ptr))
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Construction validation
# ---------------------------------------------------------------------------


def test_constructor_validation():
    factory = functools.partial(_make_parts, ())
    with pytest.raises(ValueError, match="workers"):
        ProcessServingFabric(factory, make_cfg(), workers=0)
    with pytest.raises(ValueError, match="lease_timeout"):
        ProcessServingFabric(factory, make_cfg(), workers=1,
                             lease_interval=1.0, lease_timeout=0.5)


# ---------------------------------------------------------------------------
# Parent learn-plane drain cadence driven by worker commit-epoch lag
# ---------------------------------------------------------------------------


def test_epoch_lag_drain_policy_decisions():
    """Unit semantics of the lag-aware cadence: empty queue never
    drains; lag 0 drains eagerly (broadcast plane idle); lag at/above
    the defer threshold holds; in between it falls through to the
    adaptive cost model (cold start: drain)."""
    from types import SimpleNamespace

    from repro.serving.procfabric import EpochLagDrainPolicy

    lag = {"v": 0}
    pol = EpochLagDrainPolicy(lambda: lag["v"], defer_lag=4)
    q = SimpleNamespace(_items=[], _batches=0, items_coalesced=0,
                        items_drained=0)
    pol.register(q)
    assert pol.due() is False                 # nothing pending
    q._items = [1, 2]
    assert pol.due() is True                  # lag 0: eager
    assert pol.lag_eager_drains == 1
    lag["v"] = 4
    assert pol.due() is False                 # backed up: defer
    assert pol.lag_deferrals == 1
    lag["v"] = 2
    assert pol.due() is True                  # mid lag: cost model,
    assert pol.coldstart_drains == 1          # cold start drains
    s = pol.stats()
    assert s["worker_epoch_lag"] == 2
    assert s["defer_lag"] == 4
    assert s["lag_eager_drains"] == 1 and s["lag_deferrals"] == 1
    with pytest.raises(ValueError):
        EpochLagDrainPolicy(lambda: 0, defer_lag=0)


def test_proc_adaptive_mode_installs_epoch_lag_policy():
    """``shadow_mode="adaptive"`` on the process fabric wires the
    parent learn queue to the lag-aware policy (heartbeat epochs, not
    just pending count), serving stays exact, and the barrier leaves
    nothing pending."""
    from repro.serving.procfabric import EpochLagDrainPolicy

    fab = build_proc(2, weak_known={0, 1}, shadow_mode="adaptive",
                     shadow_flush_every=4)
    try:
        assert isinstance(fab.drain_policy, EpochLagDrainPolicy)
        assert fab.learn.shadow.drain_policy is fab.drain_policy
        stream = make_stream()
        ref, ref_outs = None, None
        outs = serve_proc(fab, stream, 4)
        assert all(o.case for o in outs)
        assert len(outs) == len(stream)
        learn = fab.metrics()["replicas"][0]
        assert learn["items_enqueued"] == learn["items_drained"]
        pol = fab.metrics()["drain_policy"]
        assert pol["decisions"] > 0
        assert "worker_epoch_lag" in pol and "lag_eager_drains" in pol
    finally:
        fab.close_shadow()


def test_proc_adaptive_identical_to_thread_adaptive_outcomes():
    """The cadence signal changes *when* drains happen, never what they
    produce: adaptive process fabric matches the threaded closed-loop
    reference byte-for-byte at the barrier."""
    stream = make_stream()
    ref = build_fabric(1, weak_known={0, 1})
    ref_outs = serve_fabric(ref, stream, 4, submit=True)
    fab = build_proc(1, weak_known={0, 1}, shadow_mode="adaptive",
                     shadow_flush_every=4)
    try:
        outs = serve_proc(fab, stream, 4)
        assert_proc_equivalent(ref, ref_outs, fab, outs)
    finally:
        fab.close_shadow()
        ref.close_shadow()
