"""Sharded memory store: single-shard behaviour in-process, multi-shard
bit-identical parity (incl. tie-breaks) via a subprocess with forced host
placeholder devices (XLA device count must be set before jax initializes),
and the microbatched controller serving against the sharded store."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb

from repro.core import memory as mem
from repro.core.memory_sharded import ShardedMemory
from repro.core.pipeline import MicrobatchRAR

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = mem.MemoryConfig(capacity=32, embed_dim=16, guide_len=4)


def rand_unit(rng, d=16):
    v = rng.normal(size=d).astype(np.float32)
    return v / np.linalg.norm(v)


def test_single_shard_matches_memory_state(rng):
    """With however many devices this host has (1 in CI), the sharded
    store must agree with MemoryState exactly on a mixed workload."""
    single = mem.init_memory(CFG)
    sharded = ShardedMemory(CFG)
    embs = np.stack([rand_unit(rng) for _ in range(10)])
    guides = np.arange(40, dtype=np.int32).reshape(10, 4)
    hg = np.arange(10) % 2 == 0
    hd = np.arange(10) % 3 == 0
    now = np.arange(10, dtype=np.int32)
    args = (jnp.asarray(embs), jnp.asarray(guides), jnp.asarray(hg),
            jnp.asarray(hd), jnp.asarray(now))
    single = mem.add_batch(single, *args)
    sharded.add_batch(*args)
    assert sharded.size_fast == single.size_fast == 10

    qs = np.stack([rand_unit(rng) for _ in range(4)])
    qs[0] = embs[3]
    for guides_only in (False, True):
        a = mem.query_batch(single, jnp.asarray(qs),
                            guides_only=guides_only).device_get()
        b = mem.query_batch(sharded, jnp.asarray(qs),
                            guides_only=guides_only).device_get()
        np.testing.assert_array_equal(a.sim, b.sim)
        np.testing.assert_array_equal(a.meta, b.meta)

    # flag updates hit the replicated metadata identically
    single = mem.mark_soft(single, jnp.int32(0))
    sharded.mark_soft(jnp.int32(0))
    single = mem.touch(single, jnp.int32(2), jnp.int32(99))
    sharded.touch(jnp.int32(2), jnp.int32(99))
    st = sharded.to_single_device()
    for f in ("guide", "hard", "added_at", "ptr", "emb", "mask"):
        np.testing.assert_array_equal(np.asarray(getattr(single, f)),
                                      np.asarray(getattr(st, f)), f)


def test_sharded_wraparound_and_overflow(rng):
    sharded = ShardedMemory(CFG)
    for i in range(CFG.capacity + 5):
        sharded.add(jnp.asarray(rand_unit(rng)), jnp.zeros(4, jnp.int32),
                    False, False, np.int32(i))
    assert sharded.size_fast == CFG.capacity
    assert int(sharded.ptr) == CFG.capacity + 5
    with pytest.raises(ValueError):
        sharded.add_batch(
            jnp.zeros((CFG.capacity + 1, 16), jnp.float32),
            jnp.zeros((CFG.capacity + 1, 4), jnp.int32),
            jnp.zeros(CFG.capacity + 1, bool),
            jnp.zeros(CFG.capacity + 1, bool),
            jnp.zeros(CFG.capacity + 1, jnp.int32))


def test_capacity_must_divide_shards():
    import jax

    if len(jax.devices()) == 1:
        sharded = ShardedMemory(mem.MemoryConfig(capacity=31, embed_dim=16,
                                                 guide_len=4))
        assert sharded.shards == 1          # everything divides 1
    else:
        with pytest.raises(ValueError):
            ShardedMemory(mem.MemoryConfig(capacity=31, embed_dim=16,
                                           guide_len=4))


def test_multi_shard_parity_subprocess():
    """4 forced host devices: sharded (sim, idx) — and the full packed
    metadata — bit-identical to single-device, tie-breaks included."""
    flags = (os.environ.get("XLA_FLAGS", "")
             + " --xla_force_host_platform_device_count=4").strip()
    env = dict(os.environ,
               PYTHONPATH=SRC,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS=flags)
    r = subprocess.run([sys.executable, "-m", "repro.core.memory_sharded"],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(r.stdout.strip().splitlines()[-1])
    assert report["shards"] == 4
    assert report["bit_identical"] is True
    assert report["checks"] > 0
    # the selftest must have exercised the top-k merge across shards
    # (global top-k == single-device top-k on the same ring, ties incl.)
    assert report["topk_checked"] == [1, 2, 4, 8]
    # ... and the deferred-commit sweep: epoch-buffered commits (shuffled
    # staging + flag updates) bit-identical across both store flavours
    assert report["deferred_commit_epochs"] > 0


def test_single_shard_topk_matches_memory_state(rng):
    """Sharded top-k agrees bit-for-bit with MemoryState on this host's
    mesh (1 shard in CI; the 4-shard merge runs in the subprocess test)."""
    single = mem.init_memory(CFG)
    sharded = ShardedMemory(CFG)
    embs = np.stack([rand_unit(rng) for _ in range(12)])
    embs[5] = embs[1]              # duplicate row → tie-break path
    guides = np.arange(48, dtype=np.int32).reshape(12, 4)
    hg = np.arange(12) % 2 == 0
    hd = np.arange(12) % 3 == 0
    now = np.arange(12, dtype=np.int32)
    args = (jnp.asarray(embs), jnp.asarray(guides), jnp.asarray(hg),
            jnp.asarray(hd), jnp.asarray(now))
    single = mem.add_batch(single, *args)
    sharded.add_batch(*args)
    qs = np.stack([rand_unit(rng) for _ in range(4)])
    qs[0] = embs[1]
    for guides_only in (False, True):
        for k in (1, 2, 4, 8):
            a = mem.query_topk_batch(single, jnp.asarray(qs), k,
                                     guides_only=guides_only).device_get()
            b = sharded.query_topk_batch(jnp.asarray(qs), k,
                                         guides_only=guides_only
                                         ).device_get()
            np.testing.assert_array_equal(a.sim, b.sim)
            np.testing.assert_array_equal(a.meta, b.meta)
            a1 = mem.query_topk(single, jnp.asarray(qs[0]), k,
                                guides_only=guides_only).device_get()
            b1 = sharded.query_topk(jnp.asarray(qs[0]), k,
                                    guides_only=guides_only).device_get()
            np.testing.assert_array_equal(a1.sim, b1.sim)
            np.testing.assert_array_equal(a1.meta, b1.meta)


def test_sharded_topk_rejects_k_past_shard_rows():
    """k must not exceed the logical rows per shard (the merge would see
    local padding rows whose global slots collide with the next shard)."""
    import jax

    sharded = ShardedMemory(CFG)
    if len(jax.devices()) == 1:
        # single shard: the capacity bound is the only limit
        with pytest.raises(ValueError):
            sharded.query_topk(jnp.zeros(16), CFG.capacity + 1)
    else:
        with pytest.raises(ValueError):
            sharded.query_topk(jnp.zeros(16),
                               CFG.capacity // len(jax.devices()) + 1)


def build_batched(memory=None, **cfg_kw):
    weak = FakeTier(known={0, 1}, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    holder = {}
    ctrl = MicrobatchRAR(weak, strong, lambda p: holder["emb"],
                         lambda e, k: False, make_cfg(**cfg_kw),
                         memory=memory)
    return ctrl


def test_controller_serves_against_sharded_store():
    """MicrobatchRAR with an injected ShardedMemory produces the same
    Outcome stream and store contents as with the default MemoryState."""
    cfg_kw = dict()
    stream = [(s, x) for x in range(3) for s in range(5)]

    plain = build_batched(**cfg_kw)
    shard = build_batched(memory=ShardedMemory(plain.cfg.memory), **cfg_kw)
    for ctrl in (plain, shard):
        outs = []
        for start in range(0, len(stream), 4):
            chunk = stream[start:start + 4]
            outs += ctrl.process_batch(
                [prompt(s, x) for s, x in chunk],
                [greq(s) for s, _ in chunk],
                keys=chunk,
                embs=np.stack([skill_emb(s) for s, _ in chunk]))
        ctrl.outs = outs
    assert plain.outs == shard.outs
    assert plain.weak.engine.calls == shard.weak.engine.calls
    assert plain.strong.engine.calls == shard.strong.engine.calls
    st = shard.memory.to_single_device()
    for f in ("emb", "mask", "guide", "hard", "added_at", "ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(plain.memory, f)),
                                      np.asarray(getattr(st, f)), f)
