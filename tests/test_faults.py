"""Fault-tolerance suite: deterministic fault injection, replica
supervision + redispatch byte-identity, tier retry/backoff/breaker,
degraded-mode (weak-only) routing with deferred probe replay, and
crash-consistent journal recovery.

The invariants pinned here are the recovery plane's acceptance criteria:

* a replica crash fires before any side effect, so a supervised
  redispatch run is byte-identical to a no-fault run;
* a kill before the WAL append recovers to the previous epoch, a kill
  after the WAL append (mid-apply) recovers one epoch ahead — never a
  torn epoch;
* a strong-tier brownout serves weak-only with zero errored tickets and
  replays every deferred probe once the breaker closes;
* with no FaultPlan and default config every path is byte-identical to
  the pre-resilience code (the existing equivalence suites run wrapped).
"""
import dataclasses
import os
import threading
import time

import numpy as np
import pytest
from test_pipeline import MEM_FIELDS, make_stream, run_batched
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb
from test_shadow import assert_equivalent

from repro.core import memory as mem
from repro.core.decisions import DEGRADED_CASES
from repro.core.fm import (CircuitBreaker, InjectedTierError, ResilientTier,
                           RetryPolicy, TierTimeout, TierUnavailableError)
from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import RAR, RARConfig, retry_policy
from repro.core.shadow import ShadowQueue
from repro.serving.fabric import ServingFabric, Ticket
from repro.serving.faults import (FaultPlan, FaultSpec, InjectedFault,
                                  ReplicaCrash, random_plan)


def build_fabric(replicas=1, weak_known=(), fault_plan=None, **cfg_kw):
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    return ServingFabric(weak, strong, lambda p: None, lambda e, k: False,
                         make_cfg(**cfg_kw), replicas=replicas,
                         fault_plan=fault_plan)


def serve_serialized(fab, stream, batch):
    """Submit microbatches one ticket at a time (wait each before the
    next submit): the serve order is then deterministic even across a
    crash + redispatch, which is what makes byte-identity assertable."""
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        t = fab.submit([prompt(s, x) for s, x in chunk],
                       [greq(s) for s, _ in chunk], keys=chunk,
                       embs=np.stack([skill_emb(s) for s, _ in chunk]))
        outs += t.wait(timeout=60)
    fab.flush_shadow()
    return outs


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("nope", "crash")
    with pytest.raises(ValueError):
        FaultSpec("drain", "explode")
    with pytest.raises(ValueError):
        FaultSpec("drain", "error", at=0)


def test_fault_plan_fires_at_exact_hit_numbers():
    plan = FaultPlan([FaultPlan.replica_crash(1, at=2, count=2)])
    plan.fire("replica_serve", replica=1)          # hit 1: below `at`
    plan.fire("replica_serve", replica=0)          # other replica: no match
    for _ in range(2):                             # hits 2..3: due
        with pytest.raises(ReplicaCrash):
            plan.fire("replica_serve", replica=1)
    plan.fire("replica_serve", replica=1)          # hit 4: spent
    assert plan.n_fired == 2
    assert all(site == "replica_serve" for site, _, _ in plan.fired)


def test_fault_plan_reproducible_and_off_is_noop():
    def drive(plan):
        log = []
        for i in range(6):
            try:
                plan.fire("tier_call", tier="strong", op="answer_batch")
                log.append("ok")
            except InjectedTierError:
                log.append("err")
        return log

    a = drive(FaultPlan([FaultPlan.tier_error("strong", at=3, count=2)]))
    b = drive(FaultPlan([FaultPlan.tier_error("strong", at=3, count=2)]))
    assert a == b == ["ok", "ok", "err", "err", "ok", "ok"]
    assert drive(FaultPlan()) == ["ok"] * 6        # empty plan: no-op


def test_random_plan_is_seed_deterministic():
    kw = dict(replicas=3, crashes=2, tier_errors=2, drain_errors=1,
              wal_crashes=1, apply_crashes=1, kills=2,
              transport_delays=2, clock_skews=2, max_jitter=0.04)
    a, b = random_plan(7, **kw), random_plan(7, **kw)
    assert a.specs == b.specs
    c = random_plan(8, **kw)
    assert a.specs != c.specs
    # every requested fault family is present in the schedule
    assert {s.site for s in a.specs} == {
        "replica_serve", "tier_call", "drain", "wal_write",
        "commit_apply", "transport_frame", "clock_skew"}
    assert sum(s.action == "kill" for s in a.specs) == 2
    for s in a.specs:
        if s.site in ("transport_frame", "clock_skew"):
            assert 0.0 < s.delay <= 0.04


# ---------------------------------------------------------------------------
# Tier resilience: retry, backoff, timeout, breaker
# ---------------------------------------------------------------------------


def make_resilient(policy, plan=None, seed=1, **kw):
    inner = FakeTier(known=range(10_000), can_guide=True, name="strong")
    sleeps = []
    rt = ResilientTier(inner, policy, name="strong", fault_plan=plan,
                       seed=seed, sleep_fn=sleeps.append, **kw)
    return rt, inner, sleeps


def test_retry_recovers_from_transient_errors():
    plan = FaultPlan([FaultPlan.tier_error("strong", at=1, count=2)])
    rt, inner, slept = make_resilient(RetryPolicy(max_retries=3), plan)
    ans = rt.answer_batch([prompt(3, 1)])
    assert ans[0] == (3 + 1) % 4                  # succeeded on attempt 3
    assert rt.retries == 2 and rt.failures == 2
    assert slept == rt.sleeps and len(slept) == 2
    assert inner.engine.calls == 1                # failures fired pre-call


def test_retry_backoff_is_seeded_and_deterministic():
    def sleeps_for(seed):
        plan = FaultPlan([FaultPlan.tier_error("strong", count=3)])
        rt, _, slept = make_resilient(
            RetryPolicy(max_retries=3, backoff_base=0.1), plan, seed=seed)
        rt.answer_batch([prompt(0, 0)])
        return slept

    a, b = sleeps_for(5), sleeps_for(5)
    assert a == b and len(a) == 3
    # exponential envelope with jitter in [0.5, 1.5) of the base
    for i, s in enumerate(a):
        assert 0.5 * 0.1 * 2 ** i <= s < 1.5 * 0.1 * 2 ** i
    assert sleeps_for(6) != a


def test_exhausted_retries_raise_unavailable():
    plan = FaultPlan([FaultPlan.tier_error("strong", count=10)])
    rt, inner, _ = make_resilient(RetryPolicy(max_retries=2), plan)
    with pytest.raises(TierUnavailableError):
        rt.answer_batch([prompt(0, 0)])
    assert rt.failures == 3                       # 1 try + 2 retries
    assert inner.engine.calls == 0


def test_latency_spike_beyond_timeout_raises_tier_timeout():
    plan = FaultPlan([FaultPlan.tier_delay("strong", delay=30.0)])
    rt, _, _ = make_resilient(RetryPolicy(timeout=0.05), plan)
    with pytest.raises(TierUnavailableError) as ei:
        rt.answer_batch([prompt(0, 0)])
    assert isinstance(ei.value.__cause__, TierTimeout)  # and never slept


def test_wrapper_preserves_inner_capability_surface():
    rt, inner, _ = make_resilient(RetryPolicy())
    assert getattr(rt, "answer_many", None) is None   # FakeTier lacks it
    assert rt.engine is inner.engine
    assert rt.name == "strong"
    with pytest.raises(AttributeError):
        rt.no_such_method


def test_circuit_breaker_lifecycle():
    clock = [0.0]
    br = CircuitBreaker(threshold=2, cooldown=10.0, now_fn=lambda: clock[0])
    assert br.state == "closed" and br.available()
    br.record_failure()
    assert br.state == "closed"                   # below threshold
    br.record_failure()
    assert br.state == "open" and br.opens == 1
    assert not br.available()
    with pytest.raises(TierUnavailableError):     # cooling: calls shed
        br.before_call()
    assert br.shed == 1
    clock[0] = 11.0                               # cooldown elapsed
    assert br.available()
    br.before_call()                              # half-open probe slot
    assert br.state == "half_open"
    with pytest.raises(TierUnavailableError):     # single probe at a time
        br.before_call()
    br.record_success()
    assert br.state == "closed" and br.available()


def test_breaker_reopens_on_failed_probe():
    clock = [0.0]
    br = CircuitBreaker(threshold=1, cooldown=5.0, now_fn=lambda: clock[0])
    br.record_failure()
    clock[0] = 6.0
    br.before_call()                              # half-open probe
    br.record_failure()                           # probe failed
    assert br.state == "open" and br.opens == 2
    assert not br.available()


def test_adaptive_breaker_tightens_threshold_and_stretches_cooldown():
    """A flaky call history drives the error EWMA up: the breaker opens
    after fewer consecutive failures and cools down longer — all under
    an injected clock, no wall time involved."""
    clock = [0.0]
    br = CircuitBreaker(threshold=4, cooldown=10.0,
                        now_fn=lambda: clock[0], adaptive=True,
                        ewma_alpha=0.5)
    # clean history: effective knobs are exactly the configured ones
    st = br.stats()
    assert st["error_ewma"] == 0.0
    assert st["effective_threshold"] == 4
    assert st["effective_cooldown"] == 10.0
    br.record_failure()          # ewma .50 → effective threshold 2
    assert br.state == "closed"
    assert br.stats()["effective_threshold"] == 2
    br.record_failure()          # ewma .75 → threshold 1 ≤ 2 failures
    assert br.state == "open" and br.opens == 1
    st = br.stats()
    assert st["error_ewma"] == pytest.approx(0.75)
    assert st["effective_cooldown"] == pytest.approx(17.5)
    clock[0] = 10.5              # static cooldown elapsed — adaptive not
    assert not br.available()
    clock[0] = 17.5
    assert br.available()


def test_adaptive_breaker_relaxes_back_on_successes():
    """Successes decay the EWMA: after a clean stretch the effective
    knobs return to (approach) the configured ones."""
    clock = [0.0]
    br = CircuitBreaker(threshold=2, cooldown=10.0,
                        now_fn=lambda: clock[0], adaptive=True,
                        ewma_alpha=1.0)  # memoryless: tracks last call
    br.record_failure()          # ewma 1.0 → threshold 1: trips at once
    assert br.state == "open" and br.opens == 1
    clock[0] = 20.0              # effective cooldown = 10 · (1 + 1)
    assert br.available()
    br.before_call()             # half-open probe
    br.record_success()
    assert br.state == "closed"
    st = br.stats()
    assert st["error_ewma"] == 0.0
    assert st["effective_threshold"] == 2
    assert st["effective_cooldown"] == 10.0


def test_adaptive_breaker_default_off_keeps_static_knobs():
    """adaptive=False (the default): the EWMA never moves and the
    effective knobs are the static ones, whatever the history — the
    byte-identity pins over the static breaker hold unchanged."""
    clock = [0.0]
    br = CircuitBreaker(threshold=3, cooldown=5.0,
                        now_fn=lambda: clock[0])
    br.record_failure()
    br.record_failure()
    assert br.error_ewma == 0.0 and br.state == "closed"
    assert br._effective_threshold_locked() == 3
    assert br._effective_cooldown_locked() == 5.0
    assert "error_ewma" not in br.stats()         # off ⇒ not advertised
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=1, cooldown=1.0, ewma_alpha=0.0)
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=1, cooldown=1.0, ewma_alpha=1.5)


def test_adaptive_breaker_state_survives_export_restore():
    """The manifest round-trip carries the learned error rate: a
    recovered site resumes with the dead site's EWMA, not a clean
    slate."""
    clock = [0.0]
    br = CircuitBreaker(threshold=4, cooldown=10.0,
                        now_fn=lambda: clock[0], adaptive=True,
                        ewma_alpha=0.5)
    br.record_failure()
    br.record_failure()          # open, ewma .75
    st = br.export_state()
    assert st["error_ewma"] == pytest.approx(0.75)
    br2 = CircuitBreaker(threshold=4, cooldown=10.0,
                         now_fn=lambda: clock[0], adaptive=True,
                         ewma_alpha=0.5)
    br2.restore_state(st)
    assert br2.state == "open" and br2.opens == 1
    assert br2.stats()["error_ewma"] == pytest.approx(0.75)
    assert br2.stats()["effective_cooldown"] == pytest.approx(17.5)
    legacy = dict(st)
    legacy.pop("error_ewma")     # manifest from a pre-adaptive build
    br2.restore_state(legacy)
    assert br2.error_ewma == 0.0


def test_adaptive_knobs_flow_through_config():
    """RARConfig → retry_policy → ResilientTier plumbing, plus config
    validation of the smoothing factor."""
    rt, _, _ = make_resilient(RetryPolicy(breaker_threshold=3,
                                          breaker_adaptive=True,
                                          breaker_ewma_alpha=0.5))
    assert rt.breaker.adaptive and rt.breaker.ewma_alpha == 0.5
    cfg = make_cfg(breaker_threshold=2, breaker_adaptive=True,
                   breaker_ewma_alpha=0.3)
    pol = retry_policy(cfg)
    assert pol.breaker_adaptive and pol.breaker_ewma_alpha == 0.3
    with pytest.raises(ValueError):
        make_cfg(breaker_ewma_alpha=0.0)


def test_default_policy_wrapper_is_pass_through():
    """With every knob off the wrapper adds nothing: same answers, same
    engine-call counts, exceptions propagate untouched."""
    rt, inner, slept = make_resilient(RetryPolicy())
    ref = FakeTier(known=range(10_000), can_guide=True)
    ps = [prompt(s, x) for s in range(4) for x in range(2)]
    np.testing.assert_array_equal(rt.answer_batch(ps), ref.answer_batch(ps))
    np.testing.assert_array_equal(rt.generate_guides([greq(1)], 8),
                                  ref.generate_guides([greq(1)], 8))
    assert inner.engine.calls == ref.engine.calls
    assert rt.breaker is None and not slept


# ---------------------------------------------------------------------------
# Degraded-mode routing: brownout → weak-only, deferred probes replay
# ---------------------------------------------------------------------------


def brownout_cfg(**kw):
    base = dict(tier_max_retries=0, breaker_threshold=1,
                breaker_cooldown=0.05)
    base.update(kw)
    return make_cfg(**base)


def test_sequential_brownout_serves_weak_only_and_replays():
    plan = FaultPlan([FaultPlan.tier_error("strong", at=1, count=1)])
    holder = {}
    rar = RAR(FakeTier(known={5, 6}, name="weak"),
              FakeTier(known=range(10_000), can_guide=True, name="strong"),
              lambda p: holder["emb"], lambda e, k: False,
              brownout_cfg(), fault_plan=plan)

    def go(s, x):
        holder["emb"] = skill_emb(s)
        return rar.process(prompt(s, x), greq(s), key=(s, x))

    out = go(5, 1)                     # strong call fails → probe deferred
    assert out.case == "shadow_deferred" and out.served_by == "weak"
    assert out.strong_calls == 0 and out.response == (5 + 1) % 4
    assert rar.probes_deferred == 1 and len(rar.deferred_probes) == 1
    out2 = go(6, 2)                    # breaker open → routed degraded
    assert out2.case == "shadow_deferred" and out2.strong_calls == 0
    assert rar.probes_deferred == 2
    assert rar.memory_occupancy == 0   # nothing recorded during brownout

    time.sleep(0.08)                   # breaker cooldown elapses
    assert rar.replay_deferred() == 2
    assert rar.probes_replayed == 2 and not rar.deferred_probes
    # the deferred outcomes resolved in place: probe ran, entry recorded
    assert out.case == "case1" and out.strong_calls == 1
    assert out.served_by == "weak"     # the user-facing serve is history
    assert rar.memory_occupancy == 2
    # and the memory now routes the skill without the strong tier
    out3 = go(5, 3)
    assert out3.case == "memory_skill" and out3.strong_calls == 0


def test_sequential_brownout_memory_hard_degraded():
    """A hard entry hit during a brownout serves weak-only (no strong
    fallback, no re-probe while the tier is down) and the cool-down
    clock keeps running."""
    # go(3,1) makes two strong calls (answer + guide gen); hit 4 is the
    # strong fallback of the SECOND memory-hard hit
    plan = FaultPlan([FaultPlan.tier_error("strong", at=4, count=1)])
    holder = {}
    weak = FakeTier(known=set(), name="weak")
    weak.answer_batch = lambda ps: np.asarray([-1] * len(ps))  # stubborn
    rar = RAR(weak,
              FakeTier(known=range(10_000), can_guide=True, name="strong"),
              lambda p: holder["emb"], lambda e, k: False,
              brownout_cfg(reprobe_period=100), fault_plan=plan)

    def go(s, x):
        holder["emb"] = skill_emb(s)
        return rar.process(prompt(s, x), greq(s), key=(s, x))

    assert go(3, 1).case == "case3"    # hard entry lands (2 strong calls)
    assert go(3, 2).case == "memory_hard"
    out = go(3, 3)                     # 3rd strong call injected → breaker
    # the hit is within cool-down; with the strong tier down the serve
    # degrades to the weak answer instead of erroring
    assert out.case == "memory_hard_degraded" and out.served_by == "weak"
    assert out.strong_calls == 0
    assert rar.probes_deferred == 0    # hard hits defer nothing


@pytest.mark.parametrize("batch", [1, 4])
def test_batched_brownout_weak_only_and_flush_replays(batch):
    plan = FaultPlan([FaultPlan.tier_error("strong", at=1, count=1)])
    ctrl = MicrobatchRAR(
        FakeTier(known={0, 1}, name="weak"),
        FakeTier(known=range(10_000), can_guide=True, name="strong"),
        lambda p: None, lambda e, k: False, brownout_cfg(),
        fault_plan=plan)
    stream = make_stream()
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        outs += ctrl.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk], keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
    # zero errors: every request served (weak-only where degraded)
    assert len(outs) == len(stream)
    assert all(o.response is not None for o in outs)
    degraded = [o for o in outs if o.case in DEGRADED_CASES]
    assert degraded and all(o.strong_calls == 0 for o in degraded)
    assert ctrl.probes_deferred == len(ctrl.deferred_probes) > 0
    time.sleep(0.08)
    ctrl.flush_shadow()                # barrier replays deferred probes
    assert ctrl.probes_replayed == ctrl.probes_deferred
    assert not ctrl.deferred_probes
    assert all(o.case not in ("shadow_deferred",) for o in outs)
    assert ctrl.shadow.items_enqueued == ctrl.shadow.items_drained
    ctrl.close_shadow()


def test_fabric_brownout_zero_errored_tickets():
    plan = FaultPlan([FaultPlan.tier_error("strong", at=1, count=1)])
    fab = build_fabric(2, weak_known={0, 1}, fault_plan=plan,
                       tier_max_retries=0, breaker_threshold=1,
                       breaker_cooldown=0.05)
    assert isinstance(fab.learn.strong, ResilientTier)
    # one shared wrapper across replicas: an outage seen by one degrades
    # routing on all
    assert all(r.strong is fab.learn.strong for r in fab.replicas)
    outs = serve_serialized(fab, make_stream(), 4)
    assert all(o.response is not None for o in outs)   # no errored tickets
    stats = fab.stats()
    assert stats["probes_deferred"] > 0
    assert stats["strong_resilience"]["failures"] == 1
    time.sleep(0.08)
    fab.flush_shadow()                 # replay once the breaker closes
    stats = fab.stats()
    assert stats["probes_replayed"] == stats["probes_deferred"]
    assert fab.learn.strong.breaker.state == "closed"
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Replica supervision: crash → restart + redispatch, byte-identical
# ---------------------------------------------------------------------------


def test_replica_crash_redispatch_byte_identical_to_no_fault_run():
    """The acceptance anchor: a crashed worker's microbatch redispatches
    to a survivor and the run's bytes (outcomes, memory, FM calls, RQ2
    counters) match the no-fault run exactly."""
    stream = make_stream()
    ref, ref_outs = run_batched(stream, 4, weak_known={0, 1})
    plan = FaultPlan([FaultPlan.replica_crash(0, at=2)])
    fab = build_fabric(2, weak_known={0, 1}, fault_plan=plan)
    fab_outs = serve_serialized(fab, stream, 4)
    assert_equivalent(ref, ref_outs, fab.learn, fab_outs)
    assert fab.deaths == 1 and fab.restarts == 1 and fab.redispatches == 1
    assert fab.health == ["healthy", "healthy"]
    fab.close_shadow()


def test_single_replica_crash_restarts_and_recovers():
    """A 1-replica fabric redispatches to its own restarted slot."""
    stream = make_stream()
    ref, ref_outs = run_batched(stream, 4, weak_known={0, 1})
    plan = FaultPlan([FaultPlan.replica_crash(0, at=3)])
    fab = build_fabric(1, weak_known={0, 1}, fault_plan=plan)
    fab_outs = serve_serialized(fab, stream, 4)
    assert_equivalent(ref, ref_outs, fab.learn, fab_outs)
    assert fab.deaths == 1 and fab.restarts == 1
    fab.close_shadow()


def test_bounded_redispatch_exhaustion_surfaces_crash():
    plan = FaultPlan([FaultPlan.replica_crash(0, count=100),
                      FaultPlan.replica_crash(1, count=100)])
    fab = build_fabric(2, weak_known={0}, fault_plan=plan,
                       max_redispatch=2)
    t = fab.submit([prompt(0, 1)], [greq(0)], embs=skill_emb(0)[None],
                   replica=0)
    with pytest.raises(RuntimeError) as ei:
        t.wait(timeout=60)
    assert isinstance(ei.value.__cause__, ReplicaCrash)
    assert t.redispatches == 2                     # bounded: 1 try + 2 re
    assert fab.deaths == 3 and fab.restarts == 3
    # clear the join barrier of the failed ticket, then verify the
    # restarted workers still serve (the crash specs are spent)
    with pytest.raises(RuntimeError):
        fab.join()
    plan.specs.clear()
    outs = serve_serialized(fab, [(0, 2), (1, 3)], 2)
    assert len(outs) == 2
    fab.close_shadow()


def test_app_level_error_is_not_redispatched():
    """Only ReplicaCrash is redispatchable: an application exception's
    batch may already have side effects, so it must surface as before
    (pins the pre-existing worker-error contract)."""
    fab = build_fabric(2, weak_known={0})
    boom = RuntimeError("app bug")

    def dying(prompts):
        raise boom

    fab.replicas[1].strong = FakeTier(known=range(10_000), can_guide=True)
    fab.replicas[1].strong.answer_batch = dying
    t = fab.submit([prompt(5, 1)], [greq(5)], embs=skill_emb(5)[None],
                   replica=1)
    with pytest.raises(RuntimeError) as ei:
        t.wait(timeout=60)
    assert ei.value.__cause__ is boom
    assert t.redispatches == 0 and fab.redispatches == 0
    with pytest.raises(RuntimeError):
        fab.join()
    fab.close_shadow()


# ---------------------------------------------------------------------------
# Bounded barriers + ticket semantics (satellites)
# ---------------------------------------------------------------------------


def test_ticket_wait_timeout_then_still_waitable():
    t = Ticket(replica=0)
    with pytest.raises(TimeoutError):
        t.wait(timeout=0.01)
    t.outcomes = ["ok"]
    t._done.set()
    assert t.wait(timeout=1) == ["ok"]            # timed-out wait ≠ abandoned


def test_ticket_wait_chains_worker_error():
    t = Ticket(replica=3)
    cause = ValueError("inner")
    t.error = cause
    t._done.set()
    with pytest.raises(RuntimeError) as ei:
        t.wait()
    assert ei.value.__cause__ is cause
    assert "replica 3" in str(ei.value)


def test_fabric_join_timeout_keeps_tickets_registered():
    fab = build_fabric(1, weak_known={0})
    gate = threading.Event()
    orig = fab.replicas[0].process_batch

    def gated(*a, **kw):
        gate.wait()
        return orig(*a, **kw)

    fab.replicas[0].process_batch = gated
    fab.submit([prompt(0, 1)], [greq(0)], embs=skill_emb(0)[None])
    with pytest.raises(TimeoutError):
        fab.join(timeout=0.05)
    assert fab._tickets                            # re-registered, retryable
    gate.set()
    fab.join(timeout=60)                           # retry succeeds
    with pytest.raises(TimeoutError):
        # flush_shadow passes its bound through the join leg
        fab.replicas[0].process_batch = gated
        gate.clear()
        fab.submit([prompt(0, 2)], [greq(0)], embs=skill_emb(0)[None])
        fab.flush_shadow(timeout=0.05)
    gate.set()
    fab.flush_shadow(timeout=60)
    fab.close_shadow()


def test_shadow_close_raises_on_wedged_drainer_instead_of_orphaning():
    """The PR-4 bug fix: close() used to null the worker reference even
    when join timed out, orphaning a live drainer that could still write
    to the store. Now the barrier failure raises and the handle is kept
    so the caller can retry."""
    release = threading.Event()

    def slow_runner(items):
        release.wait()

    q = ShadowQueue(slow_runner, mode="async", flush_every=1)
    q.submit([None])                               # wakes the drainer
    with pytest.raises(TimeoutError):
        q.close(timeout=0.05)
    assert q._worker is not None                   # NOT orphaned
    release.set()
    q.close(timeout=60)                            # retry completes
    assert q._worker is None


def test_injected_drain_error_surfaces_at_barrier():
    plan = FaultPlan([FaultPlan.drain_error(at=1)])
    ctrl = MicrobatchRAR(
        FakeTier(known=set(), name="weak"),
        FakeTier(known=range(10_000), can_guide=True, name="strong"),
        lambda p: None, lambda e, k: False,
        make_cfg(shadow_mode="async", shadow_flush_every=1),
        fault_plan=plan)
    ctrl.process_batch([prompt(2, 1)], [greq(2)],
                       embs=skill_emb(2)[None])
    with pytest.raises(RuntimeError, match="shadow drainer failed"):
        for _ in range(100):
            ctrl.flush_shadow()
            time.sleep(0.01)
    ctrl.close_shadow()


# ---------------------------------------------------------------------------
# Crash-consistent journal: WAL + snapshot recovery
# ---------------------------------------------------------------------------


def run_journaled(stream, path, fault_plan=None, snapshot_every=8,
                  **cfg_kw):
    holder = {}
    rar = RAR(FakeTier(known={0, 1}, name="weak"),
              FakeTier(known=range(10_000), can_guide=True, name="strong"),
              lambda p: holder["emb"], lambda e, k: False,
              make_cfg(journal_path=path, snapshot_every=snapshot_every,
                       **cfg_kw),
              fault_plan=fault_plan)
    snapshots = {0: rar.memory}        # state after each commit epoch
    for s, x in stream:
        holder["emb"] = skill_emb(s)
        rar.process(prompt(s, x), greq(s), key=(s, x))
        snapshots[rar.commit_stream.buffer.epoch] = rar.memory
    return rar, snapshots


def assert_states_equal(a, b):
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)


@pytest.mark.parametrize("snapshot_every", [1, 3, 100])
def test_journal_recovery_is_byte_identical(tmp_path, snapshot_every):
    """Clean-shutdown recovery: journal a run, recover from disk, get
    the exact same store — regardless of where the last snapshot fell
    (snapshot_every=100 → pure WAL replay; =1 → pure snapshot)."""
    path = str(tmp_path / "journal")
    rar, _ = run_journaled(make_stream(), path,
                           snapshot_every=snapshot_every)
    rec = mem.MemoryJournal.recover(path, rar.cfg.memory)
    assert rec is not None
    state, epoch, applied, _ = rec
    assert_states_equal(state, rar.memory)
    assert epoch == rar.commit_stream.buffer.epoch
    assert applied == rar.commit_stream.buffer.entries_applied


def test_journaled_run_matches_unjournaled_run(tmp_path):
    """Journaling is write-path-only: the served bytes are identical to
    the journal-off run."""
    stream = make_stream()
    holder = {}

    def build(**kw):
        return RAR(FakeTier(known={0, 1}, name="weak"),
                   FakeTier(known=range(10_000), can_guide=True,
                            name="strong"),
                   lambda p: holder["emb"], lambda e, k: False,
                   make_cfg(**kw))

    ref = build()
    jr = build(journal_path=str(tmp_path / "journal"))
    ref_outs, jr_outs = [], []
    for s, x in stream:
        holder["emb"] = skill_emb(s)
        ref_outs.append(ref.process(prompt(s, x), greq(s), key=(s, x)))
        holder["emb"] = skill_emb(s)
        jr_outs.append(jr.process(prompt(s, x), greq(s), key=(s, x)))
    assert_equivalent(ref, ref_outs, jr, jr_outs)


def test_wal_crash_recovers_previous_epoch(tmp_path):
    """Kill before the WAL record is durable → the in-flight epoch is
    lost, recovery lands exactly on the previous epoch's bytes."""
    path = str(tmp_path / "journal")
    crash_at = 4
    plan = FaultPlan([FaultPlan.wal_crash(at=crash_at)])
    with pytest.raises(InjectedFault):
        run_journaled(make_stream(), path, fault_plan=plan,
                      snapshot_every=100)
    _, ref_snapshots = run_journaled(make_stream(),
                                     str(tmp_path / "ref"),
                                     snapshot_every=100)
    state, epoch, _, _ = mem.MemoryJournal.recover(
        path, make_cfg().memory)
    assert epoch == crash_at - 1
    assert_states_equal(state, ref_snapshots[crash_at - 1])


def test_apply_crash_recovers_one_epoch_ahead(tmp_path):
    """Kill after the WAL record but before the in-memory apply → the
    journaled epoch survives the crash: recovery replays it and lands
    one epoch AHEAD of the crashed process's memory."""
    path = str(tmp_path / "journal")
    crash_at = 4
    plan = FaultPlan([FaultPlan.apply_crash(at=crash_at)])
    with pytest.raises(InjectedFault):
        run_journaled(make_stream(), path, fault_plan=plan,
                      snapshot_every=100)
    _, ref_snapshots = run_journaled(make_stream(),
                                     str(tmp_path / "ref"),
                                     snapshot_every=100)
    state, epoch, _, _ = mem.MemoryJournal.recover(path, make_cfg().memory)
    assert epoch == crash_at
    assert_states_equal(state, ref_snapshots[crash_at])


def test_recovery_tolerates_torn_wal_tail(tmp_path):
    path = str(tmp_path / "journal")
    rar, _ = run_journaled(make_stream(), path, snapshot_every=100)
    with open(os.path.join(path, "wal.log"), "ab") as f:
        f.write(b"\x07\x00\x00\x00garbage-torn-frame")  # power-cut tail
    with pytest.warns(mem.JournalCorruptionWarning, match="crc mismatch"):
        state, epoch, _, _ = mem.MemoryJournal.recover(path,
                                                       rar.cfg.memory)
    assert_states_equal(state, rar.memory)
    assert epoch == rar.commit_stream.buffer.epoch


def test_wal_bit_flip_stops_replay_at_corrupt_frame(tmp_path):
    """Bit rot mid-file: replay keeps every epoch before the flipped
    frame, drops everything at and after it, and says where and why in
    a structured warning — never a raised exception, never a torn
    store."""
    path = str(tmp_path / "journal")
    rar, snapshots = run_journaled(make_stream(), path, snapshot_every=100)
    wal = os.path.join(path, "wal.log")
    with open(wal, "rb") as f:
        data = bytearray(f.read())
    data[12] ^= 0x40                 # payload byte of the FIRST frame
    with open(wal, "wb") as f:
        f.write(bytes(data))
    with pytest.warns(mem.JournalCorruptionWarning) as rec:
        state, epoch, applied, _ = mem.MemoryJournal.recover(
            path, rar.cfg.memory)
    w = next(r.message for r in rec
             if isinstance(r.message, mem.JournalCorruptionWarning))
    assert w.path == wal and w.offset == 0 and w.reason == "crc mismatch"
    assert epoch == 0 and applied == 0      # no snapshot: nothing survives
    assert_states_equal(state, snapshots[0])


def test_wal_truncated_frame_recovers_prefix_with_warning(tmp_path):
    """Cut the file mid-frame (lost sector): recovery is exact up to
    the last intact frame and warns with the torn frame's offset."""
    path = str(tmp_path / "journal")
    rar, snapshots = run_journaled(make_stream(), path, snapshot_every=100)
    wal = os.path.join(path, "wal.log")
    with open(wal, "rb") as f:
        data = f.read()
    with open(wal, "wb") as f:
        f.write(data[:len(data) - 3])       # 3 bytes short of a frame
    with pytest.warns(mem.JournalCorruptionWarning,
                      match="torn payload") as rec:
        state, epoch, _, _ = mem.MemoryJournal.recover(path,
                                                       rar.cfg.memory)
    w = next(r.message for r in rec
             if isinstance(r.message, mem.JournalCorruptionWarning))
    assert w.offset > 0
    assert epoch == rar.commit_stream.buffer.epoch - 1
    assert_states_equal(state, snapshots[epoch])


def test_recovered_store_resumes_serving(tmp_path):
    """E2E restart: a new controller opened on the journal path starts
    from the recovered store and serves memory hits immediately — and
    keeps journaling (a second recovery sees the new epochs)."""
    path = str(tmp_path / "journal")
    stream = make_stream()
    rar, _ = run_journaled(stream, path, snapshot_every=3)
    epoch0 = rar.commit_stream.buffer.epoch
    occupancy0 = rar.memory_occupancy
    holder = {}
    rar2 = RAR(FakeTier(known={0, 1}, name="weak"),
               FakeTier(known=range(10_000), can_guide=True,
                        name="strong"),
               lambda p: holder["emb"], lambda e, k: False,
               make_cfg(journal_path=path, snapshot_every=3))
    assert_states_equal(rar2.memory, rar.memory)
    assert rar2.commit_stream.buffer.epoch == epoch0
    holder["emb"] = skill_emb(stream[0][0])       # a learned skill
    out = rar2.process(prompt(stream[0][0], 7), greq(stream[0][0]),
                       key=None)
    assert out.strong_calls == 0                  # memory hit, no relearn
    assert rar2.memory_occupancy == occupancy0
    # learn one new skill → new journal epoch → recoverable
    holder["emb"] = skill_emb(40)
    rar2.process(prompt(40, 1), greq(40), key=None)
    _, epoch2, _, _ = mem.MemoryJournal.recover(path, rar2.cfg.memory)
    assert epoch2 == rar2.commit_stream.buffer.epoch > epoch0


def test_sequential_manifest_restores_engine_state(tmp_path):
    """The WAL carries the controller's engine-state manifest inside
    every epoch frame (plus a manifest-only checkpoint frame at clean
    shutdown): reopening the journal path restores the logical clock
    and routing counters exactly, not just the store bytes."""
    path = str(tmp_path / "journal")
    stream = make_stream()
    rar, _ = run_journaled(stream, path, snapshot_every=3,
                           breaker_threshold=2)
    rar.close_shadow()                        # checkpoint frame
    holder = {}
    rar2 = RAR(FakeTier(known={0, 1}, name="weak"),
               FakeTier(known=range(10_000), can_guide=True,
                        name="strong"),
               lambda p: holder["emb"], lambda e, k: False,
               make_cfg(journal_path=path, snapshot_every=3,
                        breaker_threshold=2))
    assert rar2.now == rar.now
    assert rar2.guides_from_memory == rar.guides_from_memory
    assert rar2.guides_generated == rar.guides_generated
    assert rar2.probes_deferred == rar.probes_deferred
    assert rar2.strong.breaker.state == rar.strong.breaker.state
    # the clock resumes, it does not restart: the next request gets a
    # fresh stamp strictly after every recovered insertion
    holder["emb"] = skill_emb(stream[0][0])
    rar2.process(prompt(stream[0][0], 5), greq(stream[0][0]), key=None)
    assert rar2.now == rar.now + 1


def test_fabric_with_journal_recovers_across_restart(tmp_path):
    """The batched/replicated path journals through the shared commit
    stream: kill a fabric mid-run, rebuild on the same path, and the
    recovered store carries every committed epoch."""
    path = str(tmp_path / "journal")
    fab = build_fabric(2, weak_known={0, 1}, journal_path=path,
                       snapshot_every=2)
    serve_serialized(fab, make_stream(), 4)
    fab.close_shadow()
    ref_state = fab.memory
    ref_epoch = fab.commit_stream.buffer.epoch
    fab2 = build_fabric(2, weak_known={0, 1}, journal_path=path,
                        snapshot_every=2)
    assert_states_equal(fab2.memory, ref_state)
    assert fab2.commit_stream.buffer.epoch == ref_epoch
    out = fab2.process_batch([prompt(0, 7)], [greq(0)],
                             embs=skill_emb(0)[None])[0]
    assert out.strong_calls == 0                  # recovered store serves
    fab2.close_shadow()


# ---------------------------------------------------------------------------
# Soak: random crash/recover schedule (smoke-sized; CI runs it seeded)
# ---------------------------------------------------------------------------


def test_soak_random_fault_schedule():
    """A seeded random schedule of replica crashes + strong-tier errors
    over a threaded 3-replica fabric: every request resolves exactly
    once, the store stays consistent, and all faults fire."""
    plan = random_plan(int(os.environ.get("REPRO_SOAK_SEED", "0")),
                       replicas=3, crashes=3, tier_errors=2, horizon=30)
    fab = build_fabric(3, weak_known={0, 1}, fault_plan=plan,
                       tier_max_retries=1, breaker_threshold=2,
                       breaker_cooldown=0.05)
    rng = np.random.default_rng(0)
    tickets, n = [], 0
    for _ in range(40):
        B = int(rng.integers(1, 4))
        chunk = [(int(rng.integers(0, 10)), int(rng.integers(0, 8)))
                 for _ in range(B)]
        n += B
        tickets.append(fab.submit(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            embs=np.stack([skill_emb(s) for s, _ in chunk])))
    time.sleep(0.08)
    fab.flush_shadow(timeout=120)
    outs = [o for t in tickets for o in t.wait(timeout=60)]
    assert len(outs) == n                          # nothing lost/duplicated
    assert all(o.response is not None for o in outs)
    assert fab.restarts == fab.deaths              # every death restarted
    assert fab.commit_stream.buffer.entries_applied == \
        int(np.asarray(fab.memory.ptr))
    stats = fab.stats()
    assert stats["items_enqueued"] == stats["items_drained"]
    assert plan.n_fired > 0
    fab.close_shadow()
