"""Serving engine: greedy decode correctness vs. repeated teacher forcing,
jit cache behaviour, call accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import forward, init_params
from repro.serving.engine import ServingEngine, greedy_generate


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def greedy_reference(cfg, params, tokens, max_new):
    """Greedy decode via repeated full forward passes (no cache)."""
    cur = tokens
    out = []
    for _ in range(max_new):
        batch = {"tokens": cur, "labels": jnp.zeros_like(cur)}
        logits, _ = forward(cfg, params, batch)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        out.append(nxt)
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
    return jnp.stack(out, axis=1)


def test_greedy_generate_matches_reference(small_model, rng):
    cfg, params = small_model
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 12)), jnp.int32)
    got = greedy_generate(cfg, params, {"tokens": tokens}, max_new=5)
    want = greedy_reference(cfg, params, tokens, 5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_jit_cache_and_accounting(small_model, rng):
    cfg, params = small_model
    engine = ServingEngine(cfg, params)
    t1 = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 8)), jnp.int32)
    engine.generate({"tokens": t1}, max_new=2)
    assert engine.calls == 4
    engine.generate({"tokens": t1}, max_new=2)
    assert engine.calls == 8
    assert len(engine._jitted) == 1            # same shape → cached
    t2 = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 16)), jnp.int32)
    engine.generate({"tokens": t2}, max_new=2)
    assert len(engine._jitted) == 2
    assert engine.flops_spent > 0


def test_generate_bucketed_matches_per_prompt(small_model, rng):
    """Mixed-length prompts through the bucketed path: same outputs as
    one-by-one serving, call accounting counts real rows only, and jit
    entries are shared across repeated mixed-length traffic."""
    cfg, params = small_model
    engine = ServingEngine(cfg, params)
    prompts = [np.asarray(rng.integers(1, cfg.vocab_size, L), np.int32)
               for L in (8, 12, 8, 12, 12, 9)]
    got = engine.generate_bucketed(prompts, max_new=3)
    assert engine.calls == len(prompts)
    for p, row in zip(prompts, got):
        one = np.asarray(engine.generate(
            {"tokens": jnp.asarray(p[None])}, max_new=3))[0]
        np.testing.assert_array_equal(row, one)
    # a second mixed batch with the same lengths but different group sizes
    # must not add compile entries beyond the (bucket, length) grid
    n_entries = len(engine._jitted)
    more = [np.asarray(rng.integers(1, cfg.vocab_size, L), np.int32)
            for L in (8, 8, 12, 12, 12, 9)]
    engine.generate_bucketed(more, max_new=3)
    assert len(engine._jitted) == n_entries       # all buckets reused


def test_ssm_generate_runs(rng):
    """State-carrying family through the same engine API."""
    cfg = configs.get_smoke("mamba2-2.7b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 16)), jnp.int32)
    out = greedy_generate(cfg, params, {"tokens": tokens}, max_new=4)
    assert out.shape == (2, 4)
    ref = greedy_reference(cfg, params, tokens, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_hybrid_generate_runs(rng):
    cfg = configs.get_smoke("recurrentgemma-2b")
    params = init_params(cfg, jax.random.PRNGKey(3))
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 16)), jnp.int32)
    out = greedy_generate(cfg, params, {"tokens": tokens}, max_new=4)
    ref = greedy_reference(cfg, params, tokens, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
