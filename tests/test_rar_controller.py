"""RAR controller state-machine tests with deterministic rule-based FM
tiers (no neural nets): Cases 1/2/3, strong-call accounting, memory-hit
routing, re-probe cool-down, and cost-reduction-over-stages properties."""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import memory as mem
from repro.core.rar import RAR, RARConfig
from repro.data import tokenizer as tk

EMBED_DIM = 16
GUIDE_LEN = 8


def make_cfg(**kw):
    base = dict(sim_threshold=0.9, guide_sim_threshold=0.9,
                reprobe_period=100,
                memory=mem.MemoryConfig(capacity=64, embed_dim=EMBED_DIM,
                                        guide_len=GUIDE_LEN))
    base.update(kw)
    return RARConfig(**base)


class FakeTier:
    """Deterministic FM stand-in.

    Question prompts are [skill_id, x, ANS-marker...]-style arrays; the
    correct answer is (skill + x) % 4. ``known`` = skills answered unaided.
    A guided prompt (GUIDE_START present) is answered correctly iff the
    guide hint encodes the right skill. Guide generation emits the skill
    hint iff ``can_guide``."""

    def __init__(self, known=(), can_guide=False, name="fake"):
        self.known = set(known)
        self.can_guide = can_guide
        self.name = name
        self.engine = type("E", (), {"calls": 0})()

    def answer_batch(self, prompts):
        out = []
        for p in prompts:
            self.engine.calls += 1
            p = list(p)
            if len(p) == 6:                      # [BOS, GS, hint, GE, s, x]
                hint, skill, x = p[2], p[4], p[5]
                out.append((skill + x) % 4 if hint == skill + 100 else -1)
            else:                                # [BOS, s, x]
                skill, x = p[1], p[2]
                out.append((skill + x) % 4 if skill in self.known else -1)
        return np.asarray(out)

    def generate_guides(self, requests, guide_len):
        self.engine.calls += len(requests)
        g = np.zeros((len(requests), guide_len), np.int32)
        g[:, 0] = tk.GUIDE_START
        for i, r in enumerate(requests):
            g[i, 1] = r[1] + 100 if self.can_guide else 99999
        g[:, 2] = tk.GUIDE_END
        return g


def prompt(skill, x):
    # [pad-slot, skill, x]; pad-slot plays the BOS role for _guided()
    return np.asarray([tk.BOS, skill, x], np.int32)


def greq(skill):
    return np.asarray([tk.GUIDE_REQ, skill], np.int32)


def skill_emb(skill):
    rng = np.random.default_rng(skill)
    v = rng.normal(size=EMBED_DIM)
    return (v / np.linalg.norm(v)).astype(np.float32)


def make_rar(weak_known=(), weak_follows_guides=True, **cfg_kw):
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    if not weak_follows_guides:
        # weak ignores hints entirely
        weak.answer_batch = lambda prompts: np.asarray([-1] * len(prompts))
    holder = {}

    def embed_fn(p):
        return holder["emb"]

    rar = RAR(weak, strong, embed_fn, lambda e, k: False, make_cfg(**cfg_kw))
    return rar, holder


def process(rar, holder, skill, x):
    holder["emb"] = skill_emb(skill)
    return rar.process(prompt(skill, x), greq(skill), key=(skill, x))


# ---------------------------------------------------------------------------


def test_case1_stores_skill_then_routes_weak():
    rar, h = make_rar(weak_known={7})
    out = process(rar, h, 7, 1)
    assert out.case == "case1" and out.strong_calls == 1
    assert out.served_by == "strong"            # user got the strong answer
    # same skill again → memory hit → weak serves, zero strong calls
    out2 = process(rar, h, 7, 2)
    assert out2.case == "memory_skill" and out2.strong_calls == 0
    assert out2.served_by == "weak"
    assert out2.response == (7 + 2) % 4         # weak is actually correct


def test_case2_guide_generated_then_reused():
    rar, h = make_rar(weak_known=set())          # weak knows nothing unaided
    out = process(rar, h, 3, 1)
    assert out.case == "case2" and out.guide_source == "fresh"
    assert out.strong_calls == 2                 # response + guide gen
    out2 = process(rar, h, 3, 2)
    assert out2.case == "memory_guide" and out2.strong_calls == 0
    assert out2.response == (3 + 2) % 4          # guided weak is correct


def test_case3_hard_entry_shortcircuits():
    rar, h = make_rar(weak_known=set(), weak_follows_guides=False)
    out = process(rar, h, 5, 1)
    assert out.case == "case3" and out.strong_calls == 2
    out2 = process(rar, h, 5, 2)
    assert out2.case == "memory_hard" and out2.strong_calls == 1
    assert out2.served_by == "strong"


def test_case3_reprobe_after_cooldown():
    rar, h = make_rar(weak_known=set(), weak_follows_guides=False,
                      reprobe_period=2)
    process(rar, h, 5, 1)                        # case3 at now=1
    out = process(rar, h, 5, 2)                  # now=2, age 1 < 2 → hard
    assert out.case == "memory_hard"
    # age reaches the period → shadow re-runs (still fails → case3 path)
    out = process(rar, h, 5, 3)
    assert out.case == "case3"


def test_reprobe_clears_hard_flag_when_weak_learns():
    """Weak 'evolves' between probes (the paper's motivating scenario:
    weaker FMs improve over time) — the hard flag must clear."""
    rar, h = make_rar(weak_known=set(), weak_follows_guides=False,
                      reprobe_period=2)
    process(rar, h, 5, 1)                        # case3
    rar.weak = FakeTier(known={5}, name="weak-evolved")   # evolution
    process(rar, h, 5, 2)                        # memory_hard (cooldown)
    out = process(rar, h, 5, 3)                  # re-probe → case1
    assert out.case == "case1_reprobe"
    out = process(rar, h, 5, 4)
    assert out.case in ("memory_skill",)         # now routed weak
    assert out.strong_calls == 0


def test_router_weak_passthrough():
    rar, h = make_rar(weak_known={1})
    rar.route_weak_fn = lambda e, k: True
    out = process(rar, h, 1, 0)
    assert out.case == "router_weak" and out.strong_calls == 0


def test_dissimilar_skills_do_not_collide():
    rar, h = make_rar(weak_known={7})
    process(rar, h, 7, 1)                        # case1 for skill 7
    out = process(rar, h, 8, 1)                  # different skill embedding
    assert out.case in ("case1", "case2", "case3")   # no memory hit


def test_allow_fresh_guides_false_blocks_generation():
    rar, h = make_rar(weak_known=set(),
                      allow_fresh_guides=False)
    out = process(rar, h, 3, 1)
    assert out.case == "case3"                   # no guide available → hard
    assert out.strong_calls == 1                 # and no guide-gen call


# ---------------------------------------------------------------------------
# System-level properties
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=5, max_size=30),
       st.integers(0, 1000))
def test_property_strong_calls_nonincreasing_over_stages(skills, salt):
    """For any static request stream, RAR's per-stage strong-FM calls never
    increase between the first and later stages (the paper's core claim —
    the system only accumulates capability)."""
    rar, h = make_rar(weak_known={0, 1})
    stream = [(s, (s * 7 + salt) % 97) for s in skills]
    per_stage = []
    for _ in range(3):
        calls = 0
        for s, x in stream:
            calls += process(rar, h, s, x).strong_calls
        per_stage.append(calls)
    assert per_stage[1] <= per_stage[0]
    assert per_stage[2] <= per_stage[0]


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=5, max_size=30))
def test_property_responses_match_strong_when_guides_work(skills):
    """With a guide-following weak FM and a competent strong FM, every
    served response equals the strong FM's answer (quality preserved)."""
    rar, h = make_rar(weak_known=set())
    for s in skills:
        out = process(rar, h, s, s % 5)
        assert out.response == (s + s % 5) % 4
