"""End-to-end behaviour of the trained RAR system (the paper's claims, at
test scale). Uses the shared cached system from ``build_system`` — the
first run trains it (~10 min on this CPU), later runs load the checkpoint.
"""
import numpy as np
import pytest

from repro.core.rar import RARConfig
from repro.experiments.setup import build_system, failing_pool
from repro.experiments.stages import run_baselines, run_rar_experiment

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def system():
    return build_system(verbose=False)


@pytest.fixture(scope="module")
def pool(system):
    return failing_pool(system, domain=0, n=120)


@pytest.fixture(scope="module")
def rar_run(system, pool):
    results, rar = run_rar_experiment(system, pool, n_stages=3, seed=0)
    return results, rar


def test_trained_capability_structure(system):
    """Weak fails unknown skills / strong solves everything / guides lift
    the weak FM — the premise the paper's method needs."""
    suite = system.suite
    rng = np.random.default_rng(0)

    def acc(tier, skills, guided=False, n=80):
        prompts, truth = [], []
        for _ in range(n):
            s = int(rng.choice(skills))
            d, x = suite.domain_of(s), int(rng.integers(0, 100))
            g = suite.guide(s) if guided else None
            prompts.append(np.asarray(suite.vocab.question(d, s, x, g),
                                      np.int32))
            truth.append(suite.answer(s, x))
        ans = tier.answer_batch(np.stack(prompts))
        return float((ans == np.asarray(truth)).mean())

    all_sk = np.arange(suite.cfg.total_skills)
    unknown = np.setdiff1d(all_sk, suite.weak_known)
    assert acc(system.strong, all_sk) > 0.9
    assert acc(system.weak, suite.weak_known) > 0.75
    assert acc(system.weak, unknown) < 0.55
    assert acc(system.weak, unknown, guided=True) > \
        acc(system.weak, unknown) + 0.25


def test_rar_reduces_strong_calls_over_stages(rar_run, pool):
    results, _ = rar_run
    first, last = results[0], results[-1]
    assert last.strong_calls < 0.6 * first.strong_calls, \
        [r.strong_calls for r in results]
    # late stages serve most requests without ANY strong call
    assert last.strong_calls < 0.6 * len(pool)


def test_rar_quality_maintained(rar_run, pool):
    results, _ = rar_run
    total = sum(r.aligned for r in results)
    n = 3 * len(pool)
    assert total / n > 0.75, total / n


def test_rar_beats_weak_baselines(system, pool, rar_run):
    base = run_baselines(system, pool, n_stages=3)
    results, _ = rar_run
    rar_aligned = sum(r.aligned for r in results)
    weak_aligned = sum(r.aligned for r in base["weak"])
    cot_aligned = sum(r.aligned for r in base["weak_cot"])
    assert rar_aligned > weak_aligned
    assert rar_aligned > cot_aligned
    # and saves vs the oracle router on cumulative strong calls
    rar_strong = sum(r.strong_calls for r in results)
    oracle_strong = sum(r.strong_calls for r in base["oracle_router"])
    assert rar_strong < oracle_strong


def test_guide_memory_populates(rar_run):
    _, rar = rar_run
    assert rar.memory.debug_size() > 0
    assert rar.memory.size_fast == rar.memory.debug_size()
    assert bool(np.asarray(rar.memory.has_guide)[
        np.asarray(rar.memory.valid)].any())


def test_microbatched_experiment_preserves_claims(system, pool, rar_run):
    """The batched data plane keeps the paper's properties on the trained
    system: strong calls still collapse across stages, and quality stays
    close to the sequential controller."""
    results_mb, rar = run_rar_experiment(system, pool, n_stages=3, seed=0,
                                         microbatch=16)
    first, last = results_mb[0], results_mb[-1]
    assert last.strong_calls < 0.6 * first.strong_calls, \
        [r.strong_calls for r in results_mb]
    results_seq, _ = rar_run
    n = 3 * len(pool)
    mb_quality = sum(r.aligned for r in results_mb) / n
    seq_quality = sum(r.aligned for r in results_seq) / n
    assert mb_quality > seq_quality - 0.1, (mb_quality, seq_quality)
    assert rar.memory.size_fast > 0


def test_async_shadow_experiment_preserves_claims(system, pool):
    """Shadow plane fully decoupled on the trained system (background
    drainer thread, drains every 4 batches): the paper's properties
    survive the staleness window, per-stage tallies are exact (stage-end
    flush barriers resolve every provisional outcome), and the
    transfer-free occupancy counter agrees with the device store."""
    results, rar = run_rar_experiment(system, pool, n_stages=3, seed=0,
                                      microbatch=16, shadow_mode="async",
                                      shadow_flush_every=4)
    rar.close_shadow()
    first, last = results[0], results[-1]
    # deferring drains can only delay learning by a few batches; the
    # cross-stage collapse in strong calls must survive
    assert last.strong_calls < 0.7 * first.strong_calls, \
        [r.strong_calls for r in results]
    n = 3 * len(pool)
    assert sum(r.aligned for r in results) / n > 0.7
    assert rar.memory_occupancy == rar.memory.size_fast > 0
