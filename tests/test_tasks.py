"""Synthetic task suite invariants (hypothesis-driven) + tokenizer checks."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.data import tokenizer as tk
from repro.data.tasks import TaskSuite, TaskSuiteConfig

SUITE = TaskSuite(TaskSuiteConfig())
N = SUITE.cfg.total_skills


def test_vocab_block_layout():
    v = SUITE.vocab
    assert v.domain_0 == 22
    assert v.skill_0 == 22 + 3
    assert v.size % 64 == 0
    assert v.h_beta_0 + 4 <= v.size


@settings(max_examples=50, deadline=None)
@given(st.integers(0, N - 1), st.integers(0, 39))
def test_answer_is_affine_rule(s, x):
    a = SUITE.answer(s, x)
    assert 0 <= a < 4
    assert a == (SUITE.alpha[s] * (x % 4) + SUITE.beta[s]) % 4


@settings(max_examples=50, deadline=None)
@given(st.integers(0, N - 1))
def test_guide_encodes_rule_not_answer(s):
    """Guides carry (α, β) hint tokens and no answer-option token —
    §III-E: 'instructions that do not contain the actual answer'."""
    g = SUITE.guide(s)
    v = SUITE.vocab
    assert g[0] == tk.GUIDE_START and g[-1] == tk.GUIDE_END
    assert g[1] == v.h_alpha_0 + SUITE.alpha[s]
    assert g[2] == v.h_beta_0 + SUITE.beta[s]
    for t in g:
        assert not (tk.OPTION_A <= t < tk.OPTION_A + 4)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, N - 1), st.integers(0, 39), st.booleans())
def test_encode_shapes_and_supervision(s, x, guided):
    d = SUITE.domain_of(s)
    g = SUITE.guide(s) if guided else None
    toks, labs = SUITE.encode(d, s, x, guide=g)
    L = SUITE.cfg.seq_len
    assert toks.shape == (L,) and labs.shape == (L,)
    # exactly two supervised positions: the answer and EOS
    assert int(np.sum(labs >= 0)) == 2
    ans_pos = int(np.where(labs >= 0)[0][0])
    assert labs[ans_pos] == SUITE.vocab.answer_token(SUITE.answer(s, x))
    assert toks[ans_pos] == tk.ANS


def test_same_skill_shares_guide_different_questions():
    """The generalization premise: one guide serves every question of its
    skill (the paper's intra-domain reuse)."""
    s = int(SUITE.domain_skills[0][0])
    assert SUITE.guide(s) == SUITE.guide(s)
    xs = [1, 2, 3]
    answers = {SUITE.answer(s, x) for x in xs}
    assert len(answers) >= 2     # rule is x-dependent (α ≥ 1)


def test_domains_share_only_shared_block():
    s0 = set(SUITE.domain_skills[0].tolist())
    s1 = set(SUITE.domain_skills[1].tolist())
    inter = s0 & s1
    assert len(inter) == SUITE.cfg.shared_skills


def test_weak_known_is_quarter():
    frac = len(SUITE.weak_known) / SUITE.cfg.total_skills
    assert 0.15 < frac < 0.35


def test_question_pool_distinct():
    pool = SUITE.question_pool(0, 200, seed=7)
    assert len(set((s, x) for _, s, x in pool)) == 200
    for d, s, x in pool:
        assert s in SUITE.domain_skills[0]


def test_guide_train_disjoint_from_known():
    assert not set(SUITE.guide_train_skills) & set(SUITE.weak_known)
