"""Microbatched controller: exact batch-of-1 equivalence with the
sequential ``RAR.process`` (Outcome stream, memory state, FM-call counts),
batched-mode behaviour at B > 1, the PR-2 regression pin (retrieval_k=1
byte-identical to the top-1 read path), and multi-guide serving over the
top-k read."""
import jax.numpy as jnp
import numpy as np
import pytest
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb

from repro.core import memory as mem
from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import RAR, splice_guides

MEM_FIELDS = ("emb", "guide", "has_guide", "hard", "valid", "added_at",
              "ptr")


def make_stream(n_skills=6, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    stream = []
    for _ in range(reps):
        for s in rng.permutation(n_skills):
            stream.append((int(s), int(rng.integers(0, 8))))
    return stream


def build(cls, weak_known=(), weak_follows_guides=True, **cfg_kw):
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    if not weak_follows_guides:
        calls = weak.engine

        def stubborn(prompts):
            calls.calls += len(prompts)
            return np.asarray([-1] * len(prompts))
        weak.answer_batch = stubborn
    holder = {}
    ctrl = cls(weak, strong, lambda p: holder["emb"], lambda e, k: False,
               make_cfg(**cfg_kw))
    return ctrl, holder


def run_sequential(stream, **kw):
    rar, holder = build(RAR, **kw)
    outs = []
    for s, x in stream:
        holder["emb"] = skill_emb(s)
        outs.append(rar.process(prompt(s, x), greq(s), key=(s, x)))
    return rar, outs


def run_batched(stream, batch, **kw):
    ctrl, _ = build(MicrobatchRAR, **kw)
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        outs += ctrl.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
    return ctrl, outs


SCENARIOS = [
    dict(weak_known={0, 1}),                        # case1 + guide paths
    dict(weak_known=set()),                         # all guide-driven
    dict(weak_known=set(), weak_follows_guides=False,
         reprobe_period=4),                         # case3 + re-probe
    dict(weak_known={0, 1, 2}, reprobe_period=3, allow_fresh_guides=False),
    # top-k retrieval: B=1 equivalence must hold on the widened read too
    dict(weak_known={0, 1}, retrieval_k=4, max_guides=2),
    dict(weak_known=set(), retrieval_k=8, max_guides=8, reprobe_period=4),
]


@pytest.mark.parametrize("kw", SCENARIOS)
def test_batch1_identical_to_sequential(kw):
    stream = make_stream()
    seq, seq_outs = run_sequential(stream, **kw)
    bat, bat_outs = run_batched(stream, 1, **kw)
    assert bat_outs == seq_outs                     # full Outcome stream
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(seq.memory, f)),
            np.asarray(getattr(bat.memory, f)), f)
    assert bat.now == seq.now
    assert bat.weak.engine.calls == seq.weak.engine.calls
    assert bat.strong.engine.calls == seq.strong.engine.calls
    assert bat.guides_from_memory == seq.guides_from_memory
    assert bat.guides_generated == seq.guides_generated


# ---------------------------------------------------------------------------
# PR-2 regression pin: retrieval_k=1 / max_guides=1 must be byte-identical
# to the top-1 read path
# ---------------------------------------------------------------------------


class _Top1RAR(RAR):
    """Sequential comparator whose memory reads take the PR-2 top-1 path
    (``mem.query``), re-shaped to the k=1 TopKResult contract."""

    def _lookup(self, emb, guides_only=False):
        q = mem.query(self.memory, emb,
                      guides_only=guides_only).device_get()
        return mem.TopKResult(sim=np.asarray(q.sim)[None],
                              meta=np.asarray(q.meta)[None])


class _Top1MicrobatchRAR(MicrobatchRAR):
    """Batched comparator on the PR-2 top-1 batch read
    (``mem.query_batch``)."""

    def _lookup_batch(self, embs, guides_only=False):
        q = mem.query_batch(self.memory, jnp.asarray(embs),
                            guides_only=guides_only).device_get()
        return mem.TopKResult(sim=np.asarray(q.sim)[:, None],
                              meta=np.asarray(q.meta)[:, None])


@pytest.mark.parametrize("kw", SCENARIOS[:4])
@pytest.mark.parametrize("batch", [1, 4])
def test_retrieval_k1_byte_identical_to_top1_path(kw, batch):
    """With the default retrieval_k=1 / max_guides=1 the controller must
    reproduce the PR-2 top-1 data plane byte for byte: same Outcome
    stream, same memory state, same FM-call counts — single-request and
    microbatched. (The comparators' reads literally call the PR-2
    ``query``/``query_batch`` dispatch.)"""
    stream = make_stream()
    if batch == 1:
        new, new_outs = run_sequential(stream, **kw)
    else:
        new, new_outs = run_batched(stream, batch, **kw)
    old_cls = _Top1RAR if batch == 1 else _Top1MicrobatchRAR
    old, holder = build(old_cls, **kw)
    old_outs = []
    if batch == 1:
        for s, x in stream:
            holder["emb"] = skill_emb(s)
            old_outs.append(old.process(prompt(s, x), greq(s), key=(s, x)))
    else:
        for start in range(0, len(stream), batch):
            chunk = stream[start:start + batch]
            old_outs += old.process_batch(
                [prompt(s, x) for s, x in chunk],
                [greq(s) for s, _ in chunk], keys=chunk,
                embs=np.stack([skill_emb(s) for s, _ in chunk]))
    assert new_outs == old_outs
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(new.memory, f)),
            np.asarray(getattr(old.memory, f)), f)
    assert new.weak.engine.calls == old.weak.engine.calls
    assert new.strong.engine.calls == old.strong.engine.calls
    assert new.guides_from_memory == old.guides_from_memory
    assert new.guides_generated == old.guides_generated


def test_query_topk_k1_pins_query_on_dispatch_path(rng):
    """query_topk(k=1) ≡ query, asserted at the controller's own store
    after a real serving run (not just on synthetic stores)."""
    ctrl, _ = build(MicrobatchRAR, weak_known={0, 1})
    stream = make_stream(n_skills=5, reps=2)
    ctrl.process_batch([prompt(s, x) for s, x in stream],
                       [greq(s) for s, _ in stream],
                       embs=np.stack([skill_emb(s) for s, _ in stream]))
    qs = np.stack([skill_emb(s) for s in range(5)])
    for guides_only in (False, True):
        a = mem.query_batch(ctrl.memory, jnp.asarray(qs),
                            guides_only=guides_only).device_get()
        b = mem.query_topk_batch(ctrl.memory, jnp.asarray(qs), 1,
                                 guides_only=guides_only).device_get()
        np.testing.assert_array_equal(a.sim, b.sim[:, 0])
        np.testing.assert_array_equal(a.meta, b.meta[:, 0])


def test_batched_mode_learns_and_matches_cost_profile():
    """At B=8 the controller still learns (second pass over the stream is
    mostly memory hits) and total strong calls stay close to sequential."""
    stream = make_stream(n_skills=8, reps=1, seed=3)
    kw = dict(weak_known={0, 1, 2})
    pass1 = stream
    pass2 = make_stream(n_skills=8, reps=1, seed=4)

    seq, seq_outs = run_sequential(pass1 + pass2, **kw)
    bat, bat_outs = run_batched(pass1 + pass2, 8, **kw)

    seq_strong = sum(o.strong_calls for o in seq_outs)
    bat_strong = sum(o.strong_calls for o in bat_outs)
    # same skills learned → identical steady state; transient duplicates
    # inside one microbatch may add a few extra shadow passes
    assert bat_strong >= seq_strong
    assert bat_strong <= seq_strong + 2 * 8
    # second pass: every skill is in memory → no strong calls at all for
    # guide-able skills in either mode
    second = bat_outs[len(pass1):]
    assert all(o.case in ("memory_skill", "memory_guide") for o in second)
    assert all(o.strong_calls == 0 for o in second)
    # responses match the sequential stream on the second pass
    assert [o.response for o in second] == \
        [o.response for o in seq_outs[len(pass1):]]


def test_batched_reprobe_clears_hard_flag():
    """The re-probe path (hard entry past cool-down) works batched: after
    the weak FM 'evolves', the hard flag clears and routing goes weak."""
    kw = dict(weak_known=set(), weak_follows_guides=False, reprobe_period=2)
    ctrl, _ = build(MicrobatchRAR, **kw)
    embs = skill_emb(5)[None]
    out = ctrl.process_batch([prompt(5, 1)], [greq(5)], embs=embs)[0]
    assert out.case == "case3"
    ctrl.weak = FakeTier(known={5}, name="weak-evolved")
    out = ctrl.process_batch([prompt(5, 2)], [greq(5)], embs=embs)[0]
    assert out.case == "memory_hard"
    out = ctrl.process_batch([prompt(5, 3)], [greq(5)], embs=embs)[0]
    assert out.case == "case1_reprobe"
    out = ctrl.process_batch([prompt(5, 4)], [greq(5)], embs=embs)[0]
    assert out.case == "memory_skill" and out.strong_calls == 0


def test_commit_eviction_does_not_corrupt_flag_updates():
    """Full ring: when the microbatch's FIFO scatter evicts the very slot
    a re-probe wanted to mark soft, the flag update must be dropped — not
    applied to the unrelated entry that now occupies the slot."""
    from repro.core import memory as mem

    kw = dict(weak_known=set(), reprobe_period=3,
              memory=mem.MemoryConfig(capacity=2, embed_dim=16,
                                      guide_len=8))
    ctrl, _ = build(MicrobatchRAR, **kw)
    ctrl.strong.can_guide = False          # guides never help → case3
    for s, now in ((5, 1), (6, 2)):        # two hard entries fill the ring
        out = ctrl.process_batch([prompt(s, 0)], [greq(s)],
                                 embs=skill_emb(s)[None])[0]
        assert out.case == "case3"
    ctrl.weak = FakeTier(known={5}, name="weak-evolved")
    # one microbatch: skill 7 records a fresh hard entry on slot 0 while
    # skill 5's successful re-probe targets (old) slot 0 for mark_soft
    outs = ctrl.process_batch(
        [prompt(7, 0), prompt(5, 1)], [greq(7), greq(5)],
        embs=np.stack([skill_emb(7), skill_emb(5)]))
    assert [o.case for o in outs] == ["case3", "case1_reprobe"]
    # skill 7's entry keeps its hard flag → next hit short-circuits strong
    out = ctrl.process_batch([prompt(7, 1)], [greq(7)],
                             embs=skill_emb(7)[None])[0]
    assert out.case == "memory_hard"
    # and skill 5 routes weak off its re-probed bare-skill entry
    out = ctrl.process_batch([prompt(5, 2)], [greq(5)],
                             embs=skill_emb(5)[None])[0]
    assert out.case == "memory_skill"


# ---------------------------------------------------------------------------
# Multi-guide serving (retrieval_k > 1)
# ---------------------------------------------------------------------------

from repro.core.rar import select_guides  # noqa: E402
from repro.data import tokenizer as tk    # noqa: E402


class MultiGuideWeak:
    """Weak tier that understands several spliced guide blocks: answers
    correctly iff ANY guide hint encodes the right skill."""

    def __init__(self):
        self.engine = type("E", (), {"calls": 0})()

    def answer_batch(self, prompts):
        out = []
        for p in prompts:
            self.engine.calls += 1
            p = list(p)
            skill, x = p[-2], p[-1]
            hints = [p[i + 1] for i, t in enumerate(p[:-2])
                     if t == tk.GUIDE_START]
            out.append((skill + x) % 4
                       if any(h == skill + 100 for h in hints) else -1)
        return np.asarray(out)


def _guide(hint):
    g = np.zeros(8, np.int32)
    g[0], g[1], g[2] = tk.GUIDE_START, hint, tk.GUIDE_END
    return g


def test_splice_guides_format_and_order():
    """Multiple guide blocks land after BOS best-first, PAD-stripped; one
    guide reproduces the single-guide format exactly."""
    p = prompt(3, 1)
    gA, gB = _guide(700), _guide(800)
    spliced = splice_guides(p, [gA, gB])
    assert list(spliced) == [tk.BOS,
                             tk.GUIDE_START, 700, tk.GUIDE_END,
                             tk.GUIDE_START, 800, tk.GUIDE_END, 3, 1]
    from repro.core.rar import splice_guide
    np.testing.assert_array_equal(splice_guides(p, [gA]),
                                  splice_guide(p, gA))


def test_select_guides_threshold_and_cap():
    sims = np.asarray([0.99, 0.95, 0.7, 0.5])
    has_guide = np.asarray([True, False, True, True])
    guides = np.stack([_guide(h) for h in (1, 2, 3, 4)])
    picked = select_guides(sims, has_guide, guides, 0.6, 4)
    # entry 1 (no guide) and entry 3 (below threshold) are skipped
    assert [g[1] for g in picked] == [1, 3]
    assert [g[1] for g in select_guides(sims, has_guide, guides,
                                        0.6, 1)] == [1]
    # a zero cap means zero guides, not "all of them"
    assert select_guides(sims, has_guide, guides, 0.6, 0) == []


def test_select_guides_dedups_near_duplicate_blocks():
    """The k retrieved guides can all come from one hot skill: identical
    (PAD-stripped) guide blocks are spliced once, the best-ranked copy
    wins, a duplicate never consumes a max_guides slot, and order stays
    deterministic (retrieval order minus repeats)."""
    sims = np.asarray([0.99, 0.95, 0.9, 0.85])
    has_guide = np.asarray([True, True, True, True])
    dup = _guide(7)
    dup_padded = dup.copy()                 # same content, via PAD tail
    guides = np.stack([dup, dup_padded, _guide(8), dup])
    picked = select_guides(sims, has_guide, guides, 0.6, 2)
    # entry 1 (duplicate of 0) is skipped WITHOUT consuming a slot, so
    # the distinct entry 2 still makes the cap of 2
    assert [g[1] for g in picked] == [7, 8]
    # all-duplicates collapse to one spliced block
    all_dup = np.stack([dup, dup, dup])
    assert len(select_guides(np.asarray([0.9, 0.9, 0.9]),
                             np.asarray([True] * 3), all_dup, 0.6, 3)) == 1


def test_memory_guide_hit_splices_duplicates_once():
    """End to end: a store holding two entries with the same guide block
    serves the weak FM with ONE spliced copy (shorter prompt, same
    answer) under retrieval_k=2."""
    skill = 3
    weak = MultiGuideWeak()
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    cfg = make_cfg(sim_threshold=0.9, retrieval_k=2, max_guides=2)
    ctrl = MicrobatchRAR(weak, strong, lambda p: skill_emb(skill),
                         lambda e, k: False, cfg)
    g = _guide(skill + 100)
    for now in (1, 2):                      # two same-guide entries
        ctrl.memory = mem.add(ctrl.memory, jnp.asarray(skill_emb(skill)),
                              jnp.asarray(g), jnp.asarray(True),
                              jnp.asarray(False), jnp.int32(now))
    out = ctrl.process_batch([prompt(skill, 1)], [greq(skill)],
                             embs=skill_emb(skill)[None])[0]
    assert out.case == "memory_guide" and out.strong_calls == 0
    assert out.response == (skill + 1) % 4
    # the weak FM saw exactly one guide block: its prompt had one hint
    # (MultiGuideWeak counts GUIDE_START markers — two identical hints
    # would still answer, so pin via the sequential driver's splice)
    from repro.core.rar import splice_guides
    spliced = splice_guides(prompt(skill, 1),
                            select_guides(np.asarray([1.0, 1.0]),
                                          np.asarray([True, True]),
                                          np.stack([g, g]), 0.9, 2))
    assert list(spliced).count(tk.GUIDE_START) == 1


def test_rar_config_rejects_bad_guide_knobs():
    from repro.core.rar import RARConfig

    with pytest.raises(ValueError):
        RARConfig(retrieval_k=0)
    with pytest.raises(ValueError):
        RARConfig(retrieval_k=4, max_guides=0)
    with pytest.raises(ValueError):
        RARConfig(retrieval_k=2, max_guides=3)


def _multi_guide_rar(max_guides, retrieval_k=4):
    """Controller whose memory holds two guide entries above threshold
    for the probe skill: the closest carries a WRONG hint, the second a
    RIGHT one — only multi-guide splicing can serve the request weak."""
    skill = 3
    q_emb = skill_emb(skill)
    rng = np.random.default_rng(123)
    off = rng.normal(size=q_emb.shape).astype(np.float32)
    off -= (off @ q_emb) * q_emb
    off /= np.linalg.norm(off)
    second = (0.97 * q_emb + np.sqrt(1 - 0.97 ** 2) * off).astype(
        np.float32)                       # cos(q, second) ≈ 0.97
    weak = MultiGuideWeak()
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    cfg = make_cfg(sim_threshold=0.9, retrieval_k=retrieval_k,
                   max_guides=max_guides)
    ctrl = MicrobatchRAR(weak, strong, lambda p: q_emb,
                         lambda e, k: False, cfg)
    ctrl.memory = mem.add(ctrl.memory, jnp.asarray(q_emb),
                          jnp.asarray(_guide(999)),       # wrong hint
                          jnp.asarray(True), jnp.asarray(False),
                          jnp.int32(1))
    ctrl.memory = mem.add(ctrl.memory, jnp.asarray(second),
                          jnp.asarray(_guide(skill + 100)),  # right hint
                          jnp.asarray(True), jnp.asarray(False),
                          jnp.int32(2))
    return ctrl, skill


@pytest.mark.parametrize("batched", [False, True])
def test_multi_guide_hit_serves_weak_where_top1_fails(batched):
    """memory_guide hit with retrieval_k=4: splicing the top-2 retrieved
    guides lets the weak FM answer a request the top-1 guide alone gets
    wrong — the paper's guided-generalization lever, now k-deep. With
    max_guides=1 the same store serves the wrong answer."""
    for max_guides, expect_correct in ((2, True), (1, False)):
        ctrl, skill = _multi_guide_rar(max_guides)
        if batched:
            out = ctrl.process_batch([prompt(skill, 1)], [greq(skill)],
                                     embs=skill_emb(skill)[None])[0]
        else:
            out = ctrl.process(prompt(skill, 1), greq(skill))
        assert out.case == "memory_guide" and out.strong_calls == 0
        assert (out.response == (skill + 1) % 4) is expect_correct


def test_multi_guide_case2a_recovers_via_second_guide():
    """Shadow case 2a with retrieval_k>1: the weak probe sees both
    retrieved guides, aligns thanks to the second, and the TOP guide is
    the one recorded (one guide block per stored entry)."""
    ctrl, skill = _multi_guide_rar(2)
    # miss the skill memory but hit the guide view: raise the routing
    # threshold above the exact-hit sim so the request takes the shadow
    # path, keep the guide threshold reachable
    import dataclasses
    ctrl.cfg = dataclasses.replace(ctrl.cfg, sim_threshold=1.5,
                                   guide_sim_threshold=0.9)
    out = ctrl.process_batch([prompt(skill, 1)], [greq(skill)],
                             embs=skill_emb(skill)[None])[0]
    assert out.case == "case2" and out.guide_source == "memory"
    assert ctrl.guides_from_memory == 1
    # the recorded entry carries the top-ranked (wrong-hint) guide block
    newest = np.asarray(ctrl.memory.guide)[2]
    assert newest[1] == 999


def test_mixed_batch_covers_all_groups():
    """One microbatch that lands in every partition group at once."""
    kw = dict(weak_known={0})
    ctrl, _ = build(MicrobatchRAR, **kw)
    warm = [(0, 1), (1, 1)]        # 0 → bare skill entry, 1 → guide entry
    ctrl.process_batch([prompt(s, x) for s, x in warm],
                       [greq(s) for s, _ in warm],
                       embs=np.stack([skill_emb(s) for s, _ in warm]))
    ctrl.route_weak_fn = lambda e, k: k is not None and k[0] == 2
    batch = [(0, 2), (1, 2), (2, 2), (3, 2)]
    outs = ctrl.process_batch([prompt(s, x) for s, x in batch],
                              [greq(s) for s, _ in batch],
                              keys=batch,
                              embs=np.stack([skill_emb(s)
                                             for s, _ in batch]))
    assert [o.case for o in outs] == ["memory_skill", "memory_guide",
                                     "router_weak", "case2"]
    assert [o.strong_calls for o in outs] == [0, 0, 0, 2]
