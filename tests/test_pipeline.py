"""Microbatched controller: exact batch-of-1 equivalence with the
sequential ``RAR.process`` (Outcome stream, memory state, FM-call counts),
plus batched-mode behaviour at B > 1."""
import numpy as np
import pytest
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb

from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import RAR

MEM_FIELDS = ("emb", "guide", "has_guide", "hard", "valid", "added_at",
              "ptr")


def make_stream(n_skills=6, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    stream = []
    for _ in range(reps):
        for s in rng.permutation(n_skills):
            stream.append((int(s), int(rng.integers(0, 8))))
    return stream


def build(cls, weak_known=(), weak_follows_guides=True, **cfg_kw):
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    if not weak_follows_guides:
        calls = weak.engine

        def stubborn(prompts):
            calls.calls += len(prompts)
            return np.asarray([-1] * len(prompts))
        weak.answer_batch = stubborn
    holder = {}
    ctrl = cls(weak, strong, lambda p: holder["emb"], lambda e, k: False,
               make_cfg(**cfg_kw))
    return ctrl, holder


def run_sequential(stream, **kw):
    rar, holder = build(RAR, **kw)
    outs = []
    for s, x in stream:
        holder["emb"] = skill_emb(s)
        outs.append(rar.process(prompt(s, x), greq(s), key=(s, x)))
    return rar, outs


def run_batched(stream, batch, **kw):
    ctrl, _ = build(MicrobatchRAR, **kw)
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        outs += ctrl.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
    return ctrl, outs


SCENARIOS = [
    dict(weak_known={0, 1}),                        # case1 + guide paths
    dict(weak_known=set()),                         # all guide-driven
    dict(weak_known=set(), weak_follows_guides=False,
         reprobe_period=4),                         # case3 + re-probe
    dict(weak_known={0, 1, 2}, reprobe_period=3, allow_fresh_guides=False),
]


@pytest.mark.parametrize("kw", SCENARIOS)
def test_batch1_identical_to_sequential(kw):
    stream = make_stream()
    seq, seq_outs = run_sequential(stream, **kw)
    bat, bat_outs = run_batched(stream, 1, **kw)
    assert bat_outs == seq_outs                     # full Outcome stream
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(seq.memory, f)),
            np.asarray(getattr(bat.memory, f)), f)
    assert bat.now == seq.now
    assert bat.weak.engine.calls == seq.weak.engine.calls
    assert bat.strong.engine.calls == seq.strong.engine.calls
    assert bat.guides_from_memory == seq.guides_from_memory
    assert bat.guides_generated == seq.guides_generated


def test_batched_mode_learns_and_matches_cost_profile():
    """At B=8 the controller still learns (second pass over the stream is
    mostly memory hits) and total strong calls stay close to sequential."""
    stream = make_stream(n_skills=8, reps=1, seed=3)
    kw = dict(weak_known={0, 1, 2})
    pass1 = stream
    pass2 = make_stream(n_skills=8, reps=1, seed=4)

    seq, seq_outs = run_sequential(pass1 + pass2, **kw)
    bat, bat_outs = run_batched(pass1 + pass2, 8, **kw)

    seq_strong = sum(o.strong_calls for o in seq_outs)
    bat_strong = sum(o.strong_calls for o in bat_outs)
    # same skills learned → identical steady state; transient duplicates
    # inside one microbatch may add a few extra shadow passes
    assert bat_strong >= seq_strong
    assert bat_strong <= seq_strong + 2 * 8
    # second pass: every skill is in memory → no strong calls at all for
    # guide-able skills in either mode
    second = bat_outs[len(pass1):]
    assert all(o.case in ("memory_skill", "memory_guide") for o in second)
    assert all(o.strong_calls == 0 for o in second)
    # responses match the sequential stream on the second pass
    assert [o.response for o in second] == \
        [o.response for o in seq_outs[len(pass1):]]


def test_batched_reprobe_clears_hard_flag():
    """The re-probe path (hard entry past cool-down) works batched: after
    the weak FM 'evolves', the hard flag clears and routing goes weak."""
    kw = dict(weak_known=set(), weak_follows_guides=False, reprobe_period=2)
    ctrl, _ = build(MicrobatchRAR, **kw)
    embs = skill_emb(5)[None]
    out = ctrl.process_batch([prompt(5, 1)], [greq(5)], embs=embs)[0]
    assert out.case == "case3"
    ctrl.weak = FakeTier(known={5}, name="weak-evolved")
    out = ctrl.process_batch([prompt(5, 2)], [greq(5)], embs=embs)[0]
    assert out.case == "memory_hard"
    out = ctrl.process_batch([prompt(5, 3)], [greq(5)], embs=embs)[0]
    assert out.case == "case1_reprobe"
    out = ctrl.process_batch([prompt(5, 4)], [greq(5)], embs=embs)[0]
    assert out.case == "memory_skill" and out.strong_calls == 0


def test_commit_eviction_does_not_corrupt_flag_updates():
    """Full ring: when the microbatch's FIFO scatter evicts the very slot
    a re-probe wanted to mark soft, the flag update must be dropped — not
    applied to the unrelated entry that now occupies the slot."""
    from repro.core import memory as mem

    kw = dict(weak_known=set(), reprobe_period=3,
              memory=mem.MemoryConfig(capacity=2, embed_dim=16,
                                      guide_len=8))
    ctrl, _ = build(MicrobatchRAR, **kw)
    ctrl.strong.can_guide = False          # guides never help → case3
    for s, now in ((5, 1), (6, 2)):        # two hard entries fill the ring
        out = ctrl.process_batch([prompt(s, 0)], [greq(s)],
                                 embs=skill_emb(s)[None])[0]
        assert out.case == "case3"
    ctrl.weak = FakeTier(known={5}, name="weak-evolved")
    # one microbatch: skill 7 records a fresh hard entry on slot 0 while
    # skill 5's successful re-probe targets (old) slot 0 for mark_soft
    outs = ctrl.process_batch(
        [prompt(7, 0), prompt(5, 1)], [greq(7), greq(5)],
        embs=np.stack([skill_emb(7), skill_emb(5)]))
    assert [o.case for o in outs] == ["case3", "case1_reprobe"]
    # skill 7's entry keeps its hard flag → next hit short-circuits strong
    out = ctrl.process_batch([prompt(7, 1)], [greq(7)],
                             embs=skill_emb(7)[None])[0]
    assert out.case == "memory_hard"
    # and skill 5 routes weak off its re-probed bare-skill entry
    out = ctrl.process_batch([prompt(5, 2)], [greq(5)],
                             embs=skill_emb(5)[None])[0]
    assert out.case == "memory_skill"


def test_mixed_batch_covers_all_groups():
    """One microbatch that lands in every partition group at once."""
    kw = dict(weak_known={0})
    ctrl, _ = build(MicrobatchRAR, **kw)
    warm = [(0, 1), (1, 1)]        # 0 → bare skill entry, 1 → guide entry
    ctrl.process_batch([prompt(s, x) for s, x in warm],
                       [greq(s) for s, _ in warm],
                       embs=np.stack([skill_emb(s) for s, _ in warm]))
    ctrl.route_weak_fn = lambda e, k: k is not None and k[0] == 2
    batch = [(0, 2), (1, 2), (2, 2), (3, 2)]
    outs = ctrl.process_batch([prompt(s, x) for s, x in batch],
                              [greq(s) for s, _ in batch],
                              keys=batch,
                              embs=np.stack([skill_emb(s)
                                             for s, _ in batch]))
    assert [o.case for o in outs] == ["memory_skill", "memory_guide",
                                     "router_weak", "case2"]
    assert [o.strong_calls for o in outs] == [0, 0, 0, 2]
