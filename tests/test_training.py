"""Optimizer, LR schedule, checkpoint roundtrip, and a tiny convergence
test on the real train loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import init_params
from repro.training import (AdamWConfig, adamw_update, init_opt_state,
                            load_checkpoint, lr_schedule, make_train_step,
                            save_checkpoint)


def test_lr_schedule_shape():
    cfg = AdamWConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # warmup peak
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)   # cosine floor
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||²
        params, opt, m = adamw_update(cfg, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clipping_caps_update():
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=0, total_steps=10,
                      grad_clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, opt)
    assert float(metrics["grad_norm"]) > 1e5    # raw norm reported


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nest": {"b": np.asarray([1, 2, 3], np.int32)},
            "name": np.asarray(7)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree)
    back = load_checkpoint(path)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["nest"]["b"], tree["nest"]["b"])


def test_train_loop_reduces_loss(rng):
    """~40 steps on a copy task with the smallest smoke config — loss must
    drop substantially (integration of model + loss + AdamW)."""
    cfg = configs.get_smoke("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=60)))
    losses = []
    for i in range(40):
        tokens = rng.integers(1, 32, (8, 16)).astype(np.int32)
        tokens[:, 8:] = tokens[:, :8]           # learnable copy structure
        labels = np.roll(tokens, -1, 1).astype(np.int32)
        labels[:, -1] = -1
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])


def test_grad_accum_matches_full_batch(rng):
    """grad_accum=2 must match the single-shot step (same data, f32)."""
    import dataclasses

    from repro import configs
    from repro.models import init_params

    cfg = dataclasses.replace(configs.get_smoke("olmo-1b"),
                              param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10)

    tokens = rng.integers(1, cfg.vocab_size, (8, 16)).astype(np.int32)
    labels = np.roll(tokens, -1, 1).astype(np.int32)
    labels[:, -1] = -1
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}

    step1 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=1))
    step2 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))
    p1, _, m1 = step1(params, init_opt_state(params), batch)
    p2, _, m2 = step2(params, init_opt_state(params), batch)
    # microbatch means weight tokens slightly differently only when the
    # valid-label counts differ per microbatch; with identical counts the
    # losses match to float tolerance.
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
