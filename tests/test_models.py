"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions, and prefill/decode consistency with
teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)
from repro.training import AdamWConfig, init_opt_state, make_train_step

ARCHS = configs.all_archs()


def make_batch(cfg, rng, B=2, S=32, shift=True):
    tokens = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key, rng):
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = make_batch(cfg, rng, B, S)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch, key, rng):
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, key)
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(learning_rate=1e-3,
                                                    warmup_steps=1,
                                                    total_steps=10)))
    batch = make_batch(cfg, rng)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch, key, rng):
    """Prefill's last-position logits == teacher-forcing logits at the last
    position (same params, same inputs)."""
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    full_logits, _ = forward(cfg, params, batch)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    pf_logits, cache, pos = prefill(cfg, params, batch, S + extra + 4)
    np.testing.assert_allclose(
        np.asarray(pf_logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.1, atol=0.1)
    assert int(pos) == S + extra


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, key, rng):
    """Greedy decode logits at position S match teacher forcing on the
    extended sequence — the KV-cache path is consistent with the parallel
    path for every family (incl. SSM states and hybrid mixed caches)."""
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    _, cache, pos = prefill(cfg, params, batch, S + extra + 4)

    next_tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (B,)), jnp.int32)
    step_logits, _ = decode_step(cfg, params, next_tok, cache, pos)

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    ext["labels"] = jnp.zeros_like(ext["tokens"])
    full_logits, _ = forward(cfg, params, ext)
    # bf16 params: the cached and parallel paths accumulate rounding
    # differently; 0.1 abs on O(10) logits still catches positional bugs.
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.1, atol=0.1)


def test_param_counts_match_formula(key):
    """param_count() accounting vs. actual init (within embed rounding)."""
    for arch in ARCHS:
        cfg = configs.get_smoke(arch)
        params = init_params(cfg, key)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expected = cfg.param_count()
        assert abs(actual - expected) / actual < 0.15, \
            (arch, actual, expected)


def test_moe_active_params_less_than_total():
    for arch in ("granite-moe-3b-a800m", "olmoe-1b-7b"):
        cfg = configs.get(arch)
        assert cfg.active_param_count() < cfg.param_count()
        assert cfg.active_param_count() > 0


def test_window_pattern_cycles():
    cfg = configs.get("gemma3-27b")
    w = cfg.layer_windows()
    assert len(w) == 62
    assert w[:6] == (1024, 1024, 1024, 1024, 1024, 0)
    assert w.count(0) == 10  # global layers


def test_hybrid_pattern():
    cfg = configs.get("recurrentgemma-2b")
    b = cfg.layer_blocks()
    assert len(b) == 26
    assert b[:3] == ("r", "r", "a")
    assert b.count("a") == 8 and b.count("r") == 18
