"""Async shadow queue: machine-checkable equivalence of the decoupled
shadow plane against the inline reference, commit-buffer properties
(epoch atomicity, order independence, wraparound), and threaded
stress/soak invariants (no lost commits, no duplicate drains, resolved
outcomes) on both store flavours.

The equivalence anchor: ``shadow_mode="deferred"`` with
``shadow_flush_every=1`` runs the *identical drain schedule* as
``"inline"`` through the queue machinery, so outcomes, memory contents,
FM-call counts and the RQ2 counters must be byte-identical — on the
single-scenario streams of ``test_pipeline`` and on fig4/fig7-style
multi-stage mini-suites. ``"async"`` with a per-batch flush barrier pins
the threaded path to the same bytes.
"""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st
from test_pipeline import MEM_FIELDS, SCENARIOS, build, make_stream
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb

from repro.core import memory as mem
from repro.core.memory_sharded import ShardedMemory
from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import RAR, RARConfig
from repro.core.shadow import PENDING, ShadowQueue


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def serve_stream(ctrl, stream, batch, flush_each=False):
    """Serve ``stream`` in microbatches; optional per-batch flush barrier
    (the async equivalence hook). Returns the outcome list."""
    outs = []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        outs += ctrl.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
        if flush_each:
            ctrl.flush_shadow()
    ctrl.flush_shadow()
    return outs


def assert_equivalent(a, a_outs, b, b_outs):
    """Byte-identical: outcome stream, memory contents, FM-call counts,
    RQ2 counters."""
    assert a_outs == b_outs
    for f in MEM_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a.memory, f)),
                                      np.asarray(getattr(b.memory, f)), f)
    assert a.now == b.now
    assert a.weak.engine.calls == b.weak.engine.calls
    assert a.strong.engine.calls == b.strong.engine.calls
    assert a.guides_from_memory == b.guides_from_memory
    assert a.guides_generated == b.guides_generated


# ---------------------------------------------------------------------------
# Equivalence suite: deferred (flush every batch) ≡ inline ≡ sequential
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", SCENARIOS)
@pytest.mark.parametrize("batch", [1, 4])
def test_deferred_flush_every_batch_identical_to_inline(kw, batch):
    stream = make_stream()
    inline, _ = build(MicrobatchRAR, **kw)
    deferred, _ = build(MicrobatchRAR, shadow_mode="deferred",
                        shadow_flush_every=1, **kw)
    a_outs = serve_stream(inline, stream, batch)
    b_outs = serve_stream(deferred, stream, batch)
    assert_equivalent(inline, a_outs, deferred, b_outs)


@pytest.mark.parametrize("kw", SCENARIOS[:3])
def test_deferred_batch1_identical_to_sequential(kw):
    """Composed with test_pipeline's batch-1 pin this closes the chain
    sequential ≡ inline ≡ deferred."""
    stream = make_stream()
    seq, holder = build(RAR, **kw)
    seq_outs = []
    for s, x in stream:
        holder["emb"] = skill_emb(s)
        seq_outs.append(seq.process(prompt(s, x), greq(s), key=(s, x)))
    deferred, _ = build(MicrobatchRAR, shadow_mode="deferred",
                        shadow_flush_every=1, **kw)
    d_outs = serve_stream(deferred, stream, 1)
    assert_equivalent(seq, seq_outs, deferred, d_outs)


@pytest.mark.parametrize("kw", SCENARIOS[:4])
def test_async_with_per_batch_barrier_identical_to_inline(kw):
    """The threaded drainer, forced onto the inline schedule by a flush
    barrier after every batch, must produce the same bytes."""
    stream = make_stream()
    inline, _ = build(MicrobatchRAR, **kw)
    async_, _ = build(MicrobatchRAR, shadow_mode="async",
                      shadow_flush_every=1, **kw)
    a_outs = serve_stream(inline, stream, 4)
    b_outs = serve_stream(async_, stream, 4, flush_each=True)
    async_.close_shadow()
    assert_equivalent(inline, a_outs, async_, b_outs)


# ---------------------------------------------------------------------------
# fig4/fig7-style mini-suites (multi-stage serving over a shuffled pool)
# ---------------------------------------------------------------------------


def run_mini_experiment(shadow_mode, flush_every=1, n_stages=3,
                        n_skills=10, batch=4, seed=7, **kw):
    """A fig4-shaped run: ``n_stages`` sequential passes over one shuffled
    pool, memory persisting across stages; per-stage strong calls +
    aligned tallied after a stage-end flush barrier (mirroring
    ``experiments.stages.run_rar_experiment``)."""
    ctrl, _ = build(MicrobatchRAR, shadow_mode=shadow_mode,
                    shadow_flush_every=flush_every, **kw)
    rng = np.random.default_rng(seed)
    pool = [(s, int(rng.integers(0, 8))) for s in range(n_skills)]
    order = rng.permutation(len(pool))
    per_stage, all_outs = [], []
    for _ in range(n_stages):
        stream = [pool[i] for i in order]
        outs = serve_stream(ctrl, stream, batch)   # flushes at stage end
        strong = sum(o.strong_calls for o in outs)
        aligned = sum(o.response == (s + x) % 4
                      for o, (s, x) in zip(outs, stream))
        per_stage.append((strong, aligned))
        all_outs += outs
    ctrl.close_shadow()
    return ctrl, all_outs, per_stage


def test_fig4_mini_suite_deferred_identical_to_inline():
    """Fig. 4 shape: cumulative strong-call reduction over stages, with
    the per-stage tallies — not just the final state — byte-identical
    between the inline and deferred shadow planes."""
    kw = dict(weak_known={0, 1})
    a, a_outs, a_stages = run_mini_experiment("inline", **kw)
    b, b_outs, b_stages = run_mini_experiment("deferred", **kw)
    assert_equivalent(a, a_outs, b, b_outs)
    assert a_stages == b_stages
    # the fig4 claim itself: capability accumulates, strong calls fall
    assert a_stages[-1][0] <= a_stages[0][0]


def test_fig7_mini_suite_guide_counters_identical():
    """Fig. 7 shape: guide-memory reuse overtakes fresh generation across
    stages; the RQ2 counters must not drift between shadow modes."""
    kw = dict(weak_known=set())        # every skill needs a guide
    a, a_outs, _ = run_mini_experiment("inline", n_stages=2, **kw)
    b, b_outs, _ = run_mini_experiment("deferred", n_stages=2, **kw)
    assert_equivalent(a, a_outs, b, b_outs)
    assert a.guides_generated > 0      # stage 1: fresh generation
    second = a_outs[len(a_outs) // 2:]  # stage 2: memory serves
    assert all(o.case == "memory_guide" for o in second)


def test_deferred_staleness_and_flush_barrier():
    """Without a drain, a repeat of the same skill cannot hit memory (its
    shadow pass has not committed); the flush barrier resolves the
    provisional outcome and lands the commit."""
    ctrl, _ = build(MicrobatchRAR, weak_known={3}, shadow_mode="deferred",
                    shadow_flush_every=0)
    out1 = ctrl.process_batch([prompt(3, 1)], [greq(3)],
                              embs=skill_emb(3)[None])[0]
    assert out1.case == PENDING and out1.served_by == "strong"
    out2 = ctrl.process_batch([prompt(3, 2)], [greq(3)],
                              embs=skill_emb(3)[None])[0]
    assert out2.case == PENDING            # stale store: no memory hit yet
    assert ctrl.shadow.buffer.epoch == 0 and ctrl.memory.size_fast == 0
    ctrl.flush_shadow()
    assert out1.case == "case1" and out2.case == "case1"
    assert ctrl.shadow.buffer.epoch == 1   # one coalesced drain epoch
    assert ctrl.memory.size_fast == 2      # both shadow passes recorded
    out3 = ctrl.process_batch([prompt(3, 3)], [greq(3)],
                              embs=skill_emb(3)[None])[0]
    assert out3.case == "memory_skill" and out3.strong_calls == 0


def test_occupancy_counter_matches_store():
    """The transfer-free host counter tracks true ring occupancy through
    deferred drains and wraparound (the progress-logging contract)."""
    cap = 8
    ctrl, _ = build(MicrobatchRAR, weak_known=set(),
                    shadow_mode="deferred", shadow_flush_every=2,
                    memory=mem.MemoryConfig(capacity=cap, embed_dim=16,
                                            guide_len=8))
    for rep in range(3):
        for s in range(0, 12, 2):
            serve_stream(ctrl, [(s, rep), (s + 1, rep)], 2)
    assert ctrl.memory_occupancy == ctrl.memory.size_fast == cap


def test_shadow_config_validation():
    with pytest.raises(ValueError):
        RARConfig(shadow_mode="background")
    with pytest.raises(ValueError):
        RARConfig(shadow_mode="deferred", shadow_flush_every=-1)
    with pytest.raises(ValueError):
        RARConfig(shadow_mode="inline", shadow_flush_every=4)
    with pytest.raises(ValueError):
        ShadowQueue(runner=lambda items: None, mode="nope")


def test_async_drainer_error_surfaces_at_barrier():
    """An exception on the drainer thread must not vanish: the next
    flush barrier re-raises it on the caller — and the failed epoch's
    items stay queued (not lost), so once the fault clears a retry
    barrier resolves every pending Outcome."""
    ctrl, _ = build(MicrobatchRAR, weak_known=set(), shadow_mode="async",
                    shadow_flush_every=1)

    def boom(items):
        raise RuntimeError("drain failed")

    real_runner = ctrl.shadow.runner
    ctrl.shadow.runner = boom
    out = ctrl.process_batch([prompt(2, 1)], [greq(2)],
                             embs=skill_emb(2)[None])[0]
    with pytest.raises(RuntimeError):
        ctrl.flush_shadow()
    # the failed epoch was re-queued, not dropped
    assert out.case == PENDING
    assert ctrl.shadow.items_requeued == 1
    assert ctrl.shadow.items_drained == 0
    # fault clears: the retry barrier drains the retained items
    ctrl.shadow.runner = real_runner
    ctrl.flush_shadow()
    assert out.case != PENDING
    assert ctrl.shadow.items_enqueued == ctrl.shadow.items_drained == 1
    ctrl.close_shadow()


@pytest.mark.parametrize("mode", ["inline", "deferred", "async",
                                  "adaptive"])
def test_injected_drain_fault_does_not_lose_items(mode):
    """The lost-failed-epoch bugfix, pinned at the issue's fault site: a
    ``drain``-site fault kills the first drain epoch mid-flight. The
    epoch's items must be re-queued (head, seq order) — after the fault
    clears one ``flush_shadow()`` barrier resolves every
    ``shadow_pending`` Outcome, ``items_enqueued == items_drained``
    holds, and the shadow pass's store write lands exactly once."""
    from repro.serving.faults import FaultPlan
    plan = FaultPlan([FaultPlan.drain_error(at=1)])
    ctrl = MicrobatchRAR(
        FakeTier(known=set(), name="weak"),
        FakeTier(known=range(10_000), can_guide=True, name="strong"),
        lambda p: None, lambda e, k: False,
        make_cfg(shadow_mode=mode, shadow_flush_every=1),
        fault_plan=plan)
    with pytest.raises(RuntimeError):
        # inline/deferred/adaptive drain on the serve call and raise
        # there; async raises at the barrier
        ctrl.process_batch([prompt(2, 1)], [greq(2)],
                           embs=skill_emb(2)[None])
        ctrl.flush_shadow()
    # the failed epoch is retained, provisional outcome unresolved
    assert [it.seq for it in ctrl.shadow._items] == [1]
    out = ctrl.shadow._items[0].outcome
    assert out.case == PENDING
    assert ctrl.shadow.items_requeued == 1
    assert ctrl.shadow.items_drained == 0
    assert ctrl.shadow.drain_failures == 1
    assert ctrl.shadow.buffer.pending == 0      # no partial staging left
    # fault cleared (at=1 is one-shot): the next barrier retries
    ctrl.flush_shadow()
    assert out.case == "case2" and out.guide_source == "fresh"
    assert ctrl.shadow.items_enqueued == ctrl.shadow.items_drained == 1
    assert not ctrl.shadow._items
    assert ctrl.memory.size_fast == 1           # landed exactly once
    assert ctrl.guides_generated == 1           # counters not doubled
    ctrl.close_shadow()


def test_requeued_epoch_retries_ahead_of_new_items():
    """Items from a failed epoch retry AT THE HEAD: a batch enqueued
    after the failure drains behind them, preserving global seq order
    across the retry."""
    drained: list[int] = []

    class Flaky:
        fail = True

        def __call__(self, items):
            if self.fail:
                self.fail = False
                raise RuntimeError("transient")
            drained.extend(it.seq for it in items)

    q = ShadowQueue(Flaky(), mode="deferred", flush_every=0)
    mk = lambda seq: type("It", (), {"seq": seq, "now": seq})()
    q.submit([mk(1), mk(2)])
    with pytest.raises(RuntimeError):
        q.flush()
    q.submit([mk(3)])
    q.flush()
    assert drained == [1, 2, 3]
    assert q.items_enqueued == q.items_drained == 3
    assert q.items_requeued == 2 and q.drain_failures == 1


# ---------------------------------------------------------------------------
# Commit-buffer property sweep (hypothesis; derandomized under the CI
# profile via conftest)
# ---------------------------------------------------------------------------


def _unit(rng, d=8):
    v = rng.normal(size=d).astype(np.float32)
    return v / np.linalg.norm(v)


def _stage(buf, op):
    kind = op[0]
    if kind == "add":
        buf.stage_add(*op[1])
    elif kind == "soft":
        buf.stage_soft_clear(op[1], op[2])
    else:
        buf.stage_touch(op[1], op[2])


FIELDS = ("emb", "mask", "guide", "hard", "added_at", "ptr")


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000),           # seed
       st.sampled_from([4, 8, 16]),      # ring capacity (wraparound)
       st.sampled_from([1, 2, 5]),       # drain cadence (ops per epoch)
       st.integers(8, 30))               # interleaving length
def test_property_commit_buffer_atomic_and_order_independent(
        seed, cap, cadence, n_ops):
    """Random interleavings of stage/drain/query over shapes × flush
    cadence × ring wraparound:

    * a query never observes a partially-applied epoch — between applies
      the store is byte-identical to the last epoch boundary (staging
      mutates nothing);
    * the final store state of every epoch is independent of the order
      its ops were staged in;
    * the chunked ``add_batch`` apply of an insert-only epoch equals the
      same inserts applied one :func:`repro.core.memory.add` at a time
      (FIFO wraparound included).
    """
    cfg = mem.MemoryConfig(capacity=cap, embed_dim=8, guide_len=4)
    rng = np.random.default_rng(seed)
    state_a, buf_a = mem.init_memory(cfg), mem.CommitBuffer()
    state_b, buf_b = mem.init_memory(cfg), mem.CommitBuffer()
    oracle = state_a                   # sequential-add oracle
    boundary = state_a                 # store at the last epoch boundary
    staged, now = [], 0

    def snap(s):
        return [np.asarray(getattr(s, f)) for f in FIELDS]

    def drain():
        nonlocal state_a, state_b, oracle, boundary, staged
        for op in staged:
            _stage(buf_a, op)
        for j in rng.permutation(len(staged)):
            _stage(buf_b, staged[int(j)])
        state_a, na = buf_a.apply(state_a)
        state_b, nb = buf_b.apply(state_b)
        adds = [op for op in staged if op[0] == "add"]
        assert na == nb == len(adds)
        assert buf_a.epoch == buf_b.epoch
        # order independence within the epoch
        for fa, fb, name in zip(snap(state_a), snap(state_b), FIELDS):
            np.testing.assert_array_equal(fa, fb, name)
        if len(adds) == len(staged):   # insert-only epoch → exact oracle
            for e, g, hg, hd, t in (a[1] for a in adds):
                oracle = mem.add(oracle, jnp.asarray(e), jnp.asarray(g),
                                 jnp.asarray(hg), jnp.asarray(hd),
                                 jnp.int32(t))
            for fa, fo, name in zip(snap(state_a), snap(oracle), FIELDS):
                np.testing.assert_array_equal(fa, fo, name)
        else:
            oracle = state_a
        boundary = state_a
        staged = []

    for i in range(n_ops):
        now += 1
        r = rng.random()
        if r < 0.55:
            staged.append(("add", (_unit(rng),
                                   rng.integers(0, 50, 4).astype(np.int32),
                                   bool(rng.random() < 0.5),
                                   bool(rng.random() < 0.3), now)))
        elif r < 0.7:
            staged.append(("soft", int(rng.integers(0, cap)), now))
        elif r < 0.85:
            staged.append(("touch", int(rng.integers(0, cap)), now))
        else:
            # query point: staged-but-unapplied ops must be invisible —
            # the live store is byte-identical to the last epoch boundary
            qv = _unit(rng)
            qa = mem.query(state_a, jnp.asarray(qv)).device_get()
            qb = mem.query(boundary, jnp.asarray(qv)).device_get()
            assert float(qa.sim) == float(qb.sim)
            np.testing.assert_array_equal(qa.meta, qb.meta)
            for fa, fbnd, name in zip(snap(state_a), snap(boundary),
                                      FIELDS):
                np.testing.assert_array_equal(fa, fbnd, name)
        if staged and (i + 1) % cadence == 0:
            drain()
    if staged:
        drain()
    assert buf_a.entries_applied == int(state_a.ptr)


def test_commit_buffer_drops_flag_update_across_epochs():
    """The eviction guard spans drain epochs: a re-probe flag update
    whose target slot was evicted by an *intervening* epoch's FIFO
    scatter (async staleness window) must be dropped — it would otherwise
    mutate the unrelated fresh entry now in that slot. With a current
    snapshot the update still applies."""
    cfg = mem.MemoryConfig(capacity=2, embed_dim=8, guide_len=4)
    rng = np.random.default_rng(0)
    state = mem.init_memory(cfg)
    for now in (1, 2):                 # two hard entries fill the ring
        state = mem.add(state, jnp.asarray(_unit(rng)),
                        jnp.zeros(4, jnp.int32), jnp.asarray(False),
                        jnp.asarray(True), jnp.int32(now))
    snap = int(state.ptr)              # classification-time pointer (2)

    buf = mem.CommitBuffer()
    # intervening epoch: two inserts wrap the ring; slot 0 now holds a
    # fresh hard entry the stale flag update must not touch
    for now in (3, 4):
        buf.stage_add(_unit(rng), np.zeros(4, np.int32), False, True, now)
    state, _ = buf.apply(state)
    assert bool(np.asarray(state.hard)[0])

    # the re-probe item's epoch: stale-snapshot updates are dropped ...
    buf.stage_soft_clear(0, 5, ptr_snapshot=snap)
    buf.stage_touch(0, 5, ptr_snapshot=snap)
    state, _ = buf.apply(state)
    assert bool(np.asarray(state.hard)[0])          # still hard
    assert int(np.asarray(state.added_at)[0]) == 3  # timestamp untouched
    # ... while a current-snapshot update applies
    buf.stage_soft_clear(0, 6, ptr_snapshot=int(state.ptr))
    state, _ = buf.apply(state)
    assert not bool(np.asarray(state.hard)[0])


# ---------------------------------------------------------------------------
# Dedup coalescing (RARConfig.shadow_dedup_sim)
# ---------------------------------------------------------------------------


def test_dedup_coalesces_duplicate_skills_in_one_drain():
    """Two same-skill requests queued across batches resolve in ONE
    shadow pass: one recorded entry, one set of probe calls, followers'
    skipped calls tallied as reclaimed — the ROADMAP's
    dedup-as-a-coalescing-rule follow-up."""
    ctrl, _ = build(MicrobatchRAR, weak_known=set(),
                    shadow_mode="deferred", shadow_flush_every=0,
                    shadow_dedup_sim=0.99)
    for x in (1, 2, 3):
        ctrl.process_batch([prompt(4, x)], [greq(4)],
                           embs=skill_emb(4)[None])
    weak_before = ctrl.weak.engine.calls
    strong_before = ctrl.strong.engine.calls
    ctrl.flush_shadow()
    # one leader probe path: weak-alone probe + guided probe (2 weak
    # calls), one fresh-guide generation (1 strong call) — NOT ×3
    assert ctrl.weak.engine.calls - weak_before == 2
    assert ctrl.strong.engine.calls - strong_before == 1
    assert ctrl.memory.size_fast == 1              # one entry per group
    q = ctrl.shadow
    assert q.items_coalesced == 2
    assert q.reclaimed_strong_calls == 2           # 2 skipped generations
    assert q.reclaimed_weak_calls == 4             # 2 followers × depth 2
    # followers adopt the leader's resolution; their user-facing strong
    # calls stay at the serve call they actually paid
    ctrl.close_shadow()


def test_dedup_followers_resolve_like_leader_distinct_skills_split():
    """Dissimilar items never coalesce; near-duplicates resolve to the
    leader's case with their own outcomes finalized."""
    ctrl, _ = build(MicrobatchRAR, weak_known={3},
                    shadow_mode="deferred", shadow_flush_every=0,
                    shadow_dedup_sim=0.99)
    outs = []
    for s, x in ((3, 1), (3, 2), (7, 1)):
        outs += ctrl.process_batch([prompt(s, x)], [greq(s)],
                                   embs=skill_emb(s)[None])
    ctrl.flush_shadow()
    assert [o.case for o in outs] == ["case1", "case1", "case2"]
    assert [o.strong_calls for o in outs] == [1, 1, 2]
    assert ctrl.memory.size_fast == 2       # skill-3 group + skill 7
    assert ctrl.shadow.items_coalesced == 1
    ctrl.close_shadow()


def test_dedup_off_is_default_and_validated():
    assert RARConfig().shadow_dedup_sim is None
    with pytest.raises(ValueError):
        RARConfig(shadow_dedup_sim=0.0)
    with pytest.raises(ValueError):
        RARConfig(shadow_dedup_sim=1.5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),          # seed
       st.integers(1, 24),              # item count
       st.sampled_from([0.5, 0.9, 0.999]))   # dedup threshold
def test_property_coalesce_groups_partition_and_cohere(seed, n, thresh):
    """Coalescing invariants for any item set: the groups partition the
    indices exactly; leaders ascend in enqueue order (deterministic);
    every follower's embedding reaches the threshold against its
    *leader*; and a threshold no embedding pair reaches yields all
    singletons."""
    from repro.core.decisions import coalesce_shadow_items

    rng = np.random.default_rng(seed)
    # a few tight clusters + noise, L2-normalized like controller embs
    centers = rng.normal(size=(4, 16)).astype(np.float32)
    embs = []
    for _ in range(n):
        v = centers[rng.integers(0, 4)] + \
            0.01 * rng.normal(size=16).astype(np.float32)
        embs.append(v / np.linalg.norm(v))
    embs = np.stack(embs).astype(np.float32)

    groups = coalesce_shadow_items(embs, thresh)
    flat = sorted(j for g in groups for j in g)
    assert flat == list(range(n))                      # exact partition
    leaders = [g[0] for g in groups]
    assert leaders == sorted(leaders)                  # deterministic
    for g in groups:
        assert g == sorted(g)
        for j in g[1:]:
            assert float(embs[j] @ embs[g[0]]) >= thresh
    # greedy rule: a leader never reaches any *earlier* leader
    for gi, lead in enumerate(leaders):
        for earlier in leaders[:gi]:
            assert float(embs[lead] @ embs[earlier]) < thresh
    # a threshold above the max pairwise cosine → all singletons (the
    # max is taken with the same per-pair dots the rule evaluates — a
    # gemm reduction can differ by an ulp)
    if n > 1:
        hi = max(float(embs[i] @ embs[j])
                 for i in range(n) for j in range(i + 1, n))
        above = np.nextafter(np.float32(hi), np.float32(2.0))
        assert all(len(g) == 1
                   for g in coalesce_shadow_items(embs, float(above)))


# ---------------------------------------------------------------------------
# Async stress / soak
# ---------------------------------------------------------------------------


def _stress(duration_s: float, store: str = "single", seed: int = 0,
            capacity: int = 8, flush_every: int = 2,
            drain_delay: float = 0.002):
    """Threaded drainer under injected drain delays and forced ring
    wraparound. Invariants: every enqueued item drains exactly once, all
    outcomes resolve, and the ring pointer advanced exactly once per
    committed entry (no lost, no duplicated commits)."""
    cfg_mem = mem.MemoryConfig(capacity=capacity, embed_dim=16,
                               guide_len=8)
    weak = FakeTier(known={0, 1}, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    cfg = make_cfg(shadow_mode="async", shadow_flush_every=flush_every,
                   memory=cfg_mem)
    memory = ShardedMemory(cfg_mem) if store == "sharded" else None
    ctrl = MicrobatchRAR(weak, strong, lambda p: None, lambda e, k: False,
                         cfg, memory=memory)
    ctrl.shadow.drain_delay = drain_delay
    drained_seqs: list[int] = []
    orig = ctrl._drain_shadow

    def traced(items):
        drained_seqs.extend(it.seq for it in items)
        orig(items)

    ctrl.shadow.runner = traced
    rng = np.random.default_rng(seed)
    outs, t_end = [], time.time() + duration_s
    batches = 0
    # a batch floor on top of the time budget: jit warm-up must not stop
    # a short run from ever wrapping the ring
    while time.time() < t_end or batches < 40:
        batches += 1
        B = int(rng.integers(1, 5))
        chunk = [(int(rng.integers(0, 12)), int(rng.integers(0, 8)))
                 for _ in range(B)]
        outs += ctrl.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
    ctrl.flush_shadow()
    ctrl.close_shadow()

    q = ctrl.shadow
    assert q.items_enqueued == q.items_drained == len(drained_seqs)
    assert len(set(drained_seqs)) == len(drained_seqs)   # no double drain
    assert sorted(drained_seqs) == list(range(1, len(drained_seqs) + 1))
    assert all(o.case != PENDING for o in outs)          # all resolved
    # commit accounting: ptr advanced exactly once per applied entry —
    # nothing lost in a coalesced epoch, nothing duplicated across drains
    assert q.buffer.entries_applied == int(ctrl.memory.ptr)
    assert ctrl.memory_occupancy == ctrl.memory.size_fast
    assert int(ctrl.memory.ptr) > capacity               # wrapped the ring
    assert q.drains >= 1
    if store == "sharded":
        st_ = ctrl.memory.to_single_device()
        assert int(np.sum(np.asarray(st_.valid))) == capacity
    return len(outs), q.drains


def test_async_stress_single_store():
    n, drains = _stress(1.2, store="single")
    assert n > 0 and drains >= 1


def test_async_stress_sharded_store():
    n, _ = _stress(1.2, store="sharded", drain_delay=0.005)
    assert n > 0


@pytest.mark.skipif(not os.environ.get("REPRO_SOAK_SMOKE"),
                    reason="60s soak; set REPRO_SOAK_SMOKE=1")
def test_async_soak_smoke():
    """The CI soak: ~60s of continuous async serving across both store
    flavours, several drain cadences and delays, full invariant sweep."""
    budget = 60.0
    legs = [("single", 1, 0.0), ("single", 3, 0.004),
            ("sharded", 2, 0.002), ("single", 0, 0.01)]
    per_leg = budget / len(legs)
    total = 0
    for i, (store, flush_every, delay) in enumerate(legs):
        n, _ = _stress(per_leg, store=store, seed=100 + i,
                       flush_every=flush_every, drain_delay=delay)
        total += n
    assert total > 100
