"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro.kernels.ref."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.memory_topk import (memory_top1_batch_pallas,
                                       memory_top1_pallas)

TOL = {np.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# memory_top1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [64, 300, 1024, 4096])
@pytest.mark.parametrize("E", [128, 384])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_memory_top1_sweep(rng, C, E, dtype):
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    q = rng.normal(size=(E,)).astype(np.float32)
    q /= np.linalg.norm(q)
    mask = rng.random(C) < 0.6
    mask[int(rng.integers(0, C))] = True  # never empty
    mem_t = jnp.asarray(mem, dtype)
    s_ref, i_ref = ref.memory_top1(mem_t, jnp.asarray(q), jnp.asarray(mask))
    s_p, i_p = memory_top1_pallas(mem_t, jnp.asarray(q), jnp.asarray(mask),
                                  block_c=128, interpret=True)
    assert int(i_ref) == int(i_p)
    np.testing.assert_allclose(float(s_ref), float(s_p), atol=1e-5)


def test_memory_top1_empty_mask(rng):
    mem = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    mask = jnp.zeros((64,), bool)
    s, _ = memory_top1_pallas(mem, q, mask, block_c=32, interpret=True)
    assert float(s) == -2.0


def test_memory_top1_exact_hit(rng):
    """Query equal to a stored row must retrieve that row with sim≈1."""
    mem = rng.normal(size=(256, 384)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    q = mem[123]
    mask = np.ones(256, bool)
    s, i = memory_top1_pallas(jnp.asarray(mem), jnp.asarray(q),
                              jnp.asarray(mask), block_c=64, interpret=True)
    assert int(i) == 123
    assert float(s) > 0.999


# ---------------------------------------------------------------------------
# memory_top1_batch (multi-query)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [64, 300, 1024])
@pytest.mark.parametrize("B", [1, 3, 8, 32])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_memory_top1_batch_sweep(rng, C, B, dtype):
    E = 384
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    mask = rng.random(C) < 0.6
    mask[int(rng.integers(0, C))] = True  # never empty
    mem_t = jnp.asarray(mem, dtype)
    s_ref, i_ref = ref.memory_top1_batch(mem_t, jnp.asarray(qs),
                                         jnp.asarray(mask))
    s_p, i_p = memory_top1_batch_pallas(mem_t, jnp.asarray(qs),
                                        jnp.asarray(mask), block_c=128,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_p),
                               atol=1e-5)


def test_memory_top1_batch_matches_single(rng):
    """Each batched query must agree with the single-query kernel."""
    C, E, B = 256, 128, 7
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    mask = jnp.asarray(rng.random(C) < 0.7)
    s_b, i_b = memory_top1_batch_pallas(jnp.asarray(mem), jnp.asarray(qs),
                                        mask, block_c=64, interpret=True)
    for b in range(B):
        s1, i1 = memory_top1_pallas(jnp.asarray(mem), jnp.asarray(qs[b]),
                                    mask, block_c=64, interpret=True)
        assert int(i1) == int(i_b[b])
        np.testing.assert_allclose(float(s1), float(s_b[b]), atol=1e-6)


def test_memory_top1_batch_empty_mask(rng):
    mem = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    mask = jnp.zeros((64,), bool)
    s, _ = memory_top1_batch_pallas(mem, qs, mask, block_c=32,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(s), np.full(4, -2.0))


def test_memory_top1_batch_exact_hits(rng):
    """Queries equal to stored rows retrieve those rows with sim≈1."""
    mem = rng.normal(size=(256, 384)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    picks = [3, 77, 200]
    qs = mem[picks]
    mask = np.ones(256, bool)
    s, i = memory_top1_batch_pallas(jnp.asarray(mem), jnp.asarray(qs),
                                    jnp.asarray(mask), block_c=64,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(i), picks)
    assert float(np.min(np.asarray(s))) > 0.999


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,window", [(128, 0), (256, 0), (256, 64),
                                      (512, 128), (256, 32)])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, S, window, H, KV, dtype):
    B, hd = 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    o_ref = ref.flash_attention(q, k, v, causal=True, window=window)
    o_p = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal(rng):
    B, S, H, hd = 1, 128, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=False)
    o_p = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_skip_equals_masked(rng):
    """Window smaller than a block: skipped blocks must not change the
    result (the FLOPs-saving path is numerically identical)."""
    B, S, H, hd = 1, 512, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=True, window=16)
    o_p = flash_attention_pallas(q, k, v, causal=True, window=16,
                                 block_q=128, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,clen,window", [
    (256, 256, 0), (512, 300, 0), (512, 300, 64), (1024, 1000, 256),
    (256, 1, 0)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_decode_attention_sweep(rng, M, clen, window, H, KV, dtype):
    B, hd = 2, 32
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, M, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, M, KV, hd)), dtype)
    cl = jnp.asarray(clen, jnp.int32)
    o_ref = ref.decode_attention(q, k, v, cl, window=window)
    o_p = decode_attention_pallas(q, k, v, cl, window=window, block_m=128,
                                  interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_decode_matches_flash_at_full_length(rng):
    """decode(q_last) == flash(q)[last] when the cache is exactly full."""
    B, S, H, hd = 1, 256, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True)
    dec = decode_attention_pallas(q[:, -1], k, v, jnp.asarray(S, jnp.int32),
                                  block_m=64, interpret=True)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)
