"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro.kernels.ref, plus the property-based top-k
parity sweep (random shapes, k, mask patterns and duplicate-similarity
ties — results must be bit-identical, tie-break order included)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.memory_topk import (MASK_GUIDE, MASK_VALID,
                                       memory_top1_batch_padded_pallas,
                                       memory_top1_batch_pallas,
                                       memory_top1_padded_pallas,
                                       memory_top1_pallas,
                                       memory_topk_batch_padded_pallas,
                                       memory_topk_padded_pallas,
                                       to_padded_layout)

TOL = {np.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# memory_top1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [64, 300, 1024, 4096])
@pytest.mark.parametrize("E", [128, 384])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_memory_top1_sweep(rng, C, E, dtype):
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    q = rng.normal(size=(E,)).astype(np.float32)
    q /= np.linalg.norm(q)
    mask = rng.random(C) < 0.6
    mask[int(rng.integers(0, C))] = True  # never empty
    mem_t = jnp.asarray(mem, dtype)
    s_ref, i_ref = ref.memory_top1(mem_t, jnp.asarray(q), jnp.asarray(mask))
    s_p, i_p = memory_top1_pallas(mem_t, jnp.asarray(q), jnp.asarray(mask),
                                  block_c=128, interpret=True)
    assert int(i_ref) == int(i_p)
    np.testing.assert_allclose(float(s_ref), float(s_p), atol=1e-5)


def test_memory_top1_empty_mask(rng):
    mem = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    mask = jnp.zeros((64,), bool)
    s, _ = memory_top1_pallas(mem, q, mask, block_c=32, interpret=True)
    assert float(s) == -2.0


def test_memory_top1_exact_hit(rng):
    """Query equal to a stored row must retrieve that row with sim≈1."""
    mem = rng.normal(size=(256, 384)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    q = mem[123]
    mask = np.ones(256, bool)
    s, i = memory_top1_pallas(jnp.asarray(mem), jnp.asarray(q),
                              jnp.asarray(mask), block_c=64, interpret=True)
    assert int(i) == 123
    assert float(s) > 0.999


# ---------------------------------------------------------------------------
# memory_top1_batch (multi-query)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [64, 300, 1024])
@pytest.mark.parametrize("B", [1, 3, 8, 32])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_memory_top1_batch_sweep(rng, C, B, dtype):
    E = 384
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    mask = rng.random(C) < 0.6
    mask[int(rng.integers(0, C))] = True  # never empty
    mem_t = jnp.asarray(mem, dtype)
    s_ref, i_ref = ref.memory_top1_batch(mem_t, jnp.asarray(qs),
                                         jnp.asarray(mask))
    s_p, i_p = memory_top1_batch_pallas(mem_t, jnp.asarray(qs),
                                        jnp.asarray(mask), block_c=128,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_p),
                               atol=1e-5)


def test_memory_top1_batch_matches_single(rng):
    """Each batched query must agree with the single-query kernel."""
    C, E, B = 256, 128, 7
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    mask = jnp.asarray(rng.random(C) < 0.7)
    s_b, i_b = memory_top1_batch_pallas(jnp.asarray(mem), jnp.asarray(qs),
                                        mask, block_c=64, interpret=True)
    for b in range(B):
        s1, i1 = memory_top1_pallas(jnp.asarray(mem), jnp.asarray(qs[b]),
                                    mask, block_c=64, interpret=True)
        assert int(i1) == int(i_b[b])
        np.testing.assert_allclose(float(s1), float(s_b[b]), atol=1e-6)


def test_memory_top1_batch_empty_mask(rng):
    mem = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    mask = jnp.zeros((64,), bool)
    s, _ = memory_top1_batch_pallas(mem, qs, mask, block_c=32,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(s), np.full(4, -2.0))


def test_memory_top1_batch_exact_hits(rng):
    """Queries equal to stored rows retrieve those rows with sim≈1."""
    mem = rng.normal(size=(256, 384)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    picks = [3, 77, 200]
    qs = mem[picks]
    mask = np.ones(256, bool)
    s, i = memory_top1_batch_pallas(jnp.asarray(mem), jnp.asarray(qs),
                                    jnp.asarray(mask), block_c=64,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(i), picks)
    assert float(np.min(np.asarray(s))) > 0.999


# ---------------------------------------------------------------------------
# memory_top1 padded entry points (the zero-copy serving path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [64, 300, 1024])
@pytest.mark.parametrize("E", [128, 384])
def test_memory_top1_padded_matches_oracle(rng, C, E):
    """Padded Pallas entry == padded oracle == legacy oracle, for both the
    valid view and the valid+guide view of the mask bit plane."""
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    q = rng.normal(size=(E,)).astype(np.float32)
    q /= np.linalg.norm(q)
    valid = rng.random(C) < 0.7
    has_guide = rng.random(C) < 0.4
    valid[int(rng.integers(0, C))] = True
    bits = (valid.astype(np.int32) * MASK_VALID
            + (valid & has_guide).astype(np.int32) * MASK_GUIDE)
    memp, maskp = to_padded_layout(jnp.asarray(mem), jnp.asarray(bits),
                                   block_c=128)
    for required, legacy_mask in ((MASK_VALID, valid),
                                  (MASK_VALID | MASK_GUIDE,
                                   valid & has_guide)):
        if not legacy_mask.any():
            continue
        s_l, i_l = ref.memory_top1(jnp.asarray(mem), jnp.asarray(q),
                                   jnp.asarray(legacy_mask))
        s_o, i_o = ref.memory_top1_padded(memp, jnp.asarray(q), maskp,
                                          required)
        s_p, i_p = memory_top1_padded_pallas(memp, jnp.asarray(q), maskp,
                                             required=required, block_c=128,
                                             interpret=True)
        assert int(i_l) == int(i_o) == int(i_p)
        np.testing.assert_allclose(float(s_l), float(s_p), atol=1e-5)
        np.testing.assert_allclose(float(s_o), float(s_p), atol=1e-5)


@pytest.mark.parametrize("B", [1, 5, 32])
def test_memory_top1_batch_padded_matches_oracle(rng, B):
    C, E = 300, 384
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    valid = rng.random(C) < 0.7
    has_guide = rng.random(C) < 0.4
    valid[int(rng.integers(0, C))] = True
    has_guide[valid.argmax()] = True
    bits = (valid.astype(np.int32) * MASK_VALID
            + (valid & has_guide).astype(np.int32) * MASK_GUIDE)
    memp, maskp = to_padded_layout(jnp.asarray(mem), jnp.asarray(bits),
                                   block_c=128)
    for required, legacy_mask in ((MASK_VALID, valid),
                                  (MASK_VALID | MASK_GUIDE,
                                   valid & has_guide)):
        s_l, i_l = ref.memory_top1_batch(jnp.asarray(mem), jnp.asarray(qs),
                                         jnp.asarray(legacy_mask))
        s_o, i_o = ref.memory_top1_batch_padded(memp, jnp.asarray(qs),
                                                maskp, required)
        s_p, i_p = memory_top1_batch_padded_pallas(
            memp, jnp.asarray(qs), maskp, required=required, block_c=128,
            interpret=True)
        np.testing.assert_array_equal(np.asarray(i_l), np.asarray(i_p))
        np.testing.assert_array_equal(np.asarray(i_o), np.asarray(i_p))
        np.testing.assert_allclose(np.asarray(s_l), np.asarray(s_p),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_o), np.asarray(s_p),
                                   atol=1e-5)


def test_query_path_is_zero_copy():
    """No store-sized buffer is materialized inside the jitted query —
    top-1 or top-k: no jaxpr equation *produces* a (Cp, Ep)-shaped value;
    the store only enters as an input operand (the old wrappers created a
    second full-size buffer via zeros+scatter on every call)."""
    import re

    import jax

    from repro.core import memory as cmem

    cfg = cmem.MemoryConfig(capacity=1024, embed_dim=384, guide_len=4)
    state = cmem.init_memory(cfg)
    q = jnp.zeros((cfg.embed_dim,), jnp.float32)
    qs = jnp.zeros((8, cfg.embed_dim), jnp.float32)
    Cp, Ep = state.emb.shape
    # equation outputs print as `name:f32[Cp,Ep] = prim ...`
    produced = re.compile(rf":f32\[{Cp},{Ep}\] =")
    for jaxpr in (jax.make_jaxpr(
                      lambda s, e: cmem._query_jit(s, e))(state, q),
                  jax.make_jaxpr(
                      lambda s, e: cmem._query_batch_jit(s, e))(state, qs),
                  jax.make_jaxpr(
                      lambda s, e: cmem._query_topk_jit(s, e, 4))(state, q),
                  jax.make_jaxpr(
                      lambda s, e: cmem._query_topk_batch_jit(s, e, 4))(
                          state, qs)):
        assert not produced.search(str(jaxpr)), jaxpr


# ---------------------------------------------------------------------------
# memory_topk (the multi-guide read path)
# ---------------------------------------------------------------------------


def _topk_store(rng, C, E, density, n_dups):
    """Random store with controlled mask density and ``n_dups`` exact
    duplicates of row 0 (duplicate similarities → the tie-break path)."""
    mem = rng.normal(size=(C, E)).astype(np.float32)
    norms = np.linalg.norm(mem, axis=1, keepdims=True)
    mem /= np.where(norms > 0, norms, 1.0)
    dup_rows = 1 + (np.arange(n_dups) * max(1, (C - 1) // (n_dups + 1))
                    ) % max(C - 1, 1)
    mem[dup_rows] = mem[0]
    valid = rng.random(C) < density
    has_guide = rng.random(C) < 0.5
    bits = (valid.astype(np.int32) * MASK_VALID
            + (valid & has_guide).astype(np.int32) * MASK_GUIDE)
    return mem, bits


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([1, 2, 4, 8]),                  # k
       st.sampled_from([17, 64, 100, 300]),            # C (odd → padding)
       st.sampled_from([16, 128, 384]),                # E
       st.sampled_from([1, 2, 5, 32]),                 # B
       st.sampled_from([0.0, 0.2, 0.7, 1.0]),          # mask density
       st.sampled_from([0, 3, 7]),                     # duplicate rows
       st.booleans())                                  # guides-only view
def test_property_topk_pallas_matches_oracle(seed, k, C, E, B, density,
                                             n_dups, guides_only):
    """Property sweep: the Pallas top-k kernel must reproduce the ref
    oracle's *retrieval* bit-for-bit — the returned rows, their order
    (duplicate-similarity ties resolve to ascending store row in both)
    and the -2.0 sentinel fill when k exceeds the view's population.
    Similarities are exact to 1 ulp across the two implementations (the
    kernel's lane-padded query block takes a different BLAS gemm shape
    than the oracle's compact one — bitwise-equal dot products across
    matmul shapes are not a portable property of any backend) and
    *bitwise* equal within each implementation at tied rows, which is
    what makes the tie order deterministic. The dispatch-path pins
    (k=1 ≡ top-1, sharded ≡ single-device) compare like against like
    and are asserted fully bitwise elsewhere."""
    rng = np.random.default_rng(seed)
    mem, bits = _topk_store(rng, C, E, density, n_dups)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    qs[0] = mem[0]                     # exact hit on the duplicated row
    memp, maskp = to_padded_layout(jnp.asarray(mem), jnp.asarray(bits),
                                   block_c=128)
    required = MASK_VALID | (MASK_GUIDE if guides_only else 0)

    s_o, i_o = ref.memory_topk_batch_padded(memp, jnp.asarray(qs), maskp,
                                            k, required)
    s_p, i_p = memory_topk_batch_padded_pallas(
        memp, jnp.asarray(qs), maskp, k=k, required=required, block_c=128,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(i_o), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(s_o), np.asarray(s_p),
                               atol=1e-6)

    s1_o, i1_o = ref.memory_topk_padded(memp, jnp.asarray(qs[0]), maskp,
                                        k, required)
    s1_p, i1_p = memory_topk_padded_pallas(
        memp, jnp.asarray(qs[0]), maskp, k=k, required=required,
        block_c=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(i1_o), np.asarray(i1_p))
    np.testing.assert_allclose(np.asarray(s1_o), np.asarray(s1_p),
                               atol=1e-6)

    # structural invariants of the result order, in BOTH implementations:
    # sims strictly descending except at ties, ties in ascending row
    # order with bitwise-equal sims
    for s_row, i_row in ((np.asarray(s_o), np.asarray(i_o)),
                         (np.asarray(s_p), np.asarray(i_p)),
                         (np.asarray(s1_o)[None], np.asarray(i1_o)[None]),
                         (np.asarray(s1_p)[None], np.asarray(i1_p)[None])):
        for b in range(s_row.shape[0]):
            for j in range(k - 1):
                assert (s_row[b, j] > s_row[b, j + 1]
                        or (s_row[b, j] == s_row[b, j + 1]
                            and i_row[b, j] < i_row[b, j + 1]))


def test_topk_tie_order_is_lowest_row_first(rng):
    """Duplicated store rows must surface in ascending row order, in both
    the oracle and the kernel, at every k that spans the duplicates."""
    C, E = 96, 64
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    dups = [5, 17, 40, 77]
    mem[dups] = mem[dups[0]]
    bits = np.full(C, MASK_VALID, np.int32)
    memp, maskp = to_padded_layout(jnp.asarray(mem), jnp.asarray(bits),
                                   block_c=32)
    q = jnp.asarray(mem[dups[0]])
    for k in (1, 2, 4):
        s_o, i_o = ref.memory_topk_padded(memp, q, maskp, k, MASK_VALID)
        _, i_p = memory_topk_padded_pallas(memp, q, maskp, k=k,
                                           required=MASK_VALID, block_c=32,
                                           interpret=True)
        assert list(np.asarray(i_o))[:min(k, 4)] == dups[:min(k, 4)]
        np.testing.assert_array_equal(np.asarray(i_o), np.asarray(i_p))
        assert float(np.asarray(s_o)[0]) > 0.999


def test_topk_k1_matches_top1_kernels(rng):
    """k=1 output must match the top-1 kernels row for row (the top-1
    data plane is the k=1 special case, not a separate contract)."""
    C, E, B = 200, 128, 6
    mem = rng.normal(size=(C, E)).astype(np.float32)
    mem /= np.linalg.norm(mem, axis=1, keepdims=True)
    qs = rng.normal(size=(B, E)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    valid = rng.random(C) < 0.6
    valid[3] = True
    bits = valid.astype(np.int32) * MASK_VALID
    memp, maskp = to_padded_layout(jnp.asarray(mem), jnp.asarray(bits),
                                   block_c=64)
    s1, i1 = memory_top1_batch_padded_pallas(memp, jnp.asarray(qs), maskp,
                                             block_c=64, interpret=True)
    sk, ik = memory_topk_batch_padded_pallas(memp, jnp.asarray(qs), maskp,
                                             k=1, required=MASK_VALID,
                                             block_c=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(ik)[:, 0])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(sk)[:, 0])


def test_topk_rejects_bad_k():
    memp = jnp.zeros((64, 128), jnp.float32)
    maskp = jnp.zeros((64, 1), jnp.int32)
    q = jnp.zeros((128,), jnp.float32)
    with pytest.raises(ValueError):
        memory_topk_padded_pallas(memp, q, maskp, k=0, interpret=True)
    with pytest.raises(ValueError):
        # k beyond the kernel block cannot keep the accumulator exact
        memory_topk_padded_pallas(memp, q, maskp, k=16, block_c=8,
                                  interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,window", [(128, 0), (256, 0), (256, 64),
                                      (512, 128), (256, 32)])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, S, window, H, KV, dtype):
    B, hd = 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    o_ref = ref.flash_attention(q, k, v, causal=True, window=window)
    o_p = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal(rng):
    B, S, H, hd = 1, 128, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=False)
    o_p = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_skip_equals_masked(rng):
    """Window smaller than a block: skipped blocks must not change the
    result (the FLOPs-saving path is numerically identical)."""
    B, S, H, hd = 1, 512, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=True, window=16)
    o_p = flash_attention_pallas(q, k, v, causal=True, window=16,
                                 block_q=128, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,clen,window", [
    (256, 256, 0), (512, 300, 0), (512, 300, 64), (1024, 1000, 256),
    (256, 1, 0)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_decode_attention_sweep(rng, M, clen, window, H, KV, dtype):
    B, hd = 2, 32
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, M, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, M, KV, hd)), dtype)
    cl = jnp.asarray(clen, jnp.int32)
    o_ref = ref.decode_attention(q, k, v, cl, window=window)
    o_p = decode_attention_pallas(q, k, v, cl, window=window, block_m=128,
                                  interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_decode_matches_flash_at_full_length(rng):
    """decode(q_last) == flash(q)[last] when the cache is exactly full."""
    B, S, H, hd = 1, 256, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True)
    dec = decode_attention_pallas(q[:, -1], k, v, jnp.asarray(S, jnp.int32),
                                  block_m=64, interpret=True)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# dispatch-layer impl selection
# ---------------------------------------------------------------------------


def test_impl_selection_memoized_with_override(monkeypatch):
    """ops resolves the kernel impl once (no per-dispatch env/backend
    probe); set_impl is the explicit override hook and set_impl(None)
    re-resolves from the environment."""
    from repro.kernels import ops

    saved = ops._impl_cache
    try:
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
        ops.set_impl(None)
        assert ops._default_impl() == "interpret"
        # memoized: flipping the env after first resolution has no effect
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        assert ops._default_impl() == "interpret"
        # the override hook wins immediately
        ops.set_impl("ref")
        assert ops._default_impl() == "ref"
        with pytest.raises(ValueError):
            ops.set_impl("bogus")
    finally:
        ops._impl_cache = saved


def test_odd_block_c_never_crashes(rng):
    """block_c values that are not row-tile multiples (or smaller than the
    tile) must still produce a valid blocking, not a ZeroDivisionError."""
    C, E = 100, 128
    mem = rng.normal(size=(C, E)).astype(np.float32)
    q = rng.normal(size=(E,)).astype(np.float32)
    mask = np.ones(C, bool)
    s_ref, i_ref = ref.memory_top1(jnp.asarray(mem), jnp.asarray(q),
                                   jnp.asarray(mask))
    for bc in (4, 12, 100):
        s, i = memory_top1_pallas(jnp.asarray(mem), jnp.asarray(q),
                                  jnp.asarray(mask), block_c=bc,
                                  interpret=True)
        assert int(i) == int(i_ref)
        np.testing.assert_allclose(float(s), float(s_ref), atol=1e-5)
