"""Static router training + contrastive embedder behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embedder as emb
from repro.core.router import LearnedRouter, train_router


def test_learned_router_separable(rng):
    """Logistic router must fit linearly separable profiling data."""
    n, d = 400, 16
    w_true = rng.normal(size=d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (X @ w_true > 0).astype(np.float32)
    router = train_router(X, y, steps=300)
    pred = np.asarray(jax.vmap(router.prob_weak_ok)(jnp.asarray(X))) > 0.5
    assert (pred == y.astype(bool)).mean() > 0.95


def test_router_threshold_controls_routing(rng):
    X = rng.normal(size=(100, 8)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.float32)
    r = train_router(X, y, steps=200)
    strict = LearnedRouter(w=r.w, b=r.b, threshold=0.99)
    loose = LearnedRouter(w=r.w, b=r.b, threshold=0.01)
    n_strict = sum(strict.route_weak(jnp.asarray(x)) for x in X)
    n_loose = sum(loose.route_weak(jnp.asarray(x)) for x in X)
    assert n_strict <= n_loose


@pytest.fixture(scope="module")
def ecfg():
    return emb.EmbedderConfig(vocab_size=32, d_model=32, num_layers=2,
                              num_heads=2, d_ff=64, embed_dim=48)


def test_embedding_unit_norm(ecfg, rng):
    params = emb.init_params(ecfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, 32, (4, 10)), jnp.int32)
    z = emb.embed(ecfg, params, toks)
    assert z.shape == (4, 48)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(z), axis=1), 1.0,
                               atol=1e-5)


def test_embedding_pad_invariance(ecfg, rng):
    """PAD tokens must not affect the embedding (mean-pool masking)."""
    params = emb.init_params(ecfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, 32, (2, 6)), jnp.int32)
    padded = jnp.concatenate(
        [toks, jnp.zeros((2, 4), jnp.int32)], axis=1)
    z1 = emb.embed(ecfg, params, toks)
    z2 = emb.embed(ecfg, params, padded)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-4)


def test_contrastive_training_pulls_positives(ecfg, rng):
    """100 NT-Xent steps on 4 'skills' with deterministic token templates
    → same-skill cosine must clearly exceed different-skill cosine."""
    params = emb.init_params(ecfg, jax.random.PRNGKey(1))
    opt = emb.init_opt(params)
    step = emb.make_train_step(ecfg, lr=1e-3)

    def batch(rng):
        toks, sids = [], []
        for _ in range(12):
            s = int(rng.integers(0, 4))
            base = np.full(10, s * 7 + 1, np.int32)
            for _ in range(2):
                t = base.copy()
                t[6:] = rng.integers(1, 32, 4)   # operand noise
                toks.append(t)
                sids.append(s)
        return jnp.asarray(np.stack(toks)), jnp.asarray(sids, jnp.int32)

    for _ in range(100):
        toks, sids = batch(rng)
        params, opt, loss = step(params, opt, toks, sids)

    toks, sids = batch(rng)
    z = np.asarray(emb.embed(ecfg, params, toks))
    sims = z @ z.T
    sid = np.asarray(sids)
    same = sims[(sid[:, None] == sid[None]) & ~np.eye(len(sid), dtype=bool)]
    diff = sims[sid[:, None] != sid[None]]
    assert same.mean() > diff.mean() + 0.3
