import os

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only repro.launch.dryrun forces 512 placeholder devices (in-process).

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Pin the hypothesis profile for reproducibility: CI runs with
# HYPOTHESIS_PROFILE=ci (derandomized — the property sweeps, incl. the
# pallas/ref top-k parity suite, must not flake on a lucky draw; a failure
# reproduces exactly). Without the real library the _hyp shim is already
# deterministic (fixed rng seed per test).
try:                                     # pragma: no cover - env dependent
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", derandomize=True, deadline=None,
                                   max_examples=30)
    _hyp_settings.register_profile("dev", deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption("--skip-slow", action="store_true",
                     default=bool(os.environ.get("REPRO_FAST")),
                     help="skip slow integration tests (trained RAR "
                          "system, subprocess dry-runs)")
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="deprecated no-op (slow tests run by default)")


def pytest_collection_modifyitems(config, items):
    if not config.getoption("--skip-slow"):
        return
    skip = pytest.mark.skip(reason="slow; skipped via --skip-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
