"""Multi-pod dry-run smoke (slow: subprocess with 512 placeholder devices).

The full 10×4×2 sweep runs via ``python -m repro.launch.dryrun --all
--both-meshes`` (results in EXPERIMENTS.md); here we gate a representative
subset in CI fashion: one arch per family × one shape each, both meshes
for one of them.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CASES = [
    ("llama3-8b", "decode_32k", False),
    ("llama3-8b", "decode_32k", True),          # multi-pod
    ("mamba2-2.7b", "long_500k", False),
    ("olmoe-1b-7b", "prefill_32k", False),
    ("recurrentgemma-2b", "decode_32k", False),
    ("whisper-medium", "train_4k", False),
]


@pytest.mark.parametrize("arch,shape,mp", CASES)
def test_dryrun_pair_compiles(arch, shape, mp, tmp_path):
    out = os.path.join(tmp_path, "dr.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if mp:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1800)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(out) as f:
        recs = json.load(f)
    rec = recs[-1]
    assert rec["status"] == "ok", rec
    assert rec["roofline"]["flops_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
