"""Replicated serving fabric: the 1-replica inline fabric pinned
byte-identical to ``MicrobatchRAR.process_batch``, N-replica threaded
stress invariants (no lost/duplicate outcomes, ``ptr ==
entries_applied``), commit-stream broadcast consistency across replica
store views, and the single-owner occupancy counter."""
import numpy as np
import pytest
from test_pipeline import SCENARIOS, make_stream, run_batched
from test_rar_controller import FakeTier, greq, make_cfg, prompt, skill_emb
from test_shadow import assert_equivalent

from repro.core import memory as mem
from repro.core.shadow import PENDING
from repro.serving.fabric import ServingFabric


def build_fabric(replicas=1, weak_known=(), weak_follows_guides=True,
                 **cfg_kw):
    """Mirror of ``test_pipeline.build`` for the fabric (``memory=`` in
    ``cfg_kw`` is a ``MemoryConfig``, as in ``make_cfg``)."""
    weak = FakeTier(known=weak_known, name="weak")
    strong = FakeTier(known=range(10_000), can_guide=True, name="strong")
    if not weak_follows_guides:
        calls = weak.engine

        def stubborn(prompts):
            calls.calls += len(prompts)
            return np.asarray([-1] * len(prompts))
        weak.answer_batch = stubborn
    return ServingFabric(weak, strong, lambda p: None,
                         lambda e, k: False, make_cfg(**cfg_kw),
                         replicas=replicas)


def serve_fabric(fab, stream, batch, submit=False):
    """Serve ``stream`` through the fabric in microbatches — synchronous
    ``process_batch`` (the equivalence path) or threaded ``submit``."""
    outs, tickets = [], []
    for start in range(0, len(stream), batch):
        chunk = stream[start:start + batch]
        args = ([prompt(s, x) for s, x in chunk],
                [greq(s) for s, _ in chunk])
        kw = dict(keys=chunk,
                  embs=np.stack([skill_emb(s) for s, _ in chunk]))
        if submit:
            tickets.append(fab.submit(*args, **kw))
        else:
            outs += fab.process_batch(*args, **kw)
    fab.flush_shadow()
    for t in tickets:
        outs += t.wait()
    return outs


# ---------------------------------------------------------------------------
# Equivalence: 1-replica inline fabric ≡ MicrobatchRAR, byte for byte
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", SCENARIOS)
@pytest.mark.parametrize("batch", [1, 4])
def test_one_replica_inline_fabric_identical_to_microbatch(kw, batch):
    """The acceptance anchor: dispatching through the fabric with one
    replica must produce the same bytes as calling
    ``MicrobatchRAR.process_batch`` directly — Outcome stream, memory
    state, FM-call counts, RQ2 counters."""
    stream = make_stream()
    ref, ref_outs = run_batched(stream, batch, **kw)
    fab = build_fabric(1, **kw)
    fab_outs = serve_fabric(fab, stream, batch)
    assert_equivalent(ref, ref_outs, fab.learn, fab_outs)
    fab.close_shadow()


@pytest.mark.parametrize("kw", SCENARIOS[:3])
def test_one_replica_threaded_fabric_identical_to_microbatch(kw):
    """Same pin through the threaded dispatch path: one replica worker
    serves the submitted microbatches FIFO, so the bytes cannot differ."""
    stream = make_stream()
    ref, ref_outs = run_batched(stream, 4, **kw)
    fab = build_fabric(1, **kw)
    fab_outs = serve_fabric(fab, stream, 4, submit=True)
    assert_equivalent(ref, ref_outs, fab.learn, fab_outs)
    fab.close_shadow()


@pytest.mark.parametrize("shadow_mode", ["deferred", "async"])
def test_one_replica_fabric_shadow_modes_identical_to_inline(shadow_mode):
    """The fabric composes with the queue's drain modes: deferred
    flush-every-batch (and async behind a per-batch barrier) through the
    fabric still matches the inline fabric byte for byte."""
    kw = dict(weak_known={0, 1})
    stream = make_stream()
    a = build_fabric(1, **kw)
    a_outs = serve_fabric(a, stream, 4)
    b = build_fabric(1, shadow_mode=shadow_mode, shadow_flush_every=1,
                     **kw)
    b_outs = []
    for start in range(0, len(stream), 4):
        chunk = stream[start:start + 4]
        b_outs += b.process_batch(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk], keys=chunk,
            embs=np.stack([skill_emb(s) for s, _ in chunk]))
        b.flush_shadow()                  # per-batch barrier
    assert_equivalent(a.learn, a_outs, b.learn, b_outs)
    a.close_shadow()
    b.close_shadow()


# ---------------------------------------------------------------------------
# Commit-stream broadcast + single-owner accounting
# ---------------------------------------------------------------------------


def test_commit_broadcast_keeps_replica_views_identical():
    """Every drain epoch lands on all replica store views atomically:
    after any barrier the views are the same object (functional store)
    and a replica that never served still routes off entries other
    replicas learned."""
    fab = build_fabric(3, weak_known={0, 1})
    stream = [(s, x) for s in range(6) for x in range(2)]
    serve_fabric(fab, stream, 3, submit=True)
    assert all(r.memory is fab.learn.memory for r in fab.replicas)
    # replica 2 serves a repeat explicitly: must hit the shared memory
    out = fab.process_batch([prompt(0, 5)], [greq(0)],
                            embs=skill_emb(0)[None], replica=2)[0]
    assert out.case in ("memory_skill", "memory_guide")
    assert out.strong_calls == 0
    fab.close_shadow()


def test_occupancy_single_counter_exact_across_replicas():
    """The small fix this PR pins: occupancy derives from the commit
    stream's single counter, so it stays exact when N replicas commit to
    one store (per-controller counters would each undercount)."""
    cap = 8
    fab = build_fabric(3, weak_known=set(),
                       memory=mem.MemoryConfig(capacity=cap, embed_dim=16,
                                               guide_len=8))
    # serve 12 distinct skills through 3 replicas → ring wraps
    for rep in range(2):
        serve_fabric(fab, [(s, rep) for s in range(12)], 2, submit=True)
    assert fab.memory_occupancy == fab.memory.size_fast == cap
    for r in fab.replicas:
        assert r.memory_occupancy == fab.memory_occupancy
    assert fab.commit_stream.commits == \
        fab.commit_stream.buffer.entries_applied == int(fab.memory.ptr)
    fab.close_shadow()


# ---------------------------------------------------------------------------
# N-replica threaded stress
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shadow_mode,flush_every", [("inline", 1),
                                                     ("deferred", 2),
                                                     ("async", 2)])
def test_fabric_threaded_stress(shadow_mode, flush_every):
    """Concurrent replicas under every drain mode: every submitted
    request resolves exactly once, nothing is lost or duplicated in the
    shared commit stream (``ptr == entries_applied``), and all store
    views agree."""
    cap = 8
    fab = build_fabric(3, weak_known={0, 1}, shadow_mode=shadow_mode,
                       shadow_flush_every=flush_every,
                       memory=mem.MemoryConfig(capacity=cap, embed_dim=16,
                                               guide_len=8))
    rng = np.random.default_rng(0)
    tickets, n_requests = [], 0
    for _ in range(60):
        B = int(rng.integers(1, 5))
        chunk = [(int(rng.integers(0, 12)), int(rng.integers(0, 8)))
                 for _ in range(B)]
        n_requests += B
        tickets.append(fab.submit(
            [prompt(s, x) for s, x in chunk],
            [greq(s) for s, _ in chunk],
            embs=np.stack([skill_emb(s) for s, _ in chunk])))
    fab.flush_shadow()
    outs = [o for t in tickets for o in t.wait()]
    assert len(outs) == n_requests                    # no lost/dup outcomes
    assert all(o.case != PENDING for o in outs)       # all resolved
    stats = fab.stats()
    assert stats["items_enqueued"] == stats["items_drained"]
    # nothing lost in a coalesced epoch, nothing duplicated across
    # drains: the ring pointer advanced exactly once per committed entry
    assert fab.commit_stream.buffer.entries_applied == \
        int(np.asarray(fab.memory.ptr))
    assert fab.memory_occupancy == fab.memory.size_fast
    assert int(np.asarray(fab.memory.ptr)) > cap      # wrapped the ring
    assert all(r.memory is fab.learn.memory for r in fab.replicas)
    # logical times stayed unique across replicas (commit-buffer keying)
    assert fab.now == n_requests
    fab.close_shadow()


def test_worker_error_surfaces_at_wait_and_join():
    fab = build_fabric(2, weak_known={0})
    boom = RuntimeError("replica died")

    def dying(prompts):
        raise boom

    # kill replica 1's *serve-plane* strong sweep (the weak probes run on
    # the learn replica, which stays healthy)
    fab.replicas[1].strong = FakeTier(known=range(10_000), can_guide=True,
                                      name="strong-dying")
    fab.replicas[1].strong.answer_batch = dying
    fab.submit([prompt(5, 1)], [greq(5)], embs=skill_emb(5)[None],
               replica=1)
    # the error must not vanish: join() waits everything out, then
    # re-raises the first worker failure
    with pytest.raises(RuntimeError):
        fab.join()
    # the fabric stays serviceable: a fresh submit to the healthy
    # replica still serves
    ok = fab.submit([prompt(0, 2)], [greq(0)], embs=skill_emb(0)[None],
                    replica=0)
    assert ok.wait(timeout=30)[0].response >= -1
    fab.close_shadow()


def test_fabric_validation():
    with pytest.raises(ValueError):
        build_fabric(0)


# ---------------------------------------------------------------------------
# Lifecycle + routing robustness (PR 8 satellite pins)
# ---------------------------------------------------------------------------


def test_close_shadow_joins_workers_when_flush_raises():
    """A flush failure inside close_shadow must not leak the replica
    worker threads: teardown runs in a finally (threads sentineled and
    joined, queues torn down), the flush error stays the primary
    exception, and a retried close with the fault cleared drains and
    succeeds."""
    fab = build_fabric(2, weak_known=set(), shadow_mode="deferred",
                       shadow_flush_every=0)
    stream = make_stream()[:6]
    tickets = [fab.submit([prompt(s, x)], [greq(s)],
                          embs=skill_emb(s)[None], replica=0)
               for s, x in stream]
    for t in tickets:
        t.wait(timeout=30)
    assert len(fab.learn.shadow._items) == len(stream)   # undrained
    threads = [t for t in fab._threads if t is not None]
    assert threads and all(t.is_alive() for t in threads)

    real_runner = fab.learn.shadow.runner
    boom = RuntimeError("drain broken")

    def dying(items):
        raise boom

    fab.learn.shadow.runner = dying
    with pytest.raises(RuntimeError, match="drain broken"):
        fab.close_shadow()
    # the finally ran: every worker thread joined, dispatch plane gone
    assert all(not t.is_alive() for t in threads)
    assert fab._queues is None
    # failed epoch retained (the drain-loss bugfix), not dropped: the
    # flush AND the learn replica's own close retry each failed once,
    # re-queuing the same 6 items both times — nothing lost either way
    assert fab.learn.shadow.drain_failures == 2
    assert fab.learn.shadow.items_requeued == 2 * len(stream)
    assert len(fab.learn.shadow._items) == len(stream)
    # fault cleared: the retried close drains everything and succeeds
    fab.learn.shadow.runner = real_runner
    fab.close_shadow()
    assert fab.learn.shadow.items_enqueued == \
        fab.learn.shadow.items_drained
    assert all(o.case != PENDING for t in tickets for o in t.wait())


def test_submit_serves_when_all_replicas_marked_dead():
    """The round-robin fall-through bug: with every slot transiently
    marked dead, submit used to enqueue onto a dead slot's queue and the
    ticket never served. Now the chosen slot is revived under the
    dispatch lock — a stale mark clears, a really-dead worker restarts —
    and the ticket serves."""
    fab = build_fabric(2, weak_known={0, 1})
    first = fab.submit([prompt(0, 1)], [greq(0)], embs=skill_emb(0)[None])
    assert first.wait(timeout=30)[0].response == 1
    # stale-mark case: workers are alive, every slot says dead
    fab.health = ["dead", "dead"]
    t = fab.submit([prompt(1, 2)], [greq(1)], embs=skill_emb(1)[None])
    assert t.wait(timeout=30)[0].response == 3
    assert fab.health[t.replica] == "healthy"         # mark self-healed
    # really-dead case: kill both workers, mark dead, submit again
    with fab._dispatch_lock:
        for q in fab._queues:
            q.put(None)
    for th in fab._threads:
        th.join(timeout=30)
    assert all(not th.is_alive() for th in fab._threads)
    fab.health = ["dead", "dead"]
    restarts = fab.restarts
    t2 = fab.submit([prompt(0, 3)], [greq(0)], embs=skill_emb(0)[None])
    assert t2.wait(timeout=30)[0].response == 3
    assert fab.restarts == restarts + 1               # slot restarted
    fab.close_shadow()


def test_autoscale_spawn_and_retire():
    """scale_to grows the fleet with live workers immediately in the
    round-robin, retire is terminal (skipped by dispatch, queued work
    still drains), the learn replica can never retire, and the
    policy-driven autoscale() is health-gated."""
    fab = build_fabric(1, weak_known={0, 1})
    fab.submit([prompt(0, 1)], [greq(0)],
               embs=skill_emb(0)[None]).wait(timeout=30)
    assert fab.scale_to(3) == 2
    assert fab.active_replicas == 3 and len(fab.replicas) == 3
    stream = make_stream()
    outs = serve_fabric(fab, stream, 2, submit=True)
    assert len(outs) == len(stream)
    assert all(o.case != PENDING for o in outs)
    # scale back down: highest slots retire, learn replica survives
    assert fab.scale_to(1) == -2
    assert fab.active_replicas == 1
    assert fab.health[1] == fab.health[2] == "retired"
    t = fab.submit([prompt(1, 1)], [greq(1)], embs=skill_emb(1)[None])
    assert t.replica == 0                             # retired slots skipped
    t.wait(timeout=30)
    with pytest.raises(ValueError):
        fab.scale_to(0)                               # learn always serves
    # policy-driven step: target from a metrics snapshot, health-gated
    fab.set_autoscaler(lambda m: 2)
    assert fab.autoscale() == 1
    assert fab.active_replicas == 2
    fab.health[0] = "dead"
    assert fab.autoscale() == 0                       # gate: no resize
    fab.health[0] = "healthy"
    m = fab.metrics()
    assert m["supervision"]["spawned"] == 3
    assert m["supervision"]["retired"] == 2
    assert m["supervision"]["active_replicas"] == 2
    fab.close_shadow()
