"""Ring-buffer KV cache (§Perf variant): decode with a window-sized ring
must produce exactly the logits of the full-length cache with the same
sliding-window mask."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, init_cache, init_params

WINDOW = 8
STEPS = 24


@pytest.mark.parametrize("arch", ["llama3-8b", "olmoe-1b-7b"])
def test_ring_cache_matches_full_cache(arch, rng):
    base = configs.get_smoke(arch)
    full_cfg = dataclasses.replace(base, decode_window=WINDOW,
                                   param_dtype="float32")
    ring_cfg = dataclasses.replace(base, decode_window=WINDOW,
                                   ring_cache=True, param_dtype="float32")
    params = init_params(full_cfg, jax.random.PRNGKey(0))

    B = 2
    full_cache = init_cache(full_cfg, B, STEPS, jnp.float32)
    ring_cache = init_cache(ring_cfg, B, STEPS, jnp.float32)
    assert ring_cache["k"].shape[2] == WINDOW
    assert full_cache["k"].shape[2] == STEPS

    toks = rng.integers(1, base.vocab_size, (STEPS, B)).astype(np.int32)
    for pos in range(STEPS):
        t = jnp.asarray(toks[pos])
        lf, full_cache = decode_step(full_cfg, params, t, full_cache,
                                     jnp.asarray(pos, jnp.int32))
        lr, ring_cache = decode_step(ring_cfg, params, t, ring_cache,
                                     jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lr, np.float32), np.asarray(lf, np.float32),
            rtol=2e-4, atol=2e-4,
            err_msg=f"pos {pos} ({'pre' if pos < WINDOW else 'post'}-wrap)")
