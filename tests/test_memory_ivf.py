"""Two-level (IVF) retrieval plane: hierarchical recall vs. the exact
oracle, route-kernel parity, byte-identity of the IVF-off default,
per-shard route merge, grow-in-place, and host-offload tiering.

The load-bearing invariants:

* probing **all** clusters reproduces the exhaustive scan's valid
  entries exactly (same total order end to end), for any write history
  including ring wrap — the exactness anchor the recall property
  degrades from;
* ``retrieval_clusters = 0`` (the default) constructs no wrapper at all
  — controllers and fabric serve bit-identically to the pre-IVF stack;
* per-shard centroid-subset routes merge bit-identically into the
  global route (THE shared (score desc, row asc) total order).
"""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import memory as mem
from repro.core.memory_ivf import IVFMemory, _route_merged, wrap_store
from repro.kernels import ref
from repro.kernels.memory_ivf import ivf_route_batch_padded_pallas, \
    ivf_route_padded_pallas
from repro.kernels.memory_topk import MASK_GUIDE, MASK_VALID

E, G = 32, 8


def _protos(rng, n, e=E):
    p = rng.normal(size=(n, e)).astype(np.float32)
    return p / np.linalg.norm(p, axis=1, keepdims=True)


def _clustered(rng, protos, n, noise=0.05):
    x = protos[rng.integers(0, len(protos), n)] \
        + noise * rng.normal(size=(n, protos.shape[1])).astype(np.float32)
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def _fill(store, rng, X, guide_frac=0.7, chunk=32):
    for i in range(0, len(X), chunk):
        xb = X[i:i + chunk]
        k = len(xb)
        store.add_batch(
            jnp.asarray(xb), jnp.asarray(rng.integers(
                0, 100, size=(k, G)).astype(np.int32)),
            jnp.asarray(rng.random(k) < guide_frac),
            jnp.asarray(rng.random(k) < 0.5),
            jnp.asarray(np.full(k, i, np.int32)))


def _assert_matches_exact(ivf, q_or_qs, k, guides_only=False, batch=False):
    """IVF result equals the exact oracle on every valid entry (valid
    rows agree bitwise on index/meta, sims to float tolerance; sentinel
    entries agree on the -2.0 sim — their index is implementation-
    defined on both sides)."""
    if batch:
        got = ivf.query_topk_batch(q_or_qs, k, guides_only=guides_only)
        want = ivf.exact_query_topk_batch(q_or_qs, k,
                                          guides_only=guides_only)
    else:
        got = ivf.query_topk(q_or_qs, k, guides_only=guides_only)
        want = ivf.exact_query_topk(q_or_qs, k, guides_only=guides_only)
    gs, ws = np.asarray(got.sim), np.asarray(want.sim)
    np.testing.assert_allclose(gs, ws, atol=1e-5)
    valid = ws > -2.0
    np.testing.assert_array_equal(np.asarray(got.index)[valid],
                                  np.asarray(want.index)[valid])
    np.testing.assert_array_equal(np.asarray(got.meta)[valid],
                                  np.asarray(want.meta)[valid])


# ---------------------------------------------------------------------------
# Route kernel: pallas (interpret) vs jnp oracle
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([9, 16, 33, 100]),     # P (odd → padding)
       st.sampled_from([1, 2, 4, 8]),         # n_probe
       st.sampled_from([1, 3, 16]),           # B
       st.sampled_from([0.0, 0.5, 1.0]))      # seeded density
def test_route_kernel_matches_oracle(seed, P, n_probe, B, density):
    rng = np.random.default_rng(seed)
    from repro.kernels.memory_topk import to_padded_layout
    cent = _protos(rng, P)
    bits = (rng.random(P) < density).astype(np.int32) * MASK_VALID
    centp, cmaskp = to_padded_layout(jnp.asarray(cent), jnp.asarray(bits),
                                     block_c=64)
    qs = jnp.asarray(_protos(rng, B))
    s_o, i_o = ref.ivf_route_batch_padded(centp, qs, cmaskp, n_probe)
    s_p, i_p = ivf_route_batch_padded_pallas(centp, qs, cmaskp,
                                             n_probe=n_probe, block_p=64,
                                             interpret=True)
    np.testing.assert_array_equal(np.asarray(i_o), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(s_o), np.asarray(s_p), atol=1e-6)
    s1_o, i1_o = ref.ivf_route_padded(centp, qs[0], cmaskp, n_probe)
    s1_p, i1_p = ivf_route_padded_pallas(centp, qs[0], cmaskp,
                                         n_probe=n_probe, block_p=64,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(i1_o), np.asarray(i1_p))
    np.testing.assert_allclose(np.asarray(s1_o), np.asarray(s1_p),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Hierarchical recall@k property suite vs. the exact-scan oracle
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([64, 100, 256]),       # C
       st.sampled_from([1, 2, 4]),            # k
       st.sampled_from([4, 8, 16]),           # clusters
       st.sampled_from([0.3, 0.8, 1.2]),      # fill fraction (>1 → wrap)
       st.booleans())                         # guides-only view
def test_property_all_probes_equals_exact(seed, C, k, clusters, fill,
                                          guides_only):
    """The exactness anchor: probes == clusters makes the two-level read
    reproduce the exhaustive scan on every valid entry — for partial
    fills, duplicate embeddings (tie-break), guides-only views, and
    ring-wrapped histories with stale member entries."""
    rng = np.random.default_rng(seed)
    store = mem.init_memory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                             guide_len=G))
    ivf = IVFMemory(store, clusters=clusters, probes=clusters)
    protos = _protos(rng, clusters)
    n = int(C * fill)
    if n:
        X = _clustered(rng, protos, n)
        if n >= 3:
            X[n // 2] = X[0]               # duplicate row → tied sims
        _fill(ivf, rng, X)
    qs = jnp.asarray(_clustered(rng, protos, 5))
    _assert_matches_exact(ivf, qs[0], k, guides_only=guides_only)
    _assert_matches_exact(ivf, qs, k, guides_only=guides_only, batch=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([4, 8]),               # probes < clusters
       st.sampled_from([1, 4]))               # k
def test_property_recall_on_clustered_data(seed, probes, k):
    """The recall@k knob on skill-structured data (the workload the
    plane serves — same-skill cosine ≈ 0.99): at probes ≥ 4 of 16
    clusters, recall against the exact oracle stays ≥ 0.9."""
    rng = np.random.default_rng(seed)
    C, clusters = 512, 16
    store = mem.init_memory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                             guide_len=G))
    ivf = IVFMemory(store, clusters=clusters, probes=probes)
    protos = _protos(rng, clusters)
    _fill(ivf, rng, _clustered(rng, protos, C), guide_frac=1.0)
    qr = jnp.asarray(_clustered(rng, protos, 32))
    got = np.asarray(ivf.query_topk_batch(qr, k).index)
    want = np.asarray(ivf.exact_query_topk_batch(qr, k).index)
    recall = np.mean([len(set(got[b]) & set(want[b])) / k
                      for b in range(len(qr))])
    assert recall >= 0.9, recall


# ---------------------------------------------------------------------------
# IVF-off byte-identity: the default constructs no wrapper at all
# ---------------------------------------------------------------------------


def test_default_config_wraps_nothing():
    from repro.core.rar import RARConfig
    cfg = RARConfig()
    assert cfg.retrieval_clusters == 0
    store = mem.init_memory(cfg.memory)
    assert wrap_store(store, cfg) is store


def test_ivf_off_query_path_bit_identical(rng):
    """With retrieval off the serve path runs the exact same dispatch as
    before this module existed: query results on an untouched store are
    bitwise equal whether or not the IVF module is imported/configured
    (the wrapper is never constructed — same object, same bytes)."""
    store = mem.init_memory(mem.MemoryConfig(capacity=64, embed_dim=E,
                                             guide_len=G))
    X = _clustered(rng, _protos(rng, 4), 40)
    store = mem.add_batch(store, jnp.asarray(X),
                          jnp.zeros((40, G), jnp.int32),
                          jnp.ones(40, bool), jnp.zeros(40, bool),
                          jnp.zeros(40, jnp.int32))
    from repro.core.rar import RARConfig
    wrapped = wrap_store(store, RARConfig())
    assert wrapped is store
    q = jnp.asarray(X[3])
    a = mem.query_topk(store, q, 4)
    b = mem.query_topk(wrapped, q, 4)
    np.testing.assert_array_equal(np.asarray(a.sim), np.asarray(b.sim))
    np.testing.assert_array_equal(np.asarray(a.meta), np.asarray(b.meta))
    ab = mem.query_topk_batch(store, jnp.asarray(X[:8]), 4)
    bb = mem.query_topk_batch(wrapped, jnp.asarray(X[:8]), 4)
    np.testing.assert_array_equal(np.asarray(ab.sim), np.asarray(bb.sim))
    np.testing.assert_array_equal(np.asarray(ab.meta), np.asarray(bb.meta))


def test_controller_default_keeps_raw_store():
    from repro.core.rar import RAR, RARConfig
    cfg = RARConfig(memory=mem.MemoryConfig(capacity=32, embed_dim=E,
                                            guide_len=G))
    rar = RAR(None, None, lambda p: None, lambda e, k: False, cfg)
    assert isinstance(rar.memory, mem.MemoryState)
    on = dataclasses.replace(cfg, retrieval_clusters=4, retrieval_probes=2)
    rar2 = RAR(None, None, lambda p: None, lambda e, k: False, on)
    assert isinstance(rar2.memory, IVFMemory)
    # idempotent: injecting an already-wrapped store wraps nothing new
    rar3 = RAR(None, None, lambda p: None, lambda e, k: False, on,
               memory=rar2.memory)
    assert rar3.memory is rar2.memory


# ---------------------------------------------------------------------------
# Sharded composition: per-shard centroid subsets merge bit-identically
# ---------------------------------------------------------------------------


def test_per_shard_route_merge_bit_identical(rng):
    """Cluster → shard placement: routing S per-shard centroid subsets
    and merging under the shared total order is bit-identical to routing
    the one global centroid plane (all clusters seeded — unseeded rows
    surface sentinels whose ids are implementation-defined)."""
    from repro.kernels.memory_topk import to_padded_layout
    P, S, n_probe = 16, 4, 4
    cent = _protos(rng, P)
    bits = np.full(P, MASK_VALID, np.int32)

    def plane(ids):
        cp, mp = to_padded_layout(jnp.asarray(cent[ids]),
                                  jnp.asarray(bits[ids]), block_c=64)
        return (cp, mp, jnp.asarray(ids.astype(np.int32)))

    global_plane = [plane(np.arange(P))]
    shard_planes = [plane(np.flatnonzero(np.arange(P) % S == s))
                    for s in range(S)]
    for trial in range(10):
        q = jnp.asarray(_protos(rng, 1)[0])
        sg, ig = jax.jit(
            lambda pl, q: _route_merged(pl, q, n_probe))(global_plane, q)
        ss, is_ = jax.jit(
            lambda pl, q: _route_merged(pl, q, n_probe))(shard_planes, q)
        np.testing.assert_array_equal(np.asarray(ig), np.asarray(is_))
        np.testing.assert_array_equal(np.asarray(sg), np.asarray(ss))


def test_sharded_backing_matches_exact(rng):
    """IVF over a ShardedMemory backing (single host device — the
    degenerate 1-shard mesh): all-probe reads equal the exact oracle."""
    from repro.core.memory_sharded import ShardedMemory
    C = 128
    sh = ShardedMemory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                        guide_len=G))
    ivf = IVFMemory(sh, clusters=8, probes=8)
    protos = _protos(rng, 8)
    _fill(ivf, rng, _clustered(rng, protos, C + 40))   # wraps the ring
    qs = jnp.asarray(_clustered(rng, protos, 6))
    _assert_matches_exact(ivf, qs[0], 4)
    _assert_matches_exact(ivf, qs, 4, batch=True)


# ---------------------------------------------------------------------------
# Grow-in-place capacity re-layout
# ---------------------------------------------------------------------------


def _store_with(rng, C, n):
    store = mem.init_memory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                             guide_len=G))
    X = _clustered(rng, _protos(rng, 4), n)
    for i in range(0, n, 16):
        xb = X[i:i + 16]
        store = mem.add_batch(
            store, jnp.asarray(xb),
            jnp.asarray(rng.integers(0, 50, size=(len(xb), G)).astype(
                np.int32)),
            jnp.ones(len(xb), bool), jnp.zeros(len(xb), bool),
            jnp.asarray(np.arange(i, i + len(xb)), np.int32))
    return store, X


def test_grow_unwrapped_preserves_slots_and_ptr(rng):
    store, X = _store_with(rng, 64, 40)              # ptr 40 <= C
    grown, remap = mem.grow_memory(store, 128)
    assert grown.capacity == 128
    assert int(grown.ptr) == 40
    np.testing.assert_array_equal(np.asarray(remap), np.arange(64))
    # occupied entries land on the SAME slots, bitwise
    np.testing.assert_array_equal(np.asarray(store.emb)[:40],
                                  np.asarray(grown.emb)[:40])
    np.testing.assert_array_equal(np.asarray(store.guide)[:40],
                                  np.asarray(grown.guide)[:40])
    np.testing.assert_array_equal(np.asarray(store.mask)[:40, 0],
                                  np.asarray(grown.mask)[:40, 0])
    assert not np.asarray(grown.valid)[40:].any()


def test_grow_wrapped_linearizes_oldest_first(rng):
    C = 64
    store, X = _store_with(rng, C, 100)              # ptr 100 > C: wrapped
    grown, remap = mem.grow_memory(store, 128)
    assert int(grown.ptr) == C                       # linearized: oldest=0
    old_emb = np.asarray(store.emb)
    new_emb = np.asarray(grown.emb)
    old_t = np.asarray(store.added_at)
    new_t = np.asarray(grown.added_at)
    r = np.asarray(remap)
    for s in range(C):                               # entry follows remap
        np.testing.assert_array_equal(old_emb[s], new_emb[r[s]])
        assert old_t[s] == new_t[r[s]]
    assert (np.diff(new_t[:C]) >= 0).all()           # oldest-first order
    # growing again (now unwrapped) keeps continuing writes exact
    again, remap2 = mem.grow_memory(grown, 256)
    np.testing.assert_array_equal(np.asarray(remap2), np.arange(128))


def test_grow_smaller_rejected(rng):
    store, _ = _store_with(rng, 64, 10)
    with pytest.raises(ValueError):
        mem.grow_memory(store, 32)


def test_commit_stream_grow_rebases_and_refuses_pending(rng):
    class View:
        pass

    store, _ = _store_with(rng, 64, 40)
    stream = mem.CommitStream()
    v = View()
    v.memory = store
    v._ptr_base = 40
    stream.subscribe(v)
    # staged-but-undrained ops must block the re-layout
    stream.buffer.stage_add(np.zeros(E, np.float32),
                            np.zeros(G, np.int32), True, False, 0)
    with pytest.raises(RuntimeError):
        stream.grow(store, 128)
    stream.buffer.take_ops()                         # drain the epoch
    grown, remap = stream.grow(store, 128)
    assert v.memory is grown
    assert v._ptr_base == 40 - stream.commits
    # post-grow eviction guards: a snapshot taken now covers exactly the
    # inserts that follow it
    buf = mem.CommitBuffer()
    snap = int(grown.ptr)
    state2 = grown
    for j in range(3):
        buf.stage_add(np.zeros(E, np.float32),
                      np.zeros(G, np.int32), True, False, j)
    buf.stage_soft_clear(5, 9, ptr_snapshot=snap)    # slot 5 < 40: safe
    buf.stage_soft_clear(41, 9, ptr_snapshot=snap)   # slot 41: evicted
    state2, n = buf.apply(state2)
    assert n == 3


def test_ivf_grow_requeries_exact(rng):
    C = 64
    store = mem.init_memory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                             guide_len=G))
    ivf = IVFMemory(store, clusters=8, probes=8)
    protos = _protos(rng, 8)
    _fill(ivf, rng, _clustered(rng, protos, C + 24))  # wrapped ring
    ivf2, remap = ivf.grow(2 * C)
    assert ivf2 is ivf and ivf.capacity == 2 * C
    _fill(ivf, rng, _clustered(rng, protos, 32))      # grow-in-place: keep
    qs = jnp.asarray(_clustered(rng, protos, 4))
    _assert_matches_exact(ivf, qs[0], 4)
    _assert_matches_exact(ivf, qs, 4, batch=True)


# ---------------------------------------------------------------------------
# Host-offload tiering
# ---------------------------------------------------------------------------


def test_offload_parity_and_traffic_split(rng):
    C, P = 128, 8
    store = mem.init_memory(mem.MemoryConfig(capacity=C, embed_dim=E,
                                             guide_len=G))
    hot = IVFMemory(store, clusters=P, probes=1)
    cold = IVFMemory(store, clusters=P, probes=1, offload=True,
                     cold_after=4)
    protos = _protos(rng, P)
    X = _clustered(rng, protos, C)
    _fill(hot, np.random.default_rng(7), X)          # identical metadata
    _fill(cold, np.random.default_rng(7), X)
    qa = jnp.asarray(_clustered(rng, protos[:1], 1)[0])
    for _ in range(10):                 # cluster 0 stays hot, rest cool
        a, b = hot.query_topk(qa, 3), cold.query_topk(qa, 3)
        np.testing.assert_array_equal(np.asarray(a.sim), np.asarray(b.sim))
    qb = jnp.asarray(_clustered(rng, protos[5:6], 1)[0])
    a, b = hot.query_topk(qb, 3), cold.query_topk(qb, 3)
    np.testing.assert_array_equal(np.asarray(a.sim), np.asarray(b.sim))
    valid = np.asarray(a.sim) > -2.0
    np.testing.assert_array_equal(np.asarray(a.index)[valid],
                                  np.asarray(b.index)[valid])
    np.testing.assert_array_equal(np.asarray(a.meta)[valid],
                                  np.asarray(b.meta)[valid])
    s = cold.stats()
    assert s["host_fetch_rows"] > 0     # the cold probe paid a host fetch
    assert s["device_fetch_rows"] > 0
    assert s["cold_clusters"] > 0


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------


def test_k_beyond_probe_budget_rejected(rng):
    store = mem.init_memory(mem.MemoryConfig(capacity=64, embed_dim=E,
                                             guide_len=G))
    ivf = IVFMemory(store, clusters=8, probes=1, bucket_cap=8)
    with pytest.raises(ValueError, match="candidate budget"):
        ivf.query_topk(jnp.asarray(_protos(rng, 1)[0]), 9)


def test_config_validation():
    from repro.core.rar import RARConfig
    cfg = mem.MemoryConfig(capacity=64, embed_dim=E, guide_len=G)
    with pytest.raises(ValueError):
        RARConfig(memory=cfg, retrieval_clusters=-1)
    with pytest.raises(ValueError):
        RARConfig(memory=cfg, retrieval_clusters=128)
    with pytest.raises(ValueError):
        RARConfig(memory=cfg, retrieval_clusters=8, retrieval_probes=0)
    with pytest.raises(ValueError):
        RARConfig(memory=cfg, retrieval_clusters=8, retrieval_probes=9)
    with pytest.raises(ValueError, match="journal"):
        RARConfig(memory=cfg, retrieval_clusters=8, journal_path="/tmp/x")
    with pytest.raises(TypeError):
        store = mem.init_memory(cfg)
        IVFMemory(IVFMemory(store, clusters=4), clusters=4)


def test_double_wrap_is_identity():
    from repro.core.rar import RARConfig
    cfg = RARConfig(memory=mem.MemoryConfig(capacity=64, embed_dim=E,
                                            guide_len=G),
                    retrieval_clusters=8, retrieval_probes=4)
    store = mem.init_memory(cfg.memory)
    w1 = wrap_store(store, cfg)
    assert isinstance(w1, IVFMemory)
    assert wrap_store(w1, cfg) is w1
