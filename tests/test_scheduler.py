"""Continuous-batching admission scheduler: loadgen determinism, batch-
formation properties (no drop / no within-stream reorder / atomic batch
dispatch / SLO budgets / bucket purity), and the determinism pin — the
same seeded arrival trace through the batcher yields byte-identical
routing decisions and strong-call counts to a closed-loop reference
run, for any ``slo_ms``, any priority mix, on both the threaded and the
process fabric. Plus one seeded open-loop soak with a mid-run worker
kill (the chaos-job entry point)."""
import os

import numpy as np
import pytest
from test_fabric import build_fabric
from test_procfabric import build_proc, _calls
from test_rar_controller import greq, prompt, skill_emb

from repro.serving.loadgen import (ArrivalEvent, bursty_trace,
                                   poisson_trace, trace_replay)
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import ContinuousBatcher, Request, serve_trace


# ---------------------------------------------------------------------------
# Load generator
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic_and_well_formed():
    a = poisson_trace(40, 25.0, seed=7, streams=4, priorities=[0, 1],
                      deadline_ms=80.0)
    b = poisson_trace(40, 25.0, seed=7, streams=4, priorities=[0, 1],
                      deadline_ms=80.0)
    assert a == b                                   # same seed, same bytes
    assert a != poisson_trace(40, 25.0, seed=8, streams=4,
                              priorities=[0, 1], deadline_ms=80.0)
    assert [e.index for e in a] == list(range(40))
    assert all(a[i].t <= a[i + 1].t for i in range(len(a) - 1))
    assert all(e.t > 0 for e in a)
    # round-robin split of an int total, priorities cycled per stream
    per = [sum(1 for e in a if e.stream == j) for j in range(4)]
    assert per == [10, 10, 10, 10]
    assert all(e.priority == e.stream % 2 for e in a)
    assert all(e.deadline_ms == 80.0 for e in a)


def test_poisson_trace_per_stream_counts_and_rates():
    t = poisson_trace([3, 5], 10.0, seed=0, streams=2, rates=[5.0, 50.0])
    assert [sum(1 for e in t if e.stream == j) for j in range(2)] == [3, 5]
    # rate is an honest long-run mean: high-rate stream finishes sooner
    big = poisson_trace([500, 500], 10.0, seed=1, streams=2,
                        rates=[5.0, 50.0])
    last = [max(e.t for e in big if e.stream == j) for j in range(2)]
    assert last[1] < last[0]


def test_bursty_trace_mean_rate_preserved_and_clustered():
    n, rate = 2000, 100.0
    t = bursty_trace(n, rate, seed=3, burst=3.0, duty=0.25)
    assert t == bursty_trace(n, rate, seed=3, burst=3.0, duty=0.25)
    span = t[-1].t
    realized = n / span
    assert 0.8 * rate < realized < 1.25 * rate      # thinning keeps the mean
    # burstiness: inter-arrival squared-CV well above the Poisson 1.0
    gaps = np.diff([e.t for e in t])
    cv2 = float(np.var(gaps) / np.mean(gaps) ** 2)
    assert cv2 > 1.2


def test_bursty_trace_rejects_impossible_duty_cycle():
    with pytest.raises(ValueError):
        bursty_trace(10, 5.0, burst=5.0, duty=0.5)  # burst*duty > 1


def test_trace_replay_normalises_and_validates():
    r = trace_replay([(0.5, 1), {"t": 0.1, "stream": 0, "priority": 2,
                                 "deadline_ms": 9.0},
                      ArrivalEvent(t=0.3, stream=2)])
    assert [e.t for e in r] == [0.1, 0.3, 0.5]
    assert [e.index for e in r] == [0, 1, 2]
    assert r[0].priority == 2 and r[0].deadline_ms == 9.0
    assert r[2].stream == 1
    with pytest.raises(ValueError):
        trace_replay([(-1.0, 0)])


# ---------------------------------------------------------------------------
# Batch-formation properties (recording fake fabric — no controller)
# ---------------------------------------------------------------------------


class _FakeTicket:
    def __init__(self, n):
        self.n = n

    def wait(self, timeout=None):
        return [None] * self.n


class _FakeFabric:
    """Records every submit; enough surface for the batcher."""

    replicas = [0, 1]

    def __init__(self):
        self.submits = []

    def submit(self, prompts, guide_requests, keys=None, embs=None,
               replica=None):
        self.submits.append({"keys": list(keys), "replica": replica,
                             "lens": [len(p) for p in prompts]})
        return _FakeTicket(len(prompts))


def _random_requests(rng, n, streams, lengths=(3,), deadline_frac=0.0):
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.01))
        reqs.append(Request(
            arrival_s=t, stream=int(rng.integers(0, streams)),
            prompt=[0] * int(rng.choice(lengths)), guide_request=None,
            priority=int(rng.integers(0, 3)),
            deadline_ms=(float(rng.uniform(5, 50))
                         if rng.random() < deadline_frac else None),
            key=i, index=i))
    return reqs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("slo_ms", [None, 4.0, 40.0])
def test_batcher_properties_random_traces(seed, slo_ms):
    """For random traces at any SLO: every admitted request dispatches
    exactly once, batches respect the size cap and length buckets,
    within-stream dispatch preserves arrival order, a batch dispatches
    atomically at close (no late joiners), and no request overstays a
    finite queueing budget."""
    rng = np.random.default_rng(seed)
    fab = _FakeFabric()
    bat = ContinuousBatcher(fab, microbatch=4, slo_ms=slo_ms,
                            registry=MetricsRegistry())
    reqs = _random_requests(rng, 80, streams=3, lengths=(3, 5),
                            deadline_frac=0.25)
    for r in reqs:
        bat.admit(r)
    bat.flush()
    # no drop, no duplicate: every key dispatched exactly once
    dispatched = [k for s in fab.submits for k in s["keys"]]
    assert sorted(dispatched) == list(range(80))
    for s in fab.submits:
        assert 1 <= len(s["keys"]) <= 4             # size cap
        assert len(set(s["lens"])) == 1             # one length bucket
    # atomic close: a request's batch contains it when it dispatches,
    # and each batch id dispatches exactly once
    ids = [d.batch_id for d in bat.dispatches]
    assert len(ids) == len(set(ids))
    for d in bat.dispatches:
        assert all(r.batch_id == d.batch_id for r in d.requests)
    # within-stream order: dispatch sequence preserves arrival order
    for j in range(3):
        seq = [r.index for d in bat.dispatches for r in d.requests
               if r.stream == j]
        assert seq == sorted(seq)
    # a stream's requests always target the same replica
    for j in range(3):
        assert len({d.replica for d in bat.dispatches
                    for r in d.requests if r.stream == j}) == 1
    # budget respected: dispatch never breaches a finite queueing budget
    for d in bat.dispatches:
        for r in d.requests:
            budget = (r.deadline_ms / 1e3 if r.deadline_ms is not None
                      else (slo_ms / 1e3) / (1 + r.priority)
                      if slo_ms is not None else float("inf"))
            assert r.dispatch_s - r.arrival_s <= budget + 1e-9


def test_slo_close_fires_at_the_oldest_members_deadline():
    fab = _FakeFabric()
    bat = ContinuousBatcher(fab, microbatch=8, slo_ms=20.0,
                            registry=MetricsRegistry())
    bat.admit(Request(arrival_s=0.0, stream=0, prompt=[0] * 3,
                      guide_request=None, key=0, index=0))
    bat.admit(Request(arrival_s=0.015, stream=0, prompt=[0] * 3,
                      guide_request=None, key=1, index=1))
    # nothing due yet; the next arrival pushes the clock past 20 ms
    assert not fab.submits
    bat.admit(Request(arrival_s=0.05, stream=0, prompt=[0] * 3,
                      guide_request=None, key=2, index=2))
    assert len(fab.submits) == 1
    assert fab.submits[0]["keys"] == [0, 1]
    d = bat.dispatches[0]
    assert d.reason == ContinuousBatcher.CLOSE_SLO
    assert d.dispatch_s == pytest.approx(0.020)     # oldest arrival + SLO
    assert bat.closes["slo"] == 1


def test_priority_tightens_the_queueing_budget():
    fab = _FakeFabric()
    bat = ContinuousBatcher(fab, microbatch=8, slo_ms=40.0,
                            registry=MetricsRegistry())
    bat.admit(Request(arrival_s=0.0, stream=0, prompt=[0] * 3,
                      guide_request=None, priority=3, key=0, index=0))
    bat.advance(0.011)                              # 40/(1+3) = 10 ms budget
    assert len(fab.submits) == 1
    assert bat.dispatches[0].dispatch_s == pytest.approx(0.010)


def test_bucket_switch_closes_the_streams_previous_batch():
    """Per-stream FIFO across buckets: when a stream's next request
    lands in a different length bucket, the batch holding its previous
    request dispatches first — a stream can never have two open batches
    in flight."""
    fab = _FakeFabric()
    bat = ContinuousBatcher(fab, microbatch=8, slo_ms=None,
                            registry=MetricsRegistry())
    bat.admit(Request(arrival_s=0.0, stream=0, prompt=[0] * 3,
                      guide_request=None, key=0, index=0))
    bat.admit(Request(arrival_s=0.001, stream=0, prompt=[0] * 7,
                      guide_request=None, key=1, index=1))
    assert len(fab.submits) == 1                    # short-prompt batch
    assert fab.submits[0]["keys"] == [0]
    assert bat.dispatches[0].reason == ContinuousBatcher.CLOSE_STREAM
    bat.flush()
    assert [s["keys"] for s in fab.submits] == [[0], [1]]


def test_admit_rejects_time_travel():
    bat = ContinuousBatcher(_FakeFabric(), microbatch=4,
                            registry=MetricsRegistry())
    bat.admit(Request(arrival_s=1.0, stream=0, prompt=[0] * 3,
                      guide_request=None, key=0, index=0))
    with pytest.raises(ValueError):
        bat.admit(Request(arrival_s=0.5, stream=0, prompt=[0] * 3,
                          guide_request=None, key=1, index=1))


# ---------------------------------------------------------------------------
# Determinism pin: open-loop ≡ closed-loop routing, thread + process
# ---------------------------------------------------------------------------
#
# Stream content mirrors the throughput bench's sharding: each stream
# owns a disjoint skill set (cross-stream retrieval can't interact) and
# repeats a skill only after a full round (repeats never share a
# microbatch) — under those conditions the batch partition is free to
# vary with slo_ms / priorities while routing stays byte-identical.


MICROBATCH = 4
ROUND_SKILLS = 6                       # > MICROBATCH: repeats can't collide


def _stream_seqs(streams, reps=3):
    """Per-stream (skill, x) sequences over disjoint skill sets."""
    return [[(j * ROUND_SKILLS + k, rep)
             for rep in range(reps) for k in range(ROUND_SKILLS)]
            for j in range(streams)]


def _serve_closed(fab, seqs, replicas):
    """Closed-loop reference: per-stream pre-partitioned microbatches,
    stream j pinned to replica j % replicas."""
    tickets = []
    for j, seq in enumerate(seqs):
        for start in range(0, len(seq), MICROBATCH):
            chunk = seq[start:start + MICROBATCH]
            tickets.append((j, fab.submit(
                [prompt(s, x) for s, x in chunk],
                [greq(s) for s, _ in chunk], keys=chunk,
                embs=np.stack([skill_emb(s) for s, _ in chunk]),
                replica=j % replicas)))
    fab.flush_shadow(timeout=180)
    by_stream = [[] for _ in seqs]
    for j, t in tickets:
        by_stream[j] += t.wait(timeout=180)
    return by_stream


def _serve_open(fab, seqs, trace, replicas, slo_ms):
    """Open-loop: the k-th arrival of stream j serves that stream's
    k-th request, admitted through the batcher."""
    cursors = [0] * len(seqs)
    admitted = []

    def make_request(ev):
        s, x = seqs[ev.stream][cursors[ev.stream]]
        cursors[ev.stream] += 1
        admitted.append(ev.stream)
        return prompt(s, x), greq(s), (s, x), skill_emb(s)

    outs, batcher = serve_trace(
        fab, trace, make_request, microbatch=MICROBATCH, slo_ms=slo_ms,
        replica_fn=lambda s: s % replicas, timeout=180)
    fab.flush_shadow(timeout=180)
    by_stream = [[] for _ in seqs]
    for j, out in zip(admitted, outs):
        by_stream[j].append(out)
    return by_stream, batcher


@pytest.mark.parametrize("slo_ms", [None, 3.0, 500.0])
@pytest.mark.parametrize("priorities", [None, [0, 2]])
def test_openloop_pin_thread_fabric(slo_ms, priorities):
    """Any slo_ms × priority mix: same seeded trace → per-stream
    Outcome streams and strong/weak call counts byte-identical to the
    closed-loop reference (formation changes, routing cannot)."""
    streams = replicas = 2
    seqs = _stream_seqs(streams)
    ref = build_fabric(replicas, weak_known={0, 1})
    ref_outs = _serve_closed(ref, seqs, replicas)
    trace = poisson_trace([len(s) for s in seqs], 300.0, seed=11,
                          streams=streams, priorities=priorities)
    fab = build_fabric(replicas, weak_known={0, 1})
    outs, batcher = _serve_open(fab, seqs, trace, replicas, slo_ms)
    assert outs == ref_outs
    assert fab.learn.weak.engine.calls == ref.learn.weak.engine.calls
    assert fab.learn.strong.engine.calls == ref.learn.strong.engine.calls
    assert batcher.stats()["dispatched"] == sum(len(s) for s in seqs)
    # latency accounting reached the fabric's shared registry
    snap = fab.metrics()["registry"]
    assert snap["sched/queue_delay_ms"]["count"] == \
        sum(len(s) for s in seqs)
    assert "sched/stream1/e2e_ms" in snap
    ref.close_shadow()
    fab.close_shadow()


def test_openloop_same_trace_same_bytes_across_runs():
    """Run-to-run determinism of the full open-loop path: identical
    trace, identical outcomes and batch partition."""
    streams = replicas = 2
    seqs = _stream_seqs(streams)
    runs = []
    for _ in range(2):
        fab = build_fabric(replicas, weak_known={0, 1})
        trace = bursty_trace([len(s) for s in seqs], 200.0, seed=5,
                             streams=streams)
        outs, batcher = _serve_open(fab, seqs, trace, replicas, 15.0)
        runs.append((outs, [d.batch_id for d in batcher.dispatches],
                     [len(d.requests) for d in batcher.dispatches],
                     batcher.closes.copy()))
        fab.close_shadow()
    assert runs[0] == runs[1]


@pytest.mark.parametrize("slo_ms", [None, 10.0])
def test_openloop_pin_process_fabric(slo_ms):
    """The same pin across the process boundary: open-loop through
    ``ProcessServingFabric`` matches the threaded closed-loop reference
    outcome-for-outcome and call-for-call."""
    streams = workers = 2
    seqs = _stream_seqs(streams, reps=2)
    ref = build_fabric(workers, weak_known={0, 1})
    ref_outs = _serve_closed(ref, seqs, workers)
    trace = poisson_trace([len(s) for s in seqs], 400.0, seed=23,
                          streams=streams, priorities=[0, 1])
    fab = build_proc(workers, weak_known={0, 1})
    try:
        outs, _ = _serve_open(fab, seqs, trace, workers, slo_ms)
        assert outs == ref_outs
        assert _calls(fab, "weak") == ref.learn.weak.engine.calls
        assert _calls(fab, "strong") == ref.learn.strong.engine.calls
    finally:
        fab.close_shadow()
        ref.close_shadow()


def test_openloop_soak_survives_worker_kill():
    """Chaos entry point: a seeded open-loop trace through the batcher
    with a mid-run SIGKILL of worker 1 — every request resolves, the
    outcomes match a kill-free run byte-for-byte (redispatch is exact),
    and supervision actually exercised (death + restart)."""
    from repro.serving.faults import FaultPlan
    seed = int(os.environ.get("REPRO_SOAK_SEED", "0"))
    streams = workers = 2
    seqs = _stream_seqs(streams, reps=2)
    trace = poisson_trace([len(s) for s in seqs], 250.0, seed=seed,
                          streams=streams)

    def run(fault_plan):
        fab = build_proc(workers, weak_known={0, 1},
                         fault_plan=fault_plan, lease_interval=0.1,
                         lease_timeout=8.0)
        try:
            outs, batcher = _serve_open(fab, seqs, trace, workers, 10.0)
            return outs, batcher.stats(), fab.deaths, fab.restarts
        finally:
            fab.close_shadow()

    clean_outs, clean_stats, _, _ = run(None)
    plan = FaultPlan([FaultPlan.replica_kill(1, at=2)])
    kill_outs, kill_stats, deaths, restarts = run(plan)
    assert kill_outs == clean_outs
    assert kill_stats == clean_stats        # formation is trace-only
    assert deaths >= 1 and deaths == restarts
    total = sum(len(s) for s in seqs)
    assert sum(len(o) for o in kill_outs) == total
