"""Sharding rule logic (pure PartitionSpec computation, no devices)."""
import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import _param_prefs, spec_from_prefs


@dataclasses.dataclass
class FakeMesh:
    shape: dict


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def spec(shape, prefs, mesh=MESH, offset=0):
    return spec_from_prefs(shape, prefs, mesh, offset=offset)


def test_divisible_dims_assigned():
    assert spec((4096, 32, 128), [(1, "model")]) == P(None, "model", None)


def test_nondivisible_falls_back():
    # 24 heads don't divide 16 → fall through to d_model
    s = spec((1536, 24, 64), [(1, "model"), (0, "model")])
    assert s == P("model", None, None)


def test_nothing_divides_replicates():
    s = spec((7, 3), [(0, "model"), (1, "model"), (0, "data")])
    assert s == P(None, None)


def test_axis_used_once():
    s = spec((64, 64), [(0, "model"), (1, "model")])
    assert s == P("model", None)


def test_dim_assigned_once():
    s = spec((64, 32), [(0, "model"), (0, "data"), (1, "data")])
    assert s == P("model", "data")


def test_tuple_axis_multipod():
    s = spec((256, 4096), [(0, ("pod", "data"))], mesh=POD)
    assert s == P(("pod", "data"), None)
    # batch 1 can't shard over 32
    s = spec((1, 4096), [(0, ("pod", "data"))], mesh=POD)
    assert s == P(None, None)


def test_stacked_offset_shifts_dims():
    # stacked layer param (L, D, H, hd): rules written for (D, H, hd)
    s = spec((32, 4096, 32, 128), [(1, "model")], offset=1)
    assert s == P(None, None, "model", None)


def test_train_mode_adds_fsdp_axis():
    prefs = _param_prefs("w_up", 2, "train", MESH)
    s = spec((4096, 14336), prefs)
    assert s == P("data", "model")


def test_serve_mode_weights_replicated_on_data():
    prefs = _param_prefs("w_up", 2, "serve", MESH)
    s = spec((4096, 14336), prefs)
    assert s == P(None, "model")


def test_moe_expert_parallel_when_divisible():
    prefs = _param_prefs("w_gate", 3, "serve", MESH)  # (E, D, F)
    assert spec((64, 2048, 1024), prefs) == P("model", None, None)
    # 40 experts don't divide 16 → F (=512/16) carries model parallelism
    assert spec((40, 1536, 512), prefs) == P(None, None, "model")


def test_norm_scales_replicated():
    prefs = _param_prefs("scale", 1, "train", MESH)
    assert spec((4096,), prefs) == P(None)


def test_embed_vocab_sharding():
    prefs = _param_prefs("embed", 2, "serve", MESH)
    assert spec((128256, 4096), prefs) == P("model", None)
    # 49155 (granite) doesn't divide 16 → replicated row dim
    assert spec((49155, 1536), prefs) == P(None, None)
