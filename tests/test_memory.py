"""Skill/guide memory: unit tests + hypothesis properties over the store
invariants (retrieval, thresholds, FIFO eviction, flag semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import memory as mem

CFG = mem.MemoryConfig(capacity=32, embed_dim=16, guide_len=4)


def unit(v):
    v = np.asarray(v, np.float32)
    return v / max(np.linalg.norm(v), 1e-9)


def rand_unit(rng, d=16):
    return unit(rng.normal(size=d))


def test_empty_memory_returns_sentinel(rng):
    state = mem.init_memory(CFG)
    q = mem.query(state, jnp.asarray(rand_unit(rng)))
    assert float(q.sim) == -2.0


def test_add_then_query_exact(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    g = jnp.asarray([5, 1, 2, 6], jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(3))
    q = mem.query(state, jnp.asarray(e))
    assert float(q.sim) > 0.999
    assert bool(q.has_guide) and not bool(q.hard)
    assert int(q.added_at) == 3
    np.testing.assert_array_equal(np.asarray(q.guide), [5, 1, 2, 6])


def test_guides_only_view(rng):
    state = mem.init_memory(CFG)
    e1, e2 = rand_unit(rng), rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e1), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))     # bare skill
    state = mem.add(state, jnp.asarray(e2), zero_g + 7, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(0))     # guide entry
    q = mem.query(state, jnp.asarray(e1), guides_only=True)
    # the only guide entry must win, even though e1 matches a bare entry
    assert bool(q.has_guide)
    np.testing.assert_allclose(float(q.sim), float(e1 @ e2), atol=1e-5)


def test_fifo_eviction(rng):
    state = mem.init_memory(CFG)
    first = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(first), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))
    for i in range(CFG.capacity):   # fill past capacity → evicts `first`
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i + 1))
    q = mem.query(state, jnp.asarray(first))
    assert float(q.sim) < 0.999     # exact row is gone


def test_mark_soft_and_touch(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                    jnp.asarray(True), jnp.int32(1))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.hard)
    state = mem.touch(state, q.index, jnp.int32(9))
    assert int(mem.query(state, jnp.asarray(e)).added_at) == 9
    state = mem.mark_soft(state, q.index)
    assert not bool(mem.query(state, jnp.asarray(e)).hard)


def _batch_of(rng, k, d=16):
    embs = np.stack([rand_unit(rng, d) for _ in range(k)])
    guides = np.arange(4 * k, dtype=np.int32).reshape(k, 4)
    has_guide = (np.arange(k) % 2).astype(bool)
    hard = (np.arange(k) % 3 == 0)
    now = np.arange(k, dtype=np.int32) + 1
    return embs, guides, has_guide, hard, now


def test_add_batch_equals_sequential_adds(rng):
    """add_batch(K entries) == K sequential add() calls, field for field."""
    embs, guides, has_guide, hard, now = _batch_of(rng, 5)
    seq = mem.init_memory(CFG)
    for j in range(5):
        seq = mem.add(seq, jnp.asarray(embs[j]), jnp.asarray(guides[j]),
                      jnp.asarray(has_guide[j]), jnp.asarray(hard[j]),
                      jnp.int32(now[j]))
    bat = mem.add_batch(mem.init_memory(CFG), jnp.asarray(embs),
                        jnp.asarray(guides), jnp.asarray(has_guide),
                        jnp.asarray(hard), jnp.asarray(now))
    for f in ("emb", "guide", "has_guide", "hard", "valid", "added_at",
              "ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(seq, f)),
                                      np.asarray(getattr(bat, f)), f)


def test_add_batch_ring_wraparound(rng):
    """A commit crossing the ring end wraps to the start, matching the
    sequential FIFO semantics."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    for i in range(CFG.capacity - 2):        # leave 2 free slots
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False), jnp.int32(i))
    embs, guides, has_guide, hard, now = _batch_of(rng, 5)
    state = mem.add_batch(state, jnp.asarray(embs), jnp.asarray(guides),
                          jnp.asarray(has_guide), jnp.asarray(hard),
                          jnp.asarray(now))
    # slots C-2, C-1 then 0, 1, 2 hold the batch (emb rows live in the
    # padded kernel layout: logical lanes first, zero padding after)
    slots = [CFG.capacity - 2, CFG.capacity - 1, 0, 1, 2]
    emb_rows = np.asarray(state.emb)[slots]
    np.testing.assert_array_equal(emb_rows[:, :CFG.embed_dim], embs)
    assert not emb_rows[:, CFG.embed_dim:].any()
    np.testing.assert_array_equal(np.asarray(state.added_at)[slots], now)
    assert int(state.ptr) == CFG.capacity + 3
    assert state.size_fast == CFG.capacity       # full ring
    assert state.debug_size() == CFG.capacity    # slow path agrees


def test_add_batch_rejects_overflow(rng):
    embs, guides, has_guide, hard, now = _batch_of(rng, CFG.capacity + 1)
    with pytest.raises(ValueError):
        mem.add_batch(mem.init_memory(CFG), jnp.asarray(embs),
                      jnp.asarray(guides), jnp.asarray(has_guide),
                      jnp.asarray(hard), jnp.asarray(now))


def test_size_fast_matches_size(rng):
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    assert state.size_fast == state.debug_size() == 0
    for i in range(CFG.capacity + 5):
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i))
        assert state.size_fast == state.debug_size()


def test_query_batch_matches_query(rng):
    state = mem.init_memory(CFG)
    for j in range(10):
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.asarray(np.full(4, j, np.int32)),
                        jnp.asarray(j % 2 == 0), jnp.asarray(j % 3 == 0),
                        jnp.int32(j))
    qs = np.stack([rand_unit(rng) for _ in range(6)])
    for guides_only in (False, True):
        qb = mem.query_batch(state, jnp.asarray(qs),
                             guides_only=guides_only)
        for b in range(6):
            q1 = mem.query(state, jnp.asarray(qs[b]),
                           guides_only=guides_only)
            assert int(q1.index) == int(np.asarray(qb.index)[b])
            np.testing.assert_allclose(float(q1.sim),
                                       float(np.asarray(qb.sim)[b]),
                                       atol=1e-6)
            np.testing.assert_array_equal(np.asarray(q1.guide),
                                          np.asarray(qb.guide)[b])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_property_best_match_wins(seeds, qseed):
    """query() returns the stored row with the max cosine (vs. numpy)."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    rows = []
    for i, s in enumerate(seeds):
        e = rand_unit(np.random.default_rng(s))
        rows.append(e)
        state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                        jnp.asarray(False), jnp.int32(i))
    q_emb = rand_unit(np.random.default_rng(qseed))
    q = mem.query(state, jnp.asarray(q_emb))
    kept = rows[-CFG.capacity:]                 # FIFO keeps the tail
    expect = max(float(np.dot(r, q_emb)) for r in kept)
    np.testing.assert_allclose(float(q.sim), expect, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans(), st.booleans())
def test_property_flags_roundtrip(seed, has_guide, hard):
    state = mem.init_memory(CFG)
    e = rand_unit(np.random.default_rng(seed))
    g = jnp.arange(4, dtype=jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(has_guide),
                    jnp.asarray(hard), jnp.int32(5))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.has_guide) == has_guide
    assert bool(q.hard) == hard


# ---------------------------------------------------------------------------
# Padded-layout invariants and edge cases
# ---------------------------------------------------------------------------


def test_empty_store_query_batch():
    """query_batch on a never-written store returns the sentinel for every
    query, with index 0 and empty metadata."""
    state = mem.init_memory(CFG)
    qs = np.eye(4, CFG.embed_dim, dtype=np.float32)
    q = mem.query_batch(state, jnp.asarray(qs)).device_get()
    np.testing.assert_array_equal(q.sim, np.full(4, -2.0))
    np.testing.assert_array_equal(q.index, np.zeros(4))
    assert not np.asarray(q.has_guide).any()
    assert not np.asarray(q.hard).any()


def test_guides_only_with_no_guide_entries(rng):
    """guides_only on a store holding only bare-skill entries must return
    the empty sentinel, not a bare entry."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    e = rand_unit(rng)
    for i in range(5):
        state = mem.add(state, jnp.asarray(rand_unit(rng) if i else e),
                        zero_g, jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i))
    q = mem.query(state, jnp.asarray(e), guides_only=True)
    assert float(q.sim) == -2.0
    qb = mem.query_batch(state, jnp.asarray(e)[None], guides_only=True)
    assert float(np.asarray(qb.sim)[0]) == -2.0
    # the unrestricted view still finds the exact hit
    assert float(mem.query(state, jnp.asarray(e)).sim) > 0.999


def test_padded_layout_invariants(rng):
    """emb stays permanently in kernel layout: rows a multiple of the row
    tile, lanes a multiple of 128, padding always zero, mask bit plane in
    sync with the valid/has_guide views."""
    from repro.kernels.memory_topk import (MASK_GUIDE, MASK_VALID,
                                           padded_lanes, padded_rows)

    state = mem.init_memory(CFG)
    C, E = CFG.capacity, CFG.embed_dim
    assert state.emb.shape == (padded_rows(C), padded_lanes(E))
    assert state.mask.shape == (padded_rows(C), 1)
    for i in range(C + 3):       # through a wraparound
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.zeros(4, jnp.int32), jnp.asarray(i % 2 == 0),
                        jnp.asarray(False), jnp.int32(i))
        emb = np.asarray(state.emb)
        bits = np.asarray(state.mask)[:, 0]
        assert not emb[:, E:].any()          # lane padding stays zero
        assert not emb[C:].any()             # row padding stays zero
        assert not bits[C:].any()            # padding rows never valid
        np.testing.assert_array_equal((bits[:C] & MASK_VALID) != 0,
                                      np.asarray(state.valid))
        np.testing.assert_array_equal((bits[:C] & MASK_GUIDE) != 0,
                                      np.asarray(state.has_guide))


def test_padded_oracle_matches_legacy_oracle(rng):
    """ref.memory_top1_padded on the persistent layout == ref.memory_top1
    on the compact store, for both mask views (the padded/legacy oracle
    equivalence that keeps CPU CI honest about the TPU kernel contract)."""
    from repro.kernels import ref
    from repro.kernels.memory_topk import MASK_GUIDE, MASK_VALID

    state = mem.init_memory(CFG)
    for j in range(20):
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.asarray(np.full(4, j, np.int32)),
                        jnp.asarray(j % 3 == 0), jnp.asarray(False),
                        jnp.int32(j))
    C, E = CFG.capacity, CFG.embed_dim
    compact = np.asarray(state.emb)[:C, :E]
    valid = np.asarray(state.valid)
    has_guide = np.asarray(state.has_guide)
    qs = np.stack([rand_unit(rng) for _ in range(5)])
    qs[0] = compact[7]                       # exact hit
    for required, legacy_mask in ((MASK_VALID, valid),
                                  (MASK_VALID | MASK_GUIDE,
                                   valid & has_guide)):
        for b in range(5):
            s_l, i_l = ref.memory_top1(jnp.asarray(compact),
                                       jnp.asarray(qs[b]),
                                       jnp.asarray(legacy_mask))
            s_p, i_p = ref.memory_top1_padded(state.emb, jnp.asarray(qs[b]),
                                              state.mask, required)
            assert int(i_l) == int(i_p)
            assert float(s_l) == float(s_p)
        s_l, i_l = ref.memory_top1_batch(jnp.asarray(compact),
                                         jnp.asarray(qs),
                                         jnp.asarray(legacy_mask))
        s_p, i_p = ref.memory_top1_batch_padded(state.emb, jnp.asarray(qs),
                                                state.mask, required)
        np.testing.assert_array_equal(np.asarray(i_l), np.asarray(i_p))
        np.testing.assert_array_equal(np.asarray(s_l), np.asarray(s_p))


# ---------------------------------------------------------------------------
# Top-k retrieval edge cases (the multi-guide read path)
# ---------------------------------------------------------------------------


def test_query_topk_empty_store():
    """Top-k on a never-written store: every slot is the -2.0 sentinel on
    the lowest store rows, with empty metadata — the k-deep analog of the
    top-1 empty-view sentinel."""
    state = mem.init_memory(CFG)
    q = mem.query_topk(state, jnp.zeros(CFG.embed_dim), 4).device_get()
    np.testing.assert_array_equal(q.sim, np.full(4, -2.0))
    np.testing.assert_array_equal(q.index, [0, 1, 2, 3])
    assert not np.asarray(q.has_guide).any()
    qb = mem.query_topk_batch(state, jnp.zeros((3, CFG.embed_dim)),
                              2).device_get()
    assert qb.sim.shape == (3, 2) and qb.meta.shape == (3, 2, 4 + 4)
    np.testing.assert_array_equal(qb.sim, np.full((3, 2), -2.0))


def test_query_topk_k_exceeds_valid_entries(rng):
    """k larger than the store population: the real entries come first
    (sorted), the rest degrade to the -2.0 sentinel."""
    state = mem.init_memory(CFG)
    embs = [rand_unit(rng) for _ in range(3)]
    for i, e in enumerate(embs):
        state = mem.add(state, jnp.asarray(e), jnp.zeros(4, jnp.int32),
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i))
    q = mem.query_topk(state, jnp.asarray(embs[0]), 8).device_get()
    assert float(q.sim[0]) > 0.999
    real = np.asarray(q.sim) > -2.0
    assert real[:3].all() and not real[3:].any()
    # the three real entries are exactly the three stored rows
    assert sorted(np.asarray(q.index)[:3]) == [0, 1, 2]


def test_query_topk_guides_only_fewer_guides_than_k(rng):
    """guides_only with fewer guide entries than k: only guide rows rank
    above the sentinel — bare-skill rows must not leak into the view."""
    state = mem.init_memory(CFG)
    for i in range(6):
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.asarray(np.full(4, i, np.int32)),
                        jnp.asarray(i < 2), jnp.asarray(False),
                        jnp.int32(i))       # only rows 0, 1 carry guides
    q = mem.query_topk(state, jnp.asarray(rand_unit(rng)), 5,
                       guides_only=True).device_get()
    real = np.asarray(q.sim) > -2.0
    assert real.sum() == 2
    assert sorted(np.asarray(q.index)[real]) == [0, 1]
    assert np.asarray(q.has_guide)[real].all()
    # unrestricted view over the same store fills all 5 slots
    q_all = mem.query_topk(state, jnp.asarray(rand_unit(rng)), 5)
    assert (np.asarray(q_all.sim) > -2.0).all()


def test_query_topk_after_add_batch_wraparound(rng):
    """Full-ring wraparound: after an add_batch past the ring end, top-k
    sees exactly the surviving entries (numpy cross-check on the full
    result, order included)."""
    state = mem.init_memory(CFG)
    C = CFG.capacity
    rows = []
    for i in range(C - 2):
        e = rand_unit(rng)
        rows.append(e)
        state = mem.add(state, jnp.asarray(e), jnp.zeros(4, jnp.int32),
                        jnp.asarray(False), jnp.asarray(False), jnp.int32(i))
    embs = np.stack([rand_unit(rng) for _ in range(5)])
    state = mem.add_batch(state, jnp.asarray(embs),
                          jnp.zeros((5, 4), jnp.int32),
                          jnp.zeros(5, bool), jnp.zeros(5, bool),
                          jnp.arange(5, dtype=jnp.int32))
    # ring now holds: slots 0..2 = batch tail, 3..C-3 = sequential tail,
    # C-2, C-1 = batch head
    expect = np.stack(rows)
    expect = np.concatenate([embs[2:], expect[3:], embs[:2]])
    assert state.size_fast == C
    q_emb = rand_unit(rng)
    k = 6
    q = mem.query_topk(state, jnp.asarray(q_emb), k).device_get()
    sims = expect.astype(np.float32) @ q_emb.astype(np.float32)
    order = sorted(range(C), key=lambda r: (-sims[r], r))[:k]
    np.testing.assert_array_equal(np.asarray(q.index), order)
    np.testing.assert_allclose(np.asarray(q.sim), sims[order], atol=1e-6)


def test_query_topk_rejects_bad_k(rng):
    state = mem.init_memory(CFG)
    with pytest.raises(ValueError):
        mem.query_topk(state, jnp.zeros(CFG.embed_dim), 0)
    with pytest.raises(ValueError):
        mem.query_topk(state, jnp.zeros(CFG.embed_dim), CFG.capacity + 1)
    # the bound is backend-independent: even when capacity allows it, k
    # beyond the kernel block is rejected at dispatch (the Pallas
    # accumulator must fit one grid-step merge; the ref oracle would
    # unroll k selection rounds)
    big = mem.init_memory(mem.MemoryConfig(capacity=2048, embed_dim=16,
                                           guide_len=4))
    with pytest.raises(ValueError):
        mem.query_topk(big, jnp.zeros(16), 1500)


def test_query_topk_k1_bit_identical_to_query(rng):
    """The k=1 top-k read IS the top-1 read: sims and packed metadata are
    bit-identical on the dispatch path, single and batched, both views."""
    state = mem.init_memory(CFG)
    for j in range(12):
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.asarray(np.full(4, j, np.int32)),
                        jnp.asarray(j % 2 == 0), jnp.asarray(j % 5 == 0),
                        jnp.int32(j))
    qs = np.stack([rand_unit(rng) for _ in range(6)])
    qs[0] = np.asarray(state.emb)[4, :CFG.embed_dim]
    for guides_only in (False, True):
        for b in range(6):
            a = mem.query(state, jnp.asarray(qs[b]),
                          guides_only=guides_only).device_get()
            bk = mem.query_topk(state, jnp.asarray(qs[b]), 1,
                                guides_only=guides_only).device_get()
            np.testing.assert_array_equal(np.asarray(a.sim),
                                          np.asarray(bk.sim)[0])
            np.testing.assert_array_equal(a.meta, bk.meta[0])
        a = mem.query_batch(state, jnp.asarray(qs),
                            guides_only=guides_only).device_get()
        bk = mem.query_topk_batch(state, jnp.asarray(qs), 1,
                                  guides_only=guides_only).device_get()
        np.testing.assert_array_equal(a.sim, bk.sim[:, 0])
        np.testing.assert_array_equal(a.meta, bk.meta[:, 0])


def test_query_result_single_transfer_struct(rng):
    """The fused epilogue packs everything into (sim, meta): field views
    agree before and after one device_get round-trip."""
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    state = mem.add(state, jnp.asarray(e), jnp.asarray([9, 8, 7, 6],
                                                       jnp.int32),
                    jnp.asarray(True), jnp.asarray(True), jnp.int32(42))
    q = mem.query(state, jnp.asarray(e))
    host = q.device_get()
    assert isinstance(host.sim, np.ndarray) or np.isscalar(host.sim)
    for field in ("index", "has_guide", "hard", "added_at", "guide"):
        np.testing.assert_array_equal(np.asarray(getattr(q, field)),
                                      np.asarray(getattr(host, field)),
                                      field)
    assert int(host.index) == 0 and bool(host.has_guide) \
        and bool(host.hard) and int(host.added_at) == 42
    np.testing.assert_array_equal(np.asarray(host.guide), [9, 8, 7, 6])
