"""Skill/guide memory: unit tests + hypothesis properties over the store
invariants (retrieval, thresholds, FIFO eviction, flag semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import memory as mem

CFG = mem.MemoryConfig(capacity=32, embed_dim=16, guide_len=4)


def unit(v):
    v = np.asarray(v, np.float32)
    return v / max(np.linalg.norm(v), 1e-9)


def rand_unit(rng, d=16):
    return unit(rng.normal(size=d))


def test_empty_memory_returns_sentinel(rng):
    state = mem.init_memory(CFG)
    q = mem.query(state, jnp.asarray(rand_unit(rng)))
    assert float(q.sim) == -2.0


def test_add_then_query_exact(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    g = jnp.asarray([5, 1, 2, 6], jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(3))
    q = mem.query(state, jnp.asarray(e))
    assert float(q.sim) > 0.999
    assert bool(q.has_guide) and not bool(q.hard)
    assert int(q.added_at) == 3
    np.testing.assert_array_equal(np.asarray(q.guide), [5, 1, 2, 6])


def test_guides_only_view(rng):
    state = mem.init_memory(CFG)
    e1, e2 = rand_unit(rng), rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e1), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))     # bare skill
    state = mem.add(state, jnp.asarray(e2), zero_g + 7, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(0))     # guide entry
    q = mem.query(state, jnp.asarray(e1), guides_only=True)
    # the only guide entry must win, even though e1 matches a bare entry
    assert bool(q.has_guide)
    np.testing.assert_allclose(float(q.sim), float(e1 @ e2), atol=1e-5)


def test_fifo_eviction(rng):
    state = mem.init_memory(CFG)
    first = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(first), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))
    for i in range(CFG.capacity):   # fill past capacity → evicts `first`
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i + 1))
    q = mem.query(state, jnp.asarray(first))
    assert float(q.sim) < 0.999     # exact row is gone


def test_mark_soft_and_touch(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                    jnp.asarray(True), jnp.int32(1))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.hard)
    state = mem.touch(state, q.index, jnp.int32(9))
    assert int(mem.query(state, jnp.asarray(e)).added_at) == 9
    state = mem.mark_soft(state, q.index)
    assert not bool(mem.query(state, jnp.asarray(e)).hard)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_property_best_match_wins(seeds, qseed):
    """query() returns the stored row with the max cosine (vs. numpy)."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    rows = []
    for i, s in enumerate(seeds):
        e = rand_unit(np.random.default_rng(s))
        rows.append(e)
        state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                        jnp.asarray(False), jnp.int32(i))
    q_emb = rand_unit(np.random.default_rng(qseed))
    q = mem.query(state, jnp.asarray(q_emb))
    kept = rows[-CFG.capacity:]                 # FIFO keeps the tail
    expect = max(float(np.dot(r, q_emb)) for r in kept)
    np.testing.assert_allclose(float(q.sim), expect, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans(), st.booleans())
def test_property_flags_roundtrip(seed, has_guide, hard):
    state = mem.init_memory(CFG)
    e = rand_unit(np.random.default_rng(seed))
    g = jnp.arange(4, dtype=jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(has_guide),
                    jnp.asarray(hard), jnp.int32(5))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.has_guide) == has_guide
    assert bool(q.hard) == hard
