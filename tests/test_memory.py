"""Skill/guide memory: unit tests + hypothesis properties over the store
invariants (retrieval, thresholds, FIFO eviction, flag semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import memory as mem

CFG = mem.MemoryConfig(capacity=32, embed_dim=16, guide_len=4)


def unit(v):
    v = np.asarray(v, np.float32)
    return v / max(np.linalg.norm(v), 1e-9)


def rand_unit(rng, d=16):
    return unit(rng.normal(size=d))


def test_empty_memory_returns_sentinel(rng):
    state = mem.init_memory(CFG)
    q = mem.query(state, jnp.asarray(rand_unit(rng)))
    assert float(q.sim) == -2.0


def test_add_then_query_exact(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    g = jnp.asarray([5, 1, 2, 6], jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(3))
    q = mem.query(state, jnp.asarray(e))
    assert float(q.sim) > 0.999
    assert bool(q.has_guide) and not bool(q.hard)
    assert int(q.added_at) == 3
    np.testing.assert_array_equal(np.asarray(q.guide), [5, 1, 2, 6])


def test_guides_only_view(rng):
    state = mem.init_memory(CFG)
    e1, e2 = rand_unit(rng), rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e1), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))     # bare skill
    state = mem.add(state, jnp.asarray(e2), zero_g + 7, jnp.asarray(True),
                    jnp.asarray(False), jnp.int32(0))     # guide entry
    q = mem.query(state, jnp.asarray(e1), guides_only=True)
    # the only guide entry must win, even though e1 matches a bare entry
    assert bool(q.has_guide)
    np.testing.assert_allclose(float(q.sim), float(e1 @ e2), atol=1e-5)


def test_fifo_eviction(rng):
    state = mem.init_memory(CFG)
    first = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(first), zero_g, jnp.asarray(False),
                    jnp.asarray(False), jnp.int32(0))
    for i in range(CFG.capacity):   # fill past capacity → evicts `first`
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i + 1))
    q = mem.query(state, jnp.asarray(first))
    assert float(q.sim) < 0.999     # exact row is gone


def test_mark_soft_and_touch(rng):
    state = mem.init_memory(CFG)
    e = rand_unit(rng)
    zero_g = jnp.zeros(4, jnp.int32)
    state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                    jnp.asarray(True), jnp.int32(1))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.hard)
    state = mem.touch(state, q.index, jnp.int32(9))
    assert int(mem.query(state, jnp.asarray(e)).added_at) == 9
    state = mem.mark_soft(state, q.index)
    assert not bool(mem.query(state, jnp.asarray(e)).hard)


def _batch_of(rng, k, d=16):
    embs = np.stack([rand_unit(rng, d) for _ in range(k)])
    guides = np.arange(4 * k, dtype=np.int32).reshape(k, 4)
    has_guide = (np.arange(k) % 2).astype(bool)
    hard = (np.arange(k) % 3 == 0)
    now = np.arange(k, dtype=np.int32) + 1
    return embs, guides, has_guide, hard, now


def test_add_batch_equals_sequential_adds(rng):
    """add_batch(K entries) == K sequential add() calls, field for field."""
    embs, guides, has_guide, hard, now = _batch_of(rng, 5)
    seq = mem.init_memory(CFG)
    for j in range(5):
        seq = mem.add(seq, jnp.asarray(embs[j]), jnp.asarray(guides[j]),
                      jnp.asarray(has_guide[j]), jnp.asarray(hard[j]),
                      jnp.int32(now[j]))
    bat = mem.add_batch(mem.init_memory(CFG), jnp.asarray(embs),
                        jnp.asarray(guides), jnp.asarray(has_guide),
                        jnp.asarray(hard), jnp.asarray(now))
    for f in ("emb", "guide", "has_guide", "hard", "valid", "added_at",
              "ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(seq, f)),
                                      np.asarray(getattr(bat, f)), f)


def test_add_batch_ring_wraparound(rng):
    """A commit crossing the ring end wraps to the start, matching the
    sequential FIFO semantics."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    for i in range(CFG.capacity - 2):        # leave 2 free slots
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False), jnp.int32(i))
    embs, guides, has_guide, hard, now = _batch_of(rng, 5)
    state = mem.add_batch(state, jnp.asarray(embs), jnp.asarray(guides),
                          jnp.asarray(has_guide), jnp.asarray(hard),
                          jnp.asarray(now))
    # slots C-2, C-1 then 0, 1, 2 hold the batch
    slots = [CFG.capacity - 2, CFG.capacity - 1, 0, 1, 2]
    np.testing.assert_array_equal(np.asarray(state.emb)[slots], embs)
    np.testing.assert_array_equal(np.asarray(state.added_at)[slots], now)
    assert int(state.ptr) == CFG.capacity + 3
    assert state.size_fast == CFG.capacity       # full ring
    assert state.size == CFG.capacity            # slow path agrees


def test_add_batch_rejects_overflow(rng):
    embs, guides, has_guide, hard, now = _batch_of(rng, CFG.capacity + 1)
    with pytest.raises(ValueError):
        mem.add_batch(mem.init_memory(CFG), jnp.asarray(embs),
                      jnp.asarray(guides), jnp.asarray(has_guide),
                      jnp.asarray(hard), jnp.asarray(now))


def test_size_fast_matches_size(rng):
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    assert state.size_fast == state.size == 0
    for i in range(CFG.capacity + 5):
        state = mem.add(state, jnp.asarray(rand_unit(rng)), zero_g,
                        jnp.asarray(False), jnp.asarray(False),
                        jnp.int32(i))
        assert state.size_fast == state.size


def test_query_batch_matches_query(rng):
    state = mem.init_memory(CFG)
    for j in range(10):
        state = mem.add(state, jnp.asarray(rand_unit(rng)),
                        jnp.asarray(np.full(4, j, np.int32)),
                        jnp.asarray(j % 2 == 0), jnp.asarray(j % 3 == 0),
                        jnp.int32(j))
    qs = np.stack([rand_unit(rng) for _ in range(6)])
    for guides_only in (False, True):
        qb = mem.query_batch(state, jnp.asarray(qs),
                             guides_only=guides_only)
        for b in range(6):
            q1 = mem.query(state, jnp.asarray(qs[b]),
                           guides_only=guides_only)
            assert int(q1.index) == int(np.asarray(qb.index)[b])
            np.testing.assert_allclose(float(q1.sim),
                                       float(np.asarray(qb.sim)[b]),
                                       atol=1e-6)
            np.testing.assert_array_equal(np.asarray(q1.guide),
                                          np.asarray(qb.guide)[b])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_property_best_match_wins(seeds, qseed):
    """query() returns the stored row with the max cosine (vs. numpy)."""
    state = mem.init_memory(CFG)
    zero_g = jnp.zeros(4, jnp.int32)
    rows = []
    for i, s in enumerate(seeds):
        e = rand_unit(np.random.default_rng(s))
        rows.append(e)
        state = mem.add(state, jnp.asarray(e), zero_g, jnp.asarray(False),
                        jnp.asarray(False), jnp.int32(i))
    q_emb = rand_unit(np.random.default_rng(qseed))
    q = mem.query(state, jnp.asarray(q_emb))
    kept = rows[-CFG.capacity:]                 # FIFO keeps the tail
    expect = max(float(np.dot(r, q_emb)) for r in kept)
    np.testing.assert_allclose(float(q.sim), expect, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans(), st.booleans())
def test_property_flags_roundtrip(seed, has_guide, hard):
    state = mem.init_memory(CFG)
    e = rand_unit(np.random.default_rng(seed))
    g = jnp.arange(4, dtype=jnp.int32)
    state = mem.add(state, jnp.asarray(e), g, jnp.asarray(has_guide),
                    jnp.asarray(hard), jnp.int32(5))
    q = mem.query(state, jnp.asarray(e))
    assert bool(q.has_guide) == has_guide
    assert bool(q.hard) == hard
