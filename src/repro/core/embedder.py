"""Request/response embedding encoder — the all-MiniLM-L12-v2 analog.

A small bidirectional transformer encoder, mean-pooled over non-PAD
positions, projected to 384 dims and L2-normalized (matching the paper's
384-d MiniLM embeddings + cosine indexing). Trained with an NT-Xent
contrastive objective where questions sharing a latent skill are positives
— the same supervision family sentence-transformers are trained with.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tk
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class EmbedderConfig:
    vocab_size: int = 128
    d_model: int = 128
    num_layers: int = 4
    num_heads: int = 4
    d_ff: int = 256
    embed_dim: int = 384          # output dimension (paper: MiniLM 384-d)
    rope_theta: float = 10_000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def init_params(cfg: EmbedderConfig, key: jax.Array) -> Any:
    k_embed, k_layers, k_proj = jax.random.split(key, 3)

    def layer_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model),
            "attn": L.attention_block_init(k1, cfg.d_model, cfg.num_heads,
                                           cfg.num_heads, cfg.head_dim,
                                           dtype=jnp.float32),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=jnp.float32),
        }

    return {
        "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model)),
        "layers": jax.vmap(layer_init)(jax.random.split(k_layers,
                                                        cfg.num_layers)),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "proj": L.dense_init(k_proj, (cfg.d_model, cfg.embed_dim)),
    }


def embed(cfg: EmbedderConfig, params: Any, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) int32 (PAD=0 ignored) -> (B, embed_dim) unit-norm f32."""
    B, S = tokens.shape
    x = params["embed"][tokens] * cfg.d_model ** 0.5
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = (tokens != tk.PAD)

    def body(carry, lp):
        h = L.rmsnorm(lp["ln1"], carry)
        q, k, v = L.attention_qkv(lp["attn"], h, positions, cfg.rope_theta)
        # bidirectional attention, PAD positions masked out of keys
        kpos = jnp.where(mask, positions, -10_000_000)
        attn = L.attention(q, k, v, q_positions=positions, k_positions=kpos,
                           causal=False, window=0)
        h = carry + L.attention_out(lp["attn"], attn)
        h2 = L.rmsnorm(lp["ln2"], h)
        return h + L.mlp(lp["mlp"], h2), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x)
    w = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(x * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)
    out = pooled @ params["proj"]
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True),
                             1e-9)


def nt_xent_loss(cfg: EmbedderConfig, params: Any, tokens: jax.Array,
                 skill_ids: jax.Array, temperature: float = 0.1
                 ) -> jax.Array:
    """NT-Xent with same-skill positives (multi-positive InfoNCE)."""
    z = embed(cfg, params, tokens)                   # (N, E), unit
    sim = z @ z.T / temperature                      # (N, N)
    N = z.shape[0]
    eye = jnp.eye(N, dtype=bool)
    pos = (skill_ids[:, None] == skill_ids[None, :]) & ~eye
    sim = jnp.where(eye, -1e9, sim)
    logp = jax.nn.log_softmax(sim, axis=-1)
    pos_f = pos.astype(jnp.float32)
    per_anchor = jnp.sum(logp * pos_f, axis=-1) / jnp.maximum(
        jnp.sum(pos_f, axis=-1), 1.0)
    return -jnp.mean(per_anchor)


def make_train_step(cfg: EmbedderConfig, lr: float = 3e-4):
    @jax.jit
    def step(params, opt, tokens, skill_ids):
        loss, grads = jax.value_and_grad(
            partial(nt_xent_loss, cfg))(params, tokens, skill_ids)
        # simple Adam
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = opt["t"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          opt["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          opt["nu"], grads)
        params = jax.tree.map(
            lambda p, m, v: p - lr * (m / (1 - b1 ** t)) /
            (jnp.sqrt(v / (1 - b2 ** t)) + eps), params, mu, nu)
        return params, {"t": t, "mu": mu, "nu": nu}, loss

    return step


def init_opt(params: Any) -> dict:
    z = jax.tree.map(jnp.zeros_like, params)
    return {"t": jnp.zeros((), jnp.int32), "mu": z,
            "nu": jax.tree.map(jnp.zeros_like, params)}
