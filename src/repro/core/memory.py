"""Skill & guide memory — the paper's vector DB (§III-F), device-resident.

A fixed-capacity ring of request embeddings with per-entry metadata:

* ``has_guide`` — entry stores a guide (Case 2) vs. a bare skill (Case 1),
* ``hard``     — weak FM failed even with guides (Case 3): route strong,
* ``added_at`` — logical time of insertion (drives Case-3 re-probing),
* ``guide``    — fixed-width guide token block.

Persistent padded layout (the zero-copy invariant)
--------------------------------------------------
``emb`` lives **permanently in kernel layout**: (Cp, Ep) f32 with rows
padded to the kernel block multiple and lanes to a multiple of 128
(:func:`repro.kernels.memory_topk.padded_rows` /
:func:`~repro.kernels.memory_topk.padded_lanes`). ``valid`` and
``has_guide`` are packed into an incrementally-maintained (Cp, 1) int32
``mask`` bit plane (bit 0 = valid, bit 1 = has_guide). Logical ring slots
are rows [0, C) of the padded buffers; padding rows [C, Cp) carry mask 0
and are never valid.

Consequences:

* a query touches each store byte exactly once — the kernel consumes the
  buffers as-is, with no per-call O(C·E) re-padding copy (the old wrappers
  re-materialized the store on *every* query, doubling HBM traffic);
* the ``guides_only`` view is a different ``required`` bit set on the same
  mask plane — no per-query (C,) mask combine;
* writes (:func:`add`, :func:`add_batch`, :func:`mark_soft`,
  :func:`touch`) scatter directly into the padded buffers, O(K·E) per
  commit, never O(C·E).

Static shapes keep every operation jit-compatible; the similarity search
is a fused cosine/top-1 over the full store — the Pallas kernel in
:mod:`repro.kernels.memory_topk` implements the contract blocked for VMEM,
and :func:`query` routes through its jnp reference on CPU. The query
epilogue (metadata gathers + ``guides_only`` handling) is fused into the
same jitted call and returns a :class:`QueryResult` packing everything
into two arrays — one ``device_get`` moves a whole microbatch of results
to the host. :func:`query_topk` / :func:`query_topk_batch` widen the same
single-pass read to the top-k entries (packed :class:`TopKResult`, sorted
by sim desc / row asc; k = 1 is bit-identical to the top-1 read) — the
multi-guide serving path. Eviction is FIFO (ring pointer), the capacity
is a config knob. :mod:`repro.core.memory_sharded` scales the same
contract across devices.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.memory_topk import (DEFAULT_BLOCK_C, MASK_GUIDE,
                                       MASK_VALID, padded_lanes,
                                       padded_rows)


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 4096
    embed_dim: int = 384
    guide_len: int = 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MemoryState:
    emb: jax.Array       # (Cp, Ep) f32 — persistent kernel layout; logical
    #                      rows [0, C), L2-normalized (or zero), zero padding
    mask: jax.Array      # (Cp, 1) int32 bit plane: MASK_VALID | MASK_GUIDE
    guide: jax.Array     # (C, G) int32
    hard: jax.Array      # (C,) bool
    added_at: jax.Array  # (C,) int32 logical time
    ptr: jax.Array       # () int32 ring insert pointer

    @property
    def capacity(self) -> int:
        """Logical capacity C (the padded buffers hold Cp ≥ C rows)."""
        return self.hard.shape[0]

    @property
    def valid(self) -> jax.Array:
        """(C,) bool view decoded from the mask bit plane."""
        return (self.mask[:self.capacity, 0] & MASK_VALID) != 0

    @property
    def has_guide(self) -> jax.Array:
        """(C,) bool view decoded from the mask bit plane."""
        return (self.mask[:self.capacity, 0] & MASK_GUIDE) != 0

    @property
    def size(self) -> int:
        """Debugging-only: blocking device sync (full reduction over
        ``valid``). Hot paths must use :attr:`size_fast` instead."""
        return int(jnp.sum(self.valid))

    @property
    def size_fast(self) -> int:
        """O(1) occupancy from the ring pointer: entries are only ever
        added (``valid`` is monotone), so size == min(ptr, capacity).
        Transfers one scalar instead of reducing the (C,) mask."""
        return min(int(self.ptr), self.capacity)


def init_memory(cfg: MemoryConfig) -> MemoryState:
    C, E, G = cfg.capacity, cfg.embed_dim, cfg.guide_len
    Cp, Ep = padded_rows(C), padded_lanes(E)
    return MemoryState(
        emb=jnp.zeros((Cp, Ep), jnp.float32),
        mask=jnp.zeros((Cp, 1), jnp.int32),
        guide=jnp.zeros((C, G), jnp.int32),
        hard=jnp.zeros((C,), bool),
        added_at=jnp.zeros((C,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
    )


def _pad_lanes(embs: jax.Array, ep: int) -> jax.Array:
    """(…, E) → (…, Ep): zero-pad the lane dim. O(K·E) — commit-sized,
    never store-sized."""
    pad = [(0, 0)] * (embs.ndim - 1) + [(0, ep - embs.shape[-1])]
    return jnp.pad(embs.astype(jnp.float32), pad)


def _mask_bits(has_guide: jax.Array) -> jax.Array:
    return MASK_VALID + jnp.where(has_guide, MASK_GUIDE, 0).astype(jnp.int32)


@jax.jit
def _add_jit(state: MemoryState, emb: jax.Array, guide: jax.Array,
             has_guide: jax.Array, hard: jax.Array,
             now: jax.Array) -> MemoryState:
    i = state.ptr % state.capacity
    return MemoryState(
        emb=state.emb.at[i].set(_pad_lanes(emb, state.emb.shape[1])),
        mask=state.mask.at[i, 0].set(_mask_bits(has_guide)),
        guide=state.guide.at[i].set(guide),
        hard=state.hard.at[i].set(hard),
        added_at=state.added_at.at[i].set(now),
        ptr=state.ptr + 1,
    )


@jax.jit
def _add_batch_jit(state: MemoryState, embs: jax.Array, guides: jax.Array,
                   has_guide: jax.Array, hard: jax.Array,
                   now: jax.Array) -> MemoryState:
    K, C = embs.shape[0], state.capacity
    if K > C:
        raise ValueError(f"microbatch commit of {K} entries exceeds "
                         f"memory capacity {C}")
    idx = (state.ptr + jnp.arange(K, dtype=jnp.int32)) % C
    return MemoryState(
        emb=state.emb.at[idx].set(_pad_lanes(embs, state.emb.shape[1])),
        mask=state.mask.at[idx, 0].set(_mask_bits(has_guide)),
        guide=state.guide.at[idx].set(guides),
        hard=state.hard.at[idx].set(hard),
        added_at=state.added_at.at[idx].set(now),
        ptr=state.ptr + K,
    )


class _MetaViews:
    """Per-field views over the packed int32 ``meta`` epilogue
    [index, has_guide, hard, added_at, guide₀…guide_{G-1}]; work on device
    arrays and host numpy alike, for any leading shape."""

    @property
    def index(self):
        return self.meta[..., 0]

    @property
    def has_guide(self):
        return self.meta[..., 1].astype(bool)

    @property
    def hard(self):
        return self.meta[..., 2].astype(bool)

    @property
    def added_at(self):
        return self.meta[..., 3]

    @property
    def guide(self):
        return self.meta[..., 4:]

    def device_get(self):
        """Pull the whole result to the host in one transfer."""
        sim, meta = jax.device_get((self.sim, self.meta))
        return type(self)(sim, meta)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QueryResult(_MetaViews):
    """Top-1 result with its metadata epilogue fused into two arrays.

    ``sim`` is (…,) f32; ``meta`` is (…, 4 + G) int32 packing
    [index, has_guide, hard, added_at, guide₀…guide_{G-1}] — a single
    host-transferable struct (one :meth:`device_get` per microbatch phase
    instead of ~6 per-field transfers)."""
    sim: jax.Array        # (…,) f32 cosine of best row (-2 if view empty)
    meta: jax.Array       # (…, 4 + G) int32 packed epilogue


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TopKResult(_MetaViews):
    """Top-k result — the multi-guide read path's packed struct.

    ``sim`` is (…, k) f32 and ``meta`` is (…, k, 4 + G) int32, entries
    sorted by (sim desc, store row asc); entries past the view's
    population carry the -2.0 sentinel. Same one-host-transfer contract
    as :class:`QueryResult` (one :meth:`device_get` per controller
    phase); the field views gain a trailing k axis."""
    sim: jax.Array        # (…, k) f32
    meta: jax.Array       # (…, k, 4 + G) int32


def pack_meta_parts(idx: jax.Array, bits: jax.Array, hard: jax.Array,
                    added_at: jax.Array, guide: jax.Array) -> jax.Array:
    """THE packed-meta layout — [index, has_guide, hard, added_at,
    guide₀…] — single source of truth for every store flavour. ``bits``
    are the winning rows' mask-plane values; ``hard``/``added_at``/
    ``guide`` are gathered here by ``idx``."""
    head = jnp.stack([idx.astype(jnp.int32),
                      (bits & MASK_GUIDE) // MASK_GUIDE,
                      hard[idx].astype(jnp.int32),
                      added_at[idx]], axis=-1)
    return jnp.concatenate([head, guide[idx]], axis=-1)


# the sharded store's epilogue dispatch (its kernel+combine is a separate
# shard_map jit; this keeps the metadata gathers one fused call, not ~5
# eager ops per query)
pack_meta_jit = jax.jit(pack_meta_parts)


def pack_meta(state: MemoryState, idx: jax.Array) -> jax.Array:
    """Fused query epilogue: gather the metadata of row(s) ``idx`` into the
    packed int32 struct (called inside the jitted query)."""
    return pack_meta_parts(idx, state.mask[idx, 0], state.hard,
                           state.added_at, state.guide)


def required_bits(guides_only: bool) -> int:
    """Mask-plane bit set a row must carry to join the query's view."""
    return MASK_VALID | (MASK_GUIDE if guides_only else 0)


@partial(jax.jit, static_argnames=("guides_only",))
def _query_jit(state: MemoryState, emb: jax.Array,
               guides_only: bool = False) -> QueryResult:
    sims, idx = kops.memory_top1_padded(state.emb, emb, state.mask,
                                        required_bits(guides_only))
    return QueryResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("guides_only",))
def _query_batch_jit(state: MemoryState, embs: jax.Array,
                     guides_only: bool = False) -> QueryResult:
    sims, idx = kops.memory_top1_batch_padded(state.emb, embs, state.mask,
                                              required_bits(guides_only))
    return QueryResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("k", "guides_only"))
def _query_topk_jit(state: MemoryState, emb: jax.Array, k: int,
                    guides_only: bool = False) -> TopKResult:
    sims, idx = kops.memory_topk_padded(state.emb, emb, state.mask, k,
                                        required_bits(guides_only))
    return TopKResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("k", "guides_only"))
def _query_topk_batch_jit(state: MemoryState, embs: jax.Array, k: int,
                          guides_only: bool = False) -> TopKResult:
    sims, idx = kops.memory_topk_batch_padded(state.emb, embs, state.mask,
                                              k, required_bits(guides_only))
    return TopKResult(sim=sims, meta=pack_meta(state, idx))


@jax.jit
def _mark_soft_jit(state: MemoryState, index: jax.Array) -> MemoryState:
    return dataclasses.replace(state, hard=state.hard.at[index].set(False))


@jax.jit
def _touch_jit(state: MemoryState, index: jax.Array,
               now: jax.Array) -> MemoryState:
    return dataclasses.replace(state,
                               added_at=state.added_at.at[index].set(now))


# ---------------------------------------------------------------------------
# Public API — thin dispatchers so the controllers (``core.rar`` /
# ``core.pipeline``) serve identically against the single-device
# MemoryState (functional, jitted) or a ``core.memory_sharded``
# ShardedMemory (method-based, returns itself after in-place update).
# ---------------------------------------------------------------------------


def query(state, emb: jax.Array, guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search. ``guides_only`` restricts to guide entries
    (the guide-memory view used during shadow inference) via the mask bit
    plane — same single store pass, no mask combine. Kernel + metadata
    epilogue are one jitted call returning one packed struct."""
    if isinstance(state, MemoryState):
        return _query_jit(state, emb, guides_only=guides_only)
    return state.query(emb, guides_only=guides_only)


def query_batch(state, embs: jax.Array,
                guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search for a whole microbatch of queries in one store
    pass. embs (B, E) → QueryResult with leading B axis. All queries see
    the same snapshot of the store (reads happen at microbatch start;
    writes commit at microbatch end via :func:`add_batch`)."""
    if isinstance(state, MemoryState):
        return _query_batch_jit(state, embs, guides_only=guides_only)
    return state.query_batch(embs, guides_only=guides_only)


def _check_k(k: int, capacity: int) -> None:
    # the upper bound holds on every backend: the Pallas kernel's (k, B)
    # accumulator must fit one grid-step merge (k <= kernel block), and
    # capping here also bounds the ref oracle's k unrolled selection
    # rounds — the dispatch contract cannot depend on which impl runs
    bound = min(capacity, DEFAULT_BLOCK_C)
    if not 1 <= k <= bound:
        raise ValueError(f"retrieval k={k} must be in [1, {bound}] "
                         f"(min of capacity={capacity} and the kernel "
                         f"block {DEFAULT_BLOCK_C})")


def query_topk(state, emb: jax.Array, k: int,
               guides_only: bool = False) -> TopKResult:
    """Top-k cosine search in the same single store pass as :func:`query`
    (k = 1 is bit-identical to it). Entries arrive sorted by
    (sim desc, store row asc); slots past the view's population carry the
    -2.0 sentinel. The multi-guide serving read
    (``core.rar.splice_guides``)."""
    _check_k(k, state.capacity)
    if isinstance(state, MemoryState):
        return _query_topk_jit(state, emb, k, guides_only=guides_only)
    return state.query_topk(emb, k, guides_only=guides_only)


def query_topk_batch(state, embs: jax.Array, k: int,
                     guides_only: bool = False) -> TopKResult:
    """Top-k search for a whole microbatch in one store pass: embs (B, E)
    → TopKResult with (B, k) leading axes. Snapshot semantics match
    :func:`query_batch`."""
    _check_k(k, state.capacity)
    if isinstance(state, MemoryState):
        return _query_topk_batch_jit(state, embs, k,
                                     guides_only=guides_only)
    return state.query_topk_batch(embs, k, guides_only=guides_only)


def add(state, emb: jax.Array, guide: jax.Array, has_guide: jax.Array,
        hard: jax.Array, now: jax.Array):
    """Insert one entry at the ring pointer (FIFO eviction). Scatters one
    padded row in place — the store is never re-materialized."""
    if isinstance(state, MemoryState):
        return _add_jit(state, emb, guide, has_guide, hard, now)
    state.add(emb, guide, has_guide, hard, now)
    return state


def add_batch(state, embs: jax.Array, guides: jax.Array,
              has_guide: jax.Array, hard: jax.Array, now: jax.Array):
    """Insert K entries at consecutive ring slots in one jitted call — the
    microbatch commit (all of a batch's shadow-inference writes land
    together). embs (K, E); guides (K, G); has_guide/hard (K,) bool;
    now (K,) int32 per-entry logical times. Equivalent to K sequential
    :func:`add` calls for K ≤ capacity (slot indices are then distinct, so
    the scatter order cannot matter)."""
    if isinstance(state, MemoryState):
        return _add_batch_jit(state, embs, guides, has_guide, hard, now)
    state.add_batch(embs, guides, has_guide, hard, now)
    return state


def mark_soft(state, index: jax.Array):
    """Clear a hard flag after a successful re-probe (Case 3 → Case 1/2).
    ``index`` may be a scalar or a (K,) batch of indices (the microbatch
    commit's flag pass)."""
    if isinstance(state, MemoryState):
        return _mark_soft_jit(state, index)
    state.mark_soft(index)
    return state


def touch(state, index: jax.Array, now: jax.Array):
    """Refresh an entry's timestamp (restarts the re-probe cool-down).
    ``index``/``now`` may be scalars or matching (K,) batches."""
    if isinstance(state, MemoryState):
        return _touch_jit(state, index, now)
    state.touch(index, now)
    return state
