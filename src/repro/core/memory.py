"""Skill & guide memory — the paper's vector DB (§III-F), device-resident.

A fixed-capacity ring of request embeddings with per-entry metadata:

* ``has_guide`` — entry stores a guide (Case 2) vs. a bare skill (Case 1),
* ``hard``     — weak FM failed even with guides (Case 3): route strong,
* ``added_at`` — logical time of insertion (drives Case-3 re-probing),
* ``guide``    — fixed-width guide token block.

Static shapes keep every operation jit-compatible; the similarity search is
a fused cosine/top-1 over the full store — the Pallas kernel in
:mod:`repro.kernels.memory_topk` implements the same contract blocked for
VMEM, and :func:`query` routes through its jnp reference on CPU.
Eviction is FIFO (ring pointer), the capacity is a config knob.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 4096
    embed_dim: int = 384
    guide_len: int = 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MemoryState:
    emb: jax.Array        # (C, E) f32, rows L2-normalized (or zero)
    guide: jax.Array      # (C, G) int32
    has_guide: jax.Array  # (C,) bool
    hard: jax.Array       # (C,) bool
    valid: jax.Array      # (C,) bool
    added_at: jax.Array   # (C,) int32 logical time
    ptr: jax.Array        # () int32 ring insert pointer

    @property
    def size(self) -> int:
        """Debugging-only: blocking device sync (full reduction over
        ``valid``). Hot paths must use :attr:`size_fast` instead."""
        return int(jnp.sum(self.valid))

    @property
    def size_fast(self) -> int:
        """O(1) occupancy from the ring pointer: entries are only ever
        added (``valid`` is monotone), so size == min(ptr, capacity).
        Transfers one scalar instead of reducing the (C,) mask."""
        return min(int(self.ptr), self.emb.shape[0])


def init_memory(cfg: MemoryConfig) -> MemoryState:
    C, E, G = cfg.capacity, cfg.embed_dim, cfg.guide_len
    return MemoryState(
        emb=jnp.zeros((C, E), jnp.float32),
        guide=jnp.zeros((C, G), jnp.int32),
        has_guide=jnp.zeros((C,), bool),
        hard=jnp.zeros((C,), bool),
        valid=jnp.zeros((C,), bool),
        added_at=jnp.zeros((C,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
    )


@jax.jit
def add(state: MemoryState, emb: jax.Array, guide: jax.Array,
        has_guide: jax.Array, hard: jax.Array,
        now: jax.Array) -> MemoryState:
    """Insert one entry at the ring pointer (FIFO eviction)."""
    i = state.ptr % state.emb.shape[0]
    return MemoryState(
        emb=state.emb.at[i].set(emb),
        guide=state.guide.at[i].set(guide),
        has_guide=state.has_guide.at[i].set(has_guide),
        hard=state.hard.at[i].set(hard),
        valid=state.valid.at[i].set(True),
        added_at=state.added_at.at[i].set(now),
        ptr=state.ptr + 1,
    )


@jax.jit
def add_batch(state: MemoryState, embs: jax.Array, guides: jax.Array,
              has_guide: jax.Array, hard: jax.Array,
              now: jax.Array) -> MemoryState:
    """Insert K entries at consecutive ring slots in one jitted call — the
    microbatch commit (all of a batch's shadow-inference writes land
    together). embs (K, E); guides (K, G); has_guide/hard (K,) bool;
    now (K,) int32 per-entry logical times. Equivalent to K sequential
    :func:`add` calls for K ≤ capacity (slot indices are then distinct, so
    the scatter order cannot matter)."""
    K, C = embs.shape[0], state.emb.shape[0]
    if K > C:
        raise ValueError(f"microbatch commit of {K} entries exceeds "
                         f"memory capacity {C}")
    idx = (state.ptr + jnp.arange(K, dtype=jnp.int32)) % C
    return MemoryState(
        emb=state.emb.at[idx].set(embs),
        guide=state.guide.at[idx].set(guides),
        has_guide=state.has_guide.at[idx].set(has_guide),
        hard=state.hard.at[idx].set(hard),
        valid=state.valid.at[idx].set(True),
        added_at=state.added_at.at[idx].set(now),
        ptr=state.ptr + K,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QueryResult:
    index: jax.Array      # () int32 — argmax row (undefined if sim < -1)
    sim: jax.Array        # () f32 cosine of best row (-2 if store empty)
    has_guide: jax.Array
    hard: jax.Array
    guide: jax.Array      # (G,) int32
    added_at: jax.Array


@partial(jax.jit, static_argnames=("guides_only",))
def query(state: MemoryState, emb: jax.Array,
          guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search. ``guides_only`` restricts to guide entries
    (the guide-memory view used during shadow inference)."""
    mask = state.valid
    if guides_only:
        mask = mask & state.has_guide
    sims, idx = kops.memory_top1(state.emb, emb, mask)
    return QueryResult(
        index=idx,
        sim=sims,
        has_guide=state.has_guide[idx],
        hard=state.hard[idx],
        guide=state.guide[idx],
        added_at=state.added_at[idx],
    )


@partial(jax.jit, static_argnames=("guides_only",))
def query_batch(state: MemoryState, embs: jax.Array,
                guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search for a whole microbatch of queries in one store
    pass. embs (B, E) → QueryResult with per-field leading B axis. All
    queries see the same snapshot of the store (reads happen at microbatch
    start; writes commit at microbatch end via :func:`add_batch`)."""
    mask = state.valid
    if guides_only:
        mask = mask & state.has_guide
    sims, idx = kops.memory_top1_batch(state.emb, embs, mask)
    return QueryResult(
        index=idx,
        sim=sims,
        has_guide=state.has_guide[idx],
        hard=state.hard[idx],
        guide=state.guide[idx],
        added_at=state.added_at[idx],
    )


@jax.jit
def mark_soft(state: MemoryState, index: jax.Array) -> MemoryState:
    """Clear a hard flag after a successful re-probe (Case 3 → Case 1/2).
    ``index`` may be a scalar or a (K,) batch of indices (the microbatch
    commit's flag pass)."""
    return dataclasses.replace(state, hard=state.hard.at[index].set(False))


@jax.jit
def touch(state: MemoryState, index: jax.Array,
          now: jax.Array) -> MemoryState:
    """Refresh an entry's timestamp (restarts the re-probe cool-down).
    ``index``/``now`` may be scalars or matching (K,) batches."""
    return dataclasses.replace(state,
                               added_at=state.added_at.at[index].set(now))
