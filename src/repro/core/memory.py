"""Skill & guide memory — the paper's vector DB (§III-F), device-resident.

A fixed-capacity ring of request embeddings with per-entry metadata:

* ``has_guide`` — entry stores a guide (Case 2) vs. a bare skill (Case 1),
* ``hard``     — weak FM failed even with guides (Case 3): route strong,
* ``added_at`` — logical time of insertion (drives Case-3 re-probing),
* ``guide``    — fixed-width guide token block.

Persistent padded layout (the zero-copy invariant)
--------------------------------------------------
``emb`` lives **permanently in kernel layout**: (Cp, Ep) f32 with rows
padded to the kernel block multiple and lanes to a multiple of 128
(:func:`repro.kernels.memory_topk.padded_rows` /
:func:`~repro.kernels.memory_topk.padded_lanes`). ``valid`` and
``has_guide`` are packed into an incrementally-maintained (Cp, 1) int32
``mask`` bit plane (bit 0 = valid, bit 1 = has_guide). Logical ring slots
are rows [0, C) of the padded buffers; padding rows [C, Cp) carry mask 0
and are never valid.

Consequences:

* a query touches each store byte exactly once — the kernel consumes the
  buffers as-is, with no per-call O(C·E) re-padding copy (the old wrappers
  re-materialized the store on *every* query, doubling HBM traffic);
* the ``guides_only`` view is a different ``required`` bit set on the same
  mask plane — no per-query (C,) mask combine;
* writes (:func:`add`, :func:`add_batch`, :func:`mark_soft`,
  :func:`touch`) scatter directly into the padded buffers, O(K·E) per
  commit, never O(C·E).

Static shapes keep every operation jit-compatible; the similarity search
is a fused cosine/top-1 over the full store — the Pallas kernel in
:mod:`repro.kernels.memory_topk` implements the contract blocked for VMEM,
and :func:`query` routes through its jnp reference on CPU. The query
epilogue (metadata gathers + ``guides_only`` handling) is fused into the
same jitted call and returns a :class:`QueryResult` packing everything
into two arrays — one ``device_get`` moves a whole microbatch of results
to the host. :func:`query_topk` / :func:`query_topk_batch` widen the same
single-pass read to the top-k entries (packed :class:`TopKResult`, sorted
by sim desc / row asc; k = 1 is bit-identical to the top-1 read) — the
multi-guide serving path. Eviction is FIFO (ring pointer), the capacity
is a config knob. :mod:`repro.core.memory_sharded` scales the same
contract across devices.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.memory_topk import (DEFAULT_BLOCK_C, MASK_GUIDE,
                                       MASK_VALID, padded_lanes,
                                       padded_rows)


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 4096
    embed_dim: int = 384
    guide_len: int = 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MemoryState:
    emb: jax.Array       # (Cp, Ep) f32 — persistent kernel layout; logical
    #                      rows [0, C), L2-normalized (or zero), zero padding
    mask: jax.Array      # (Cp, 1) int32 bit plane: MASK_VALID | MASK_GUIDE
    guide: jax.Array     # (C, G) int32
    hard: jax.Array      # (C,) bool
    added_at: jax.Array  # (C,) int32 logical time
    ptr: jax.Array       # () int32 ring insert pointer

    @property
    def capacity(self) -> int:
        """Logical capacity C (the padded buffers hold Cp ≥ C rows)."""
        return self.hard.shape[0]

    @property
    def valid(self) -> jax.Array:
        """(C,) bool view decoded from the mask bit plane."""
        return (self.mask[:self.capacity, 0] & MASK_VALID) != 0

    @property
    def has_guide(self) -> jax.Array:
        """(C,) bool view decoded from the mask bit plane."""
        return (self.mask[:self.capacity, 0] & MASK_GUIDE) != 0

    def debug_size(self) -> int:
        """Debugging-only occupancy: a *blocking device sync* (full
        reduction over ``valid``). Deliberately a method, not a property,
        so the sync is loud at call sites — hot paths must use
        :attr:`size_fast` or the commit-stream counters instead.

        Query-path sync audit (the PR-4 host-counter contract): the serve
        path performs exactly **one** device transfer per controller
        phase — the packed :meth:`QueryResult.device_get` /
        :meth:`TopKResult.device_get`. Every other host-visible number is
        a host counter: occupancy via ``CommitStream.commits`` +
        ``RAR._ptr_base`` (one ``int(ptr)`` at construction, never per
        request), epoch progress via ``CommitBuffer.epoch``/
        ``entries_applied``. The remaining ``device_get(state.ptr)`` in
        :meth:`CommitBuffer.apply_ops` sits on the drain path (per epoch,
        off the serve sweep), and :attr:`size_fast` transfers one scalar
        for shutdown/CLI reporting only."""
        return int(jnp.sum(self.valid))

    @property
    def size_fast(self) -> int:
        """O(1) occupancy from the ring pointer: entries are only ever
        added (``valid`` is monotone), so size == min(ptr, capacity).
        Transfers one scalar instead of reducing the (C,) mask — still a
        device sync; keep it off per-request paths (see
        :meth:`debug_size` for the full audit)."""
        return min(int(self.ptr), self.capacity)


def init_memory(cfg: MemoryConfig) -> MemoryState:
    C, E, G = cfg.capacity, cfg.embed_dim, cfg.guide_len
    Cp, Ep = padded_rows(C), padded_lanes(E)
    return MemoryState(
        emb=jnp.zeros((Cp, Ep), jnp.float32),
        mask=jnp.zeros((Cp, 1), jnp.int32),
        guide=jnp.zeros((C, G), jnp.int32),
        hard=jnp.zeros((C,), bool),
        added_at=jnp.zeros((C,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
    )


def _pad_lanes(embs: jax.Array, ep: int) -> jax.Array:
    """(…, E) → (…, Ep): zero-pad the lane dim. O(K·E) — commit-sized,
    never store-sized."""
    pad = [(0, 0)] * (embs.ndim - 1) + [(0, ep - embs.shape[-1])]
    return jnp.pad(embs.astype(jnp.float32), pad)


def _mask_bits(has_guide: jax.Array) -> jax.Array:
    return MASK_VALID + jnp.where(has_guide, MASK_GUIDE, 0).astype(jnp.int32)


@jax.jit
def _add_jit(state: MemoryState, emb: jax.Array, guide: jax.Array,
             has_guide: jax.Array, hard: jax.Array,
             now: jax.Array) -> MemoryState:
    i = state.ptr % state.capacity
    return MemoryState(
        emb=state.emb.at[i].set(_pad_lanes(emb, state.emb.shape[1])),
        mask=state.mask.at[i, 0].set(_mask_bits(has_guide)),
        guide=state.guide.at[i].set(guide),
        hard=state.hard.at[i].set(hard),
        added_at=state.added_at.at[i].set(now),
        ptr=state.ptr + 1,
    )


@jax.jit
def _add_batch_jit(state: MemoryState, embs: jax.Array, guides: jax.Array,
                   has_guide: jax.Array, hard: jax.Array,
                   now: jax.Array) -> MemoryState:
    K, C = embs.shape[0], state.capacity
    if K > C:
        raise ValueError(f"microbatch commit of {K} entries exceeds "
                         f"memory capacity {C}")
    idx = (state.ptr + jnp.arange(K, dtype=jnp.int32)) % C
    return MemoryState(
        emb=state.emb.at[idx].set(_pad_lanes(embs, state.emb.shape[1])),
        mask=state.mask.at[idx, 0].set(_mask_bits(has_guide)),
        guide=state.guide.at[idx].set(guides),
        hard=state.hard.at[idx].set(hard),
        added_at=state.added_at.at[idx].set(now),
        ptr=state.ptr + K,
    )


class _MetaViews:
    """Per-field views over the packed int32 ``meta`` epilogue
    [index, has_guide, hard, added_at, guide₀…guide_{G-1}]; work on device
    arrays and host numpy alike, for any leading shape."""

    @property
    def index(self):
        return self.meta[..., 0]

    @property
    def has_guide(self):
        return self.meta[..., 1].astype(bool)

    @property
    def hard(self):
        return self.meta[..., 2].astype(bool)

    @property
    def added_at(self):
        return self.meta[..., 3]

    @property
    def guide(self):
        return self.meta[..., 4:]

    def device_get(self):
        """Pull the whole result to the host in one transfer."""
        sim, meta = jax.device_get((self.sim, self.meta))
        return type(self)(sim, meta)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QueryResult(_MetaViews):
    """Top-1 result with its metadata epilogue fused into two arrays.

    ``sim`` is (…,) f32; ``meta`` is (…, 4 + G) int32 packing
    [index, has_guide, hard, added_at, guide₀…guide_{G-1}] — a single
    host-transferable struct (one :meth:`device_get` per microbatch phase
    instead of ~6 per-field transfers)."""
    sim: jax.Array        # (…,) f32 cosine of best row (-2 if view empty)
    meta: jax.Array       # (…, 4 + G) int32 packed epilogue


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TopKResult(_MetaViews):
    """Top-k result — the multi-guide read path's packed struct.

    ``sim`` is (…, k) f32 and ``meta`` is (…, k, 4 + G) int32, entries
    sorted by (sim desc, store row asc); entries past the view's
    population carry the -2.0 sentinel. Same one-host-transfer contract
    as :class:`QueryResult` (one :meth:`device_get` per controller
    phase); the field views gain a trailing k axis."""
    sim: jax.Array        # (…, k) f32
    meta: jax.Array       # (…, k, 4 + G) int32


def pack_meta_parts(idx: jax.Array, bits: jax.Array, hard: jax.Array,
                    added_at: jax.Array, guide: jax.Array) -> jax.Array:
    """THE packed-meta layout — [index, has_guide, hard, added_at,
    guide₀…] — single source of truth for every store flavour. ``bits``
    are the winning rows' mask-plane values; ``hard``/``added_at``/
    ``guide`` are gathered here by ``idx``."""
    head = jnp.stack([idx.astype(jnp.int32),
                      (bits & MASK_GUIDE) // MASK_GUIDE,
                      hard[idx].astype(jnp.int32),
                      added_at[idx]], axis=-1)
    return jnp.concatenate([head, guide[idx]], axis=-1)


# the sharded store's epilogue dispatch (its kernel+combine is a separate
# shard_map jit; this keeps the metadata gathers one fused call, not ~5
# eager ops per query)
pack_meta_jit = jax.jit(pack_meta_parts)


def pack_meta(state: MemoryState, idx: jax.Array) -> jax.Array:
    """Fused query epilogue: gather the metadata of row(s) ``idx`` into the
    packed int32 struct (called inside the jitted query)."""
    return pack_meta_parts(idx, state.mask[idx, 0], state.hard,
                           state.added_at, state.guide)


def required_bits(guides_only: bool) -> int:
    """Mask-plane bit set a row must carry to join the query's view."""
    return MASK_VALID | (MASK_GUIDE if guides_only else 0)


@partial(jax.jit, static_argnames=("guides_only",))
def _query_jit(state: MemoryState, emb: jax.Array,
               guides_only: bool = False) -> QueryResult:
    sims, idx = kops.memory_top1_padded(state.emb, emb, state.mask,
                                        required_bits(guides_only))
    return QueryResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("guides_only",))
def _query_batch_jit(state: MemoryState, embs: jax.Array,
                     guides_only: bool = False) -> QueryResult:
    sims, idx = kops.memory_top1_batch_padded(state.emb, embs, state.mask,
                                              required_bits(guides_only))
    return QueryResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("k", "guides_only"))
def _query_topk_jit(state: MemoryState, emb: jax.Array, k: int,
                    guides_only: bool = False) -> TopKResult:
    sims, idx = kops.memory_topk_padded(state.emb, emb, state.mask, k,
                                        required_bits(guides_only))
    return TopKResult(sim=sims, meta=pack_meta(state, idx))


@partial(jax.jit, static_argnames=("k", "guides_only"))
def _query_topk_batch_jit(state: MemoryState, embs: jax.Array, k: int,
                          guides_only: bool = False) -> TopKResult:
    sims, idx = kops.memory_topk_batch_padded(state.emb, embs, state.mask,
                                              k, required_bits(guides_only))
    return TopKResult(sim=sims, meta=pack_meta(state, idx))


def grow_memory(state: MemoryState, new_capacity: int
                ) -> tuple[MemoryState, "jax.Array"]:
    """Grow-in-place capacity re-layout: returns ``(grown_state, remap)``
    where ``remap[s]`` is the new logical slot of old slot ``s``.

    Two regimes, chosen by whether the ring has wrapped:

    * **Not yet wrapped** (``ptr <= C``) — rows copy straight across:
      slot indices, the ring pointer, and therefore every outstanding
      ``ptr_snapshot`` eviction guard in :class:`CommitBuffer` stay
      *exactly* valid (the guard's modulo moves from C to newC, but with
      ``snap <= ptr <= C`` the covered-interval test is unchanged for
      every slot). ``remap`` is the identity.
    * **Wrapped** (``ptr > C``) — the ring is linearized oldest-first
      (old slot ``ptr % C`` becomes row 0) and the new pointer is C, so
      future inserts land after the newest entry and FIFO eviction order
      is preserved. Old slot indices *move* (by ``remap``), so callers
      must quiesce first: :meth:`CommitStream.grow` refuses while ops
      are staged, and rebases each subscribed view's ``_ptr_base`` so
      post-grow pointer snapshots are exact. Flag ops captured before a
      wrapped grow are the caller's to remap (or drop — the guard's
      snapshot clamp makes a stale op at worst a conservatively dropped
      flag update, never a corrupted entry).

    Runs off the serve path (one ``device_get`` of the scalar pointer);
    the copy is O(C·E) once, like ``to_padded_layout``.
    """
    C = state.capacity
    if new_capacity < C:
        raise ValueError(f"cannot shrink memory: {new_capacity} < {C}")
    G = state.guide.shape[1]
    ptr = int(jax.device_get(state.ptr))
    fresh = init_memory(MemoryConfig(capacity=new_capacity,
                                     embed_dim=state.emb.shape[1],
                                     guide_len=G))
    if ptr <= C:
        order = jnp.arange(C, dtype=jnp.int32)
        new_ptr = state.ptr
        remap = jnp.arange(C, dtype=jnp.int32)
    else:
        shift = ptr % C
        order = (jnp.arange(C, dtype=jnp.int32) + shift) % C
        new_ptr = jnp.asarray(C, jnp.int32)
        remap = (jnp.arange(C, dtype=jnp.int32) - shift) % C
    grown = MemoryState(
        emb=fresh.emb.at[:C].set(state.emb[order]),
        mask=fresh.mask.at[:C].set(state.mask[order]),
        guide=fresh.guide.at[:C].set(state.guide[order]),
        hard=fresh.hard.at[:C].set(state.hard[order]),
        added_at=fresh.added_at.at[:C].set(state.added_at[order]),
        ptr=new_ptr,
    )
    return grown, remap


@jax.jit
def _mark_soft_jit(state: MemoryState, index: jax.Array) -> MemoryState:
    return dataclasses.replace(state, hard=state.hard.at[index].set(False))


@jax.jit
def _touch_jit(state: MemoryState, index: jax.Array,
               now: jax.Array) -> MemoryState:
    return dataclasses.replace(state,
                               added_at=state.added_at.at[index].set(now))


# ---------------------------------------------------------------------------
# Epoch-versioned commit buffer — the shadow plane's write staging area
# ---------------------------------------------------------------------------


class CommitBuffer:
    """Staging area for shadow-plane memory writes, applied in epochs.

    The async shadow queue (:mod:`repro.core.shadow`) decouples learning
    (weak probes, guide generation, memory commits) from the serve sweep.
    All memory *writes* it produces are staged here — inserts
    (:meth:`stage_add`), re-probe flag clears (:meth:`stage_soft_clear`)
    and timestamp refreshes (:meth:`stage_touch`) — and land on the store
    in one :meth:`apply` call per drain **epoch**:

    * **Atomicity** — within an epoch, all staged writes become visible
      together. For the functional :class:`MemoryState` the new store is
      built first and swapped in as one reference assignment; for the
      mutable sharded store the caller serializes :meth:`apply` against
      readers (the shadow queue's ``store_lock``). A concurrent query can
      therefore never observe a partially-applied shadow batch (the
      hypothesis sweep in ``tests/test_shadow.py`` pins this).
    * **Determinism / order-independence** — staged ops are keyed by
      their request's logical time ``now`` (unique per request) and are
      sorted before applying: inserts by ``now`` (FIFO ring order — the
      same order the sequential controller would have written them),
      soft-clears as a sorted index set, touches last-``now``-wins per
      index. The final store state of an epoch is thus independent of the
      order items were staged in.
    * **Eviction guard** — flag updates target entries that existed when
      their request was classified; a flag update is dropped if its slot
      has been overwritten by any FIFO insert since then (it would
      otherwise hit the unrelated fresh entry now in that slot). The
      staging calls take the ring pointer observed at classification time
      (``ptr_snapshot``) so the guard spans *intervening* drain epochs,
      not just the applying epoch's own scatter — with no intervening
      drains (inline / deferred flush-every-batch) this reduces exactly
      to the PR-1 microbatch-commit rule.
    * **Transfer-free accounting** — :attr:`entries_applied` counts
      inserts ever applied on the host, so serve-loop progress logging
      can report ring occupancy without the ``size_fast`` device-scalar
      sync.

    Single-writer discipline: one thread stages and applies at a time
    (the drainer); readers only need :attr:`epoch`/:attr:`entries_applied`
    which are plain ints under the GIL.
    """

    def __init__(self):
        self._records: list[tuple] = []      # (now, emb, guide, hg, hard)
        self._soft_clears: list[tuple] = []  # (now, index, ptr_snapshot)
        self._touches: list[tuple] = []      # (now, index, ptr_snapshot)
        self.epoch = 0                # bumped once per non-empty apply
        self.entries_applied = 0      # inserts ever applied (host counter)

    # -- staging --------------------------------------------------------
    def stage_add(self, emb, guide, has_guide: bool, hard: bool,
                  now: int) -> None:
        """Stage one ring insert (a shadow pass's recorded entry)."""
        self._records.append((int(now), emb, guide, bool(has_guide),
                              bool(hard)))

    def stage_soft_clear(self, index: int, now: int,
                         ptr_snapshot: int | None = None) -> None:
        """Stage a hard-flag clear after a successful re-probe.
        ``ptr_snapshot`` is the ring pointer when the target entry was
        observed (eviction guard; None = start of the applying epoch)."""
        self._soft_clears.append((int(now), int(index), ptr_snapshot))

    def stage_touch(self, index: int, now: int,
                    ptr_snapshot: int | None = None) -> None:
        """Stage a timestamp refresh (failed re-probe restarts the
        cool-down); ``ptr_snapshot`` as in :meth:`stage_soft_clear`."""
        self._touches.append((int(now), int(index), ptr_snapshot))

    # -- inspection -----------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._records) + len(self._soft_clears) + \
            len(self._touches)

    # -- partial-epoch rollback -----------------------------------------
    def mark(self) -> tuple:
        """Opaque cursor over the staging area, for :meth:`rollback`.
        Taken by a drain runner *before* it stages anything, so a
        mid-epoch failure can unstage exactly its own partial work and a
        queue-level retry replays from a clean slate (the
        lost-failed-epoch bugfix: re-queued items must not double-stage)."""
        return (len(self._records), len(self._soft_clears),
                len(self._touches))

    def rollback(self, mark: tuple) -> None:
        """Discard every op staged since ``mark``. Ops staged *before*
        the mark (another replica's epoch sharing this buffer) are
        untouched. If the buffer was applied since the mark (cursor now
        shorter than the mark), there is nothing of ours left to unstage
        — the clamp makes rollback after a racing apply a no-op rather
        than an error."""
        r, s, t = mark
        del self._records[min(r, len(self._records)):]
        del self._soft_clears[min(s, len(self._soft_clears)):]
        del self._touches[min(t, len(self._touches)):]

    # -- apply ----------------------------------------------------------
    def take_ops(self):
        """Drain the staged ops: returns ``(records, soft_clears,
        touches)`` (records sorted by logical ``now``) and leaves the
        staging area empty. Split from :meth:`apply_ops` so the commit
        stream can write the epoch to a write-ahead journal *between*
        taking and applying — the crash-consistency boundary."""
        records = sorted(self._records, key=lambda r: r[0])
        soft_clears, touches = self._soft_clears, self._touches
        self._records, self._soft_clears, self._touches = [], [], []
        return records, soft_clears, touches

    def apply(self, state):
        """Apply every staged op to ``state`` as one epoch; returns the
        (new) store and the number of entries inserted. Ops land in
        deterministic order (see class docstring); inserts are chunked at
        ring capacity so an epoch larger than the ring degrades to the
        sequential FIFO result instead of a self-overwriting scatter."""
        if not self.pending:
            return state, 0
        return self.apply_ops(state, *self.take_ops())

    def apply_ops(self, state, records, soft_clears, touches):
        """Apply one epoch's (already taken) ops to ``state``. This is
        the single code path both live drains and journal *recovery*
        replay go through — which is what makes a recovered store
        byte-identical to the pre-crash one."""
        import numpy as np

        records = sorted(records, key=lambda r: r[0])
        C = state.capacity
        base_ptr = int(jax.device_get(state.ptr))
        end_ptr = base_ptr + len(records)

        def evicted(idx: int, snap) -> bool:
            """Has slot ``idx`` been overwritten by any insert between
            the flag op's pointer snapshot and the end of this epoch's
            scatter? (Clamping guards against a snapshot from a mirror
            that missed out-of-band writes — over-covering only drops a
            flag update, never corrupts an entry.)"""
            snap = base_ptr if snap is None else min(int(snap), base_ptr)
            covered = end_ptr - snap
            return covered >= C or (idx - snap) % C < covered

        def po2_chunks(seq):
            """Split into power-of-two-sized runs (13 -> 8+4+1): the
            jitted scatters compile one kernel per bucket size instead
            of one per arbitrary batch length, so a coalesced replay of
            many epochs can't trigger fresh compiles mid-serve. Order
            is preserved, so the scatter bytes are unchanged."""
            i = 0
            while i < len(seq):
                step = 1 << ((len(seq) - i).bit_length() - 1)
                yield seq[i:i + step]
                i += step

        for start in range(0, len(records), C):
            for chunk in po2_chunks(records[start:start + C]):
                state = add_batch(
                    state,
                    jnp.asarray(np.stack([np.asarray(r[1])
                                          for r in chunk])),
                    jnp.asarray(np.stack([np.asarray(r[2], np.int32)
                                          for r in chunk])),
                    jnp.asarray(np.asarray([r[3] for r in chunk], bool)),
                    jnp.asarray(np.asarray([r[4] for r in chunk], bool)),
                    jnp.asarray(np.asarray([r[0] for r in chunk],
                                           np.int32)))
        softs = sorted({idx for _, idx, snap in soft_clears
                        if not evicted(idx, snap)})
        for chunk in po2_chunks(softs):
            state = mark_soft(state, jnp.asarray(chunk, jnp.int32))
        # duplicate touch targets dedupe last-now-wins (scatter order for
        # duplicate indices is implementation-defined)
        by_idx = {idx: now for now, idx, snap in
                  sorted(touches, key=lambda t: t[:2])
                  if not evicted(idx, snap)}
        for chunk in po2_chunks(sorted(by_idx)):
            state = touch(state,
                          jnp.asarray(chunk, jnp.int32),
                          jnp.asarray([by_idx[i] for i in chunk],
                                      jnp.int32))
        self.epoch += 1
        self.entries_applied += len(records)
        return state, len(records)


# ---------------------------------------------------------------------------
# Write-ahead journal — crash-consistent persistence of the commit stream
# ---------------------------------------------------------------------------


class JournalCorruptionWarning(UserWarning):
    """A WAL replay hit a torn or corrupt frame and stopped there.

    Carries where and why, so operators can distinguish the benign case
    (torn tail from a mid-write crash — expected, recovery is exact up
    to the previous epoch) from on-disk corruption earlier in the file
    (bit rot: every later epoch is lost)."""

    def __init__(self, path: str, offset: int, reason: str):
        super().__init__(f"WAL replay stopped at byte {offset} of "
                         f"{path}: {reason}")
        self.path = path
        self.offset = offset
        self.reason = reason


class MemoryJournal:
    """Epoch-granular write-ahead journal + periodic snapshot for one
    commit stream's store.

    Layout: ``<dir>/wal.log`` (append-only record stream) and
    ``<dir>/snapshot.npz`` (atomic store snapshot, written via
    :func:`repro.training.checkpoint.save_checkpoint`). Each WAL record
    is ``<u32 length><u32 crc32>`` + a pickled payload holding one
    epoch's taken ops (inserts as host arrays, flag clears, touches) and
    its epoch number.

    Protocol (see :meth:`CommitStream.apply`): the epoch's ops are
    journaled **and fsynced before** they are applied to the in-memory
    store. A crash before the WAL write loses the epoch entirely
    (recovery lands on the previous epoch — which is also all the crashed
    process's store ever showed); a crash after the WAL write but before
    the apply recovers *with* the epoch (one epoch ahead of the dead
    process's memory). Either way the recovered store equals a store
    some prefix of epochs was applied to — never a torn state.

    Every ``snapshot_every`` epochs the full store is snapshotted
    atomically (tmpfile + ``os.replace``) and the WAL is truncated;
    records carry their epoch number, so recovery filters anything the
    snapshot already covers — a crash *between* snapshot and truncation
    is harmless.

    :meth:`recover` replays surviving epochs through
    :meth:`CommitBuffer.apply_ops` — the very code path live drains use —
    so the restored store is byte-identical to the pre-crash commit
    state. A torn or corrupt WAL tail (short read / CRC mismatch) is
    tolerated: replay stops at the last complete record.

    Only the functional :class:`MemoryState` store is journalable (the
    sharded store mutates device buffers in place and has its own
    persistence story).
    """

    def __init__(self, path: str, *, snapshot_every: int = 8,
                 fault_plan=None):
        import os
        if snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, "
                             f"got {snapshot_every}")
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.wal_path = os.path.join(path, "wal.log")
        self.snap_path = os.path.join(path, "snapshot.npz")
        self.manifest_path = os.path.join(path, "manifest.pkl")
        self.snapshot_every = snapshot_every
        self.fault_plan = fault_plan
        self._wal = open(self.wal_path, "ab")
        self.epochs_logged = 0
        self.snapshots = 0

    # -- record framing -------------------------------------------------
    # one codec for WAL records and fabric RPC frames — the corruption
    # tests cover both at once
    @staticmethod
    def _frame(obj) -> bytes:
        from repro.serving.transport import frame_message
        return frame_message(obj)

    @staticmethod
    def _read_records(path):
        """Yield payload objects from a WAL file. Replay stops at the
        first torn or corrupt frame with a structured
        :class:`JournalCorruptionWarning` (never raises): everything
        before the bad frame is recovered, everything after is
        unreachable anyway — its epochs chain past the gap. A clean EOF
        stays silent."""
        import os
        import pickle
        import struct
        import warnings
        import zlib
        if not os.path.exists(path):
            return
        offset = 0
        with open(path, "rb") as f:
            while True:
                head = f.read(8)
                if len(head) == 0:
                    return                       # clean end
                if len(head) < 8:
                    warnings.warn(JournalCorruptionWarning(
                        path, offset,
                        f"torn header ({len(head)} of 8 bytes)"))
                    return
                length, crc = struct.unpack("<II", head)
                payload = f.read(length)
                if len(payload) < length:
                    warnings.warn(JournalCorruptionWarning(
                        path, offset, f"torn payload ({len(payload)} of "
                        f"{length} bytes)"))
                    return
                if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                    warnings.warn(JournalCorruptionWarning(
                        path, offset, "crc mismatch"))
                    return
                yield pickle.loads(payload)
                offset += 8 + length

    # -- logging --------------------------------------------------------
    def log_epoch(self, epoch: int, records, soft_clears, touches,
                  manifest: dict | None = None) -> None:
        """Make one epoch's ops durable (write + flush + fsync). The
        ``wal_write`` fault site fires *before* the write — an injected
        crash here models dying with the epoch not yet on disk.

        ``manifest`` rides inside the same frame as the ops: one fsync
        makes the guide-store epoch *and* the engine-state snapshot it
        pairs with durable together, so recovery can never observe a
        store from epoch N with counters from epoch N±1."""
        import os

        import numpy as np
        if self.fault_plan is not None:
            self.fault_plan.fire("wal_write", epoch=epoch)
        host_records = [(now, np.asarray(emb), np.asarray(g, np.int32),
                         hg, hard) for now, emb, g, hg, hard in records]
        self._wal.write(self._frame({
            "epoch": int(epoch), "records": host_records,
            "soft_clears": list(soft_clears), "touches": list(touches),
            "manifest": manifest}))
        self._wal.flush()
        os.fsync(self._wal.fileno())
        self.epochs_logged += 1

    def log_checkpoint(self, epoch: int, manifest: dict) -> None:
        """Journal a manifest-only record: engine state *as of* the
        current epoch, with no store ops. Written at clean shutdown (and
        on demand) so state that advanced past the last store commit —
        the clock, counters of store-untouched requests — survives a
        subsequent kill. Replay takes the manifest, applies nothing."""
        import os
        self._wal.write(self._frame({
            "epoch": int(epoch), "checkpoint": True,
            "manifest": manifest}))
        self._wal.flush()
        os.fsync(self._wal.fileno())
        self.epochs_logged += 1

    def maybe_snapshot(self, state, buffer: CommitBuffer,
                       manifest: dict | None = None) -> None:
        if buffer.epoch % self.snapshot_every == 0:
            self.snapshot(state, buffer, manifest)

    def snapshot(self, state, buffer: CommitBuffer,
                 manifest: dict | None = None) -> None:
        """Atomically snapshot the full store + buffer counters, then
        truncate the WAL (safe in either order — see class docstring).
        The manifest lands in ``manifest.pkl`` (tmpfile + ``os.replace``)
        *before* the truncation: if we die between the two, the WAL's
        embedded manifests still cover every epoch past the snapshot."""
        import os
        import pickle

        import numpy as np
        from repro.training.checkpoint import save_checkpoint
        if manifest is not None:
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump({"epoch": int(buffer.epoch),
                             "manifest": manifest}, f, protocol=4)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.manifest_path)
        save_checkpoint(self.snap_path, {
            "state": state,
            "meta": np.asarray([buffer.epoch, buffer.entries_applied],
                               np.int64)})
        self._wal.close()
        self._wal = open(self.wal_path, "wb")   # truncate
        self._wal.flush()
        os.fsync(self._wal.fileno())
        self.snapshots += 1

    def close(self) -> None:
        if not self._wal.closed:
            self._wal.close()

    def stats(self) -> dict:
        return {"epochs_logged": self.epochs_logged,
                "snapshots": self.snapshots}

    # -- recovery -------------------------------------------------------
    @staticmethod
    def recover(path: str, mem_cfg: MemoryConfig):
        """Rebuild the store from ``<path>`` after a crash.

        Returns ``(state, epoch, entries_applied, manifest)`` — the
        recovered :class:`MemoryState`, the buffer counters a resumed
        stream must continue from, and the newest engine-state manifest
        that is *consistent with the recovered store* (``None`` when the
        site never journaled one) — or ``None`` if the directory holds
        neither snapshot nor WAL (a fresh site). Replays every complete
        WAL record newer than the snapshot through
        :meth:`CommitBuffer.apply_ops`, in epoch (= file) order; each
        replayed record's embedded manifest supersedes the snapshot-side
        one, so store and manifest always come from the same fsync.
        """
        import os
        import pickle

        import numpy as np
        from repro.training.checkpoint import load_checkpoint
        snap_path = os.path.join(path, "snapshot.npz")
        wal_path = os.path.join(path, "wal.log")
        man_path = os.path.join(path, "manifest.pkl")
        have_snap = os.path.exists(snap_path)
        have_wal = os.path.exists(wal_path) and \
            os.path.getsize(wal_path) > 0
        if not have_snap and not have_wal:
            return None
        manifest = None
        if have_snap:
            tree = load_checkpoint(snap_path)
            state = jax.tree.map(jnp.asarray, tree["state"])
            epoch, entries = (int(x) for x in np.asarray(tree["meta"]))
            if os.path.exists(man_path):
                with open(man_path, "rb") as f:
                    manifest = pickle.load(f)["manifest"]
        else:
            state, epoch, entries = init_memory(mem_cfg), 0, 0
        replay = CommitBuffer()
        replay.epoch, replay.entries_applied = epoch, entries
        for rec in MemoryJournal._read_records(wal_path):
            if rec.get("checkpoint"):
                if rec["epoch"] >= replay.epoch:
                    manifest = rec["manifest"]
                continue                      # manifest only, no ops
            if rec["epoch"] <= epoch:
                continue                      # snapshot already covers it
            state, _ = replay.apply_ops(state, rec["records"],
                                        rec["soft_clears"],
                                        rec["touches"])
            replay.epoch = rec["epoch"]       # keep numbering exact
            if rec.get("manifest") is not None:
                manifest = rec["manifest"]
        return state, replay.epoch, replay.entries_applied, manifest


# ---------------------------------------------------------------------------
# Commit stream — the serve/learn interface around the commit buffer
# ---------------------------------------------------------------------------


class CommitStream:
    """The serve/learn commit interface of one serving site.

    Generalizes what used to be three per-controller pieces — the shadow
    queue's ``store_lock``, its :class:`CommitBuffer`, and the
    controller's private host-side commit counter — into one object that
    any number of serve replicas can share:

    * :attr:`buffer` — the epoch-versioned staging area for all learn-
      plane writes (one per stream: every replica's shadow drain stages
      into the same epochs);
    * :attr:`lock` — serializes commit applies against serve-plane
      snapshot reads (for the functional ``MemoryState`` the apply is a
      reference swap; for the mutable sharded store the lock is what
      makes the multi-field update atomic for readers);
    * :attr:`commits` — the **single** host-side counter of entries ever
      committed, owned here rather than per-controller so
      ``RAR.memory_occupancy`` stays exact when N replicas share a store
      (each replica previously counted only its own writes);
    * subscribed **views** — controllers whose ``.memory`` mirrors the
      store: every applied epoch is broadcast to all of them under the
      lock, so replicas always read a whole number of epochs.

    A standalone controller owns a private stream with itself as the only
    view; the serving fabric (:mod:`repro.serving.fabric`) passes one
    shared stream to all its replicas.

    With a :class:`MemoryJournal` attached the stream is
    crash-consistent: each epoch's ops are journaled (write-ahead,
    fsynced) before the in-memory apply, and the store is periodically
    snapshotted — see :meth:`MemoryJournal.recover` /
    :func:`open_journaled_stream`. A :class:`repro.serving.faults`
    fault plan fires at the ``wal_write`` / ``commit_apply`` boundary so
    the crash-consistency property is testable deterministically.
    """

    def __init__(self, buffer: CommitBuffer | None = None, *,
                 journal: "MemoryJournal | None" = None, fault_plan=None):
        self.buffer = buffer if buffer is not None else CommitBuffer()
        self.lock = threading.RLock()
        self.commits = 0             # entries ever committed (host-side)
        self._views: list = []       # controllers mirroring the store
        self.journal = journal
        self.fault_plan = fault_plan
        # engine-state exporter (set by the owning controller/fabric):
        # called under the stream lock right before an epoch is
        # journaled, its dict rides in the same WAL frame as the ops —
        # the epoch-consistent recovery manifest
        self.state_provider = None
        # per-epoch ops tap (set by the process fabric): called under
        # the lock after a successful apply with the epoch's taken ops,
        # so the fabric can broadcast them to out-of-process workers
        self.ops_listener = None
        # optional metrics registry (set by the owning fabric): applied
        # epochs/entries counters + current-epoch gauge, bumped under
        # the stream lock — all host ints, zero device syncs
        self.metrics = None

    def subscribe(self, view) -> None:
        """Register a controller whose ``.memory`` tracks this stream's
        store (idempotent). ``view.commit_epoch_seen`` tracks the last
        epoch broadcast to it — the per-view commit-lag metric."""
        if view not in self._views:
            self._views.append(view)
            view.commit_epoch_seen = self.buffer.epoch

    def count(self, n: int = 1) -> None:
        """Account ``n`` direct (non-buffered) commits — the sequential
        controller's per-request writes."""
        with self.lock:
            self.commits += n

    def apply(self, state):
        """Apply the staged epoch to ``state`` and broadcast the new
        store to every subscribed view atomically (one lock hold covers
        the apply, the counter bump and all view updates). With a
        journal, the epoch is made durable (write-ahead) before the
        apply; the ``commit_apply`` fault site fires between the two —
        the kill-mid-epoch point the recovery property tests. Returns
        the new store."""
        with self.lock:
            if not self.buffer.pending:
                return state
            records, soft_clears, touches = self.buffer.take_ops()
            epoch = self.buffer.epoch + 1
            manifest = None
            if self.journal is not None:
                if self.state_provider is not None:
                    manifest = self.state_provider()
                self.journal.log_epoch(epoch, records, soft_clears,
                                       touches, manifest)
            if self.fault_plan is not None:
                self.fault_plan.fire("commit_apply", epoch=epoch)
            state, n = self.buffer.apply_ops(state, records, soft_clears,
                                             touches)
            self.commits += n
            for v in self._views:
                v.memory = state
                v.commit_epoch_seen = self.buffer.epoch
            if self.ops_listener is not None:
                self.ops_listener(epoch, records, soft_clears, touches,
                                  n)
            if self.metrics is not None:
                with self.metrics.lock:
                    self.metrics.counter("commit/epochs_applied").inc()
                    self.metrics.counter("commit/entries_applied").inc(n)
                    self.metrics.gauge("commit/epoch").set(
                        self.buffer.epoch)
            if self.journal is not None:
                self.journal.maybe_snapshot(state, self.buffer, manifest)
        return state

    def grow(self, state, new_capacity: int):
        """Grow the stream's store in place (capacity re-layout) and
        re-broadcast it to every subscribed view atomically. Refuses
        while commit ops are staged — a wrapped-ring grow moves slot
        indices, so staged flag ops (which carry old indices) must drain
        first; see :func:`grow_memory`. Each view's ``_ptr_base`` is
        rebased to the grown pointer so the serve path's host-side
        ``ptr_snapshot`` arithmetic (``_ptr_base + commits``) stays exact
        across the grow. Returns ``(new_state, remap)``."""
        with self.lock:
            if self.buffer.pending:
                raise RuntimeError(
                    f"grow with {self.buffer.pending} staged commit ops; "
                    f"drain (apply) the epoch first")
            if isinstance(state, MemoryState):
                state, remap = grow_memory(state, new_capacity)
            else:
                state, remap = state.grow(new_capacity)
            new_ptr = int(jax.device_get(state.ptr))
            for v in self._views:
                v.memory = state
                if hasattr(v, "_ptr_base"):
                    v._ptr_base = new_ptr - self.commits
            return state, remap

    def checkpoint(self) -> None:
        """Journal a manifest-only record at the current epoch — called
        at clean shutdown (and by tests) so engine state that advanced
        past the last store commit survives a later kill. No-op without
        a journal or a state provider."""
        with self.lock:
            if self.journal is None or self.state_provider is None:
                return
            self.journal.log_checkpoint(self.buffer.epoch,
                                        self.state_provider())

    def commit_direct(self, state, *, record=None, soft_clear=None,
                      touch_op=None):
        """Commit the sequential controller's per-request write as one
        single-op epoch through the staged path (so it hits the journal
        like any drain epoch). ``record`` is a ``stage_add`` tuple
        ``(emb, guide, has_guide, hard, now)``; ``soft_clear`` /
        ``touch_op`` are ``(index, now, ptr_snapshot)``. Returns the new
        store. Byte-identical to the direct ``add``/``mark_soft``/
        ``touch`` calls it replaces (a K=1 ``add_batch`` is the pinned
        equivalent of ``add``) — the sequential controller only routes
        through here when a journal is attached."""
        with self.lock:
            if record is not None:
                emb, guide, has_guide, hard, now = record
                self.buffer.stage_add(emb, guide, has_guide, hard, now)
            if soft_clear is not None:
                self.buffer.stage_soft_clear(*soft_clear)
            if touch_op is not None:
                self.buffer.stage_touch(*touch_op)
            return self.apply(state)


def open_journaled_stream(path: str, mem_cfg: MemoryConfig, *,
                          snapshot_every: int = 8, fault_plan=None):
    """Open (or re-open after a crash) a journaled commit stream at
    ``path``. Returns ``(stream, recovered_state, manifest)`` —
    ``recovered_state`` is the byte-identical pre-crash store and
    ``manifest`` the engine-state dict journaled with its last epoch
    (both ``None`` for a fresh site). The stream's buffer counters
    resume from the recovered epoch, so WAL epoch numbering stays
    monotone across restarts."""
    recovered = MemoryJournal.recover(path, mem_cfg)
    journal = MemoryJournal(path, snapshot_every=snapshot_every,
                            fault_plan=fault_plan)
    stream = CommitStream(journal=journal, fault_plan=fault_plan)
    state, manifest = None, None
    if recovered is not None:
        state, epoch, entries, manifest = recovered
        stream.buffer.epoch = epoch
        stream.buffer.entries_applied = entries
    return stream, state, manifest


# ---------------------------------------------------------------------------
# Public API — thin dispatchers so the controllers (``core.rar`` /
# ``core.pipeline``) serve identically against the single-device
# MemoryState (functional, jitted) or a ``core.memory_sharded``
# ShardedMemory (method-based, returns itself after in-place update).
# ---------------------------------------------------------------------------


def query(state, emb: jax.Array, guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search. ``guides_only`` restricts to guide entries
    (the guide-memory view used during shadow inference) via the mask bit
    plane — same single store pass, no mask combine. Kernel + metadata
    epilogue are one jitted call returning one packed struct."""
    if isinstance(state, MemoryState):
        return _query_jit(state, emb, guides_only=guides_only)
    return state.query(emb, guides_only=guides_only)


def query_batch(state, embs: jax.Array,
                guides_only: bool = False) -> QueryResult:
    """Top-1 cosine search for a whole microbatch of queries in one store
    pass. embs (B, E) → QueryResult with leading B axis. All queries see
    the same snapshot of the store (reads happen at microbatch start;
    writes commit at microbatch end via :func:`add_batch`)."""
    if isinstance(state, MemoryState):
        return _query_batch_jit(state, embs, guides_only=guides_only)
    return state.query_batch(embs, guides_only=guides_only)


def _check_k(k: int, capacity: int) -> None:
    # the upper bound holds on every backend: the Pallas kernel's (k, B)
    # accumulator must fit one grid-step merge (k <= kernel block), and
    # capping here also bounds the ref oracle's k unrolled selection
    # rounds — the dispatch contract cannot depend on which impl runs
    bound = min(capacity, DEFAULT_BLOCK_C)
    if not 1 <= k <= bound:
        raise ValueError(f"retrieval k={k} must be in [1, {bound}] "
                         f"(min of capacity={capacity} and the kernel "
                         f"block {DEFAULT_BLOCK_C})")


def query_topk(state, emb: jax.Array, k: int,
               guides_only: bool = False) -> TopKResult:
    """Top-k cosine search in the same single store pass as :func:`query`
    (k = 1 is bit-identical to it). Entries arrive sorted by
    (sim desc, store row asc); slots past the view's population carry the
    -2.0 sentinel. The multi-guide serving read
    (``core.rar.splice_guides``)."""
    _check_k(k, state.capacity)
    if isinstance(state, MemoryState):
        return _query_topk_jit(state, emb, k, guides_only=guides_only)
    return state.query_topk(emb, k, guides_only=guides_only)


def query_topk_batch(state, embs: jax.Array, k: int,
                     guides_only: bool = False) -> TopKResult:
    """Top-k search for a whole microbatch in one store pass: embs (B, E)
    → TopKResult with (B, k) leading axes. Snapshot semantics match
    :func:`query_batch`."""
    _check_k(k, state.capacity)
    if isinstance(state, MemoryState):
        return _query_topk_batch_jit(state, embs, k,
                                     guides_only=guides_only)
    return state.query_topk_batch(embs, k, guides_only=guides_only)


def add(state, emb: jax.Array, guide: jax.Array, has_guide: jax.Array,
        hard: jax.Array, now: jax.Array):
    """Insert one entry at the ring pointer (FIFO eviction). Scatters one
    padded row in place — the store is never re-materialized."""
    if isinstance(state, MemoryState):
        return _add_jit(state, emb, guide, has_guide, hard, now)
    state.add(emb, guide, has_guide, hard, now)
    return state


def add_batch(state, embs: jax.Array, guides: jax.Array,
              has_guide: jax.Array, hard: jax.Array, now: jax.Array):
    """Insert K entries at consecutive ring slots in one jitted call — the
    microbatch commit (all of a batch's shadow-inference writes land
    together). embs (K, E); guides (K, G); has_guide/hard (K,) bool;
    now (K,) int32 per-entry logical times. Equivalent to K sequential
    :func:`add` calls for K ≤ capacity (slot indices are then distinct, so
    the scatter order cannot matter)."""
    if isinstance(state, MemoryState):
        return _add_batch_jit(state, embs, guides, has_guide, hard, now)
    state.add_batch(embs, guides, has_guide, hard, now)
    return state


def mark_soft(state, index: jax.Array):
    """Clear a hard flag after a successful re-probe (Case 3 → Case 1/2).
    ``index`` may be a scalar or a (K,) batch of indices (the microbatch
    commit's flag pass)."""
    if isinstance(state, MemoryState):
        return _mark_soft_jit(state, index)
    state.mark_soft(index)
    return state


def touch(state, index: jax.Array, now: jax.Array):
    """Refresh an entry's timestamp (restarts the re-probe cool-down).
    ``index``/``now`` may be scalars or matching (K,) batches."""
    if isinstance(state, MemoryState):
        return _touch_jit(state, index, now)
    state.touch(index, now)
    return state
