"""Sharded skill/guide memory — the (C, E) ring spread across devices.

Scales the store past one device's HBM (the ROADMAP's "sharded memory"
item): logical ring slots [0, C) are row-sharded over a 1-D ``"mem"`` mesh
axis, shard *s* owning slots [s·Cs, (s+1)·Cs) with Cs = C/S. Each shard
keeps its slice in the same persistent padded kernel layout as the
single-device :class:`repro.core.memory.MemoryState` — (Csp, Ep) f32
embeddings plus the (Csp, 1) int32 valid/has_guide mask bit plane — so the
read path per shard is the *identical* zero-copy Pallas kernel
(:mod:`repro.kernels.memory_topk` via ``shard_map``), streaming only the
local shard once per query.

Combine: each shard produces its local (best sim, best row, mask bits);
an all-gather of those S-scalar triples plus an argmax over the shard axis
yields the global (sim, index). ``argmax`` takes the first maximum, so
cross-shard ties resolve to the lowest shard — which, with the in-kernel
lowest-row tie-break, makes the result **bit-identical** to the
single-device kernel (same f32 row dot products, same lowest-global-row
tie-break; asserted in ``tests/test_memory_sharded.py``). At S scalars per
query the gather is equivalent to a psum-tree combine and simpler.

Top-k (:meth:`ShardedMemory.query_topk` / :meth:`query_topk_batch`): each
shard computes its local top-k with the same zero-copy kernel, the S·k
(sim, global row, mask bits) candidate triples are all-gathered and
re-selected by the shared (sim desc, row asc) extraction rule
(:func:`_merge_topk` — the same total order as the kernel accumulator and
the ref oracle), so the global top-k is bit-identical to single-device,
ties included. k is capped at Cs rows so a shard's candidates can never
include local padding rows, whose global slot numbers would collide with
the next shard's.

Writes: FIFO ring-pointer arithmetic maps a global slot g to
(shard g // Cs, row g mod Cs). A microbatch commit broadcasts the K padded
rows + mask bits with their global slots; every shard turns the slots into
local rows, clamps out-of-range ones to the (out-of-bounds) padding row
and scatters with ``mode="drop"`` — one scatter per shard regardless of
how the batch straddles shard boundaries. Per-entry metadata that never
feeds the kernel (guide tokens, hard flags, timestamps — O(C·G) int32,
bytes next to the O(C·E) f32 store) stays replicated so the query epilogue
and flag updates (:meth:`mark_soft`/:meth:`touch`) remain single cheap
scatters.

The controller-facing API mirrors :mod:`repro.core.memory`:
:meth:`ShardedMemory.query` / :meth:`query_batch` return the same packed
:class:`~repro.core.memory.QueryResult`, and
:meth:`add` / :meth:`add_batch` / :meth:`mark_soft` / :meth:`touch` keep
microbatch-commit semantics, so ``MicrobatchRAR`` can serve against either
store.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import memory as mem
from repro.kernels import ops as kops
from repro.kernels.memory_topk import (MASK_VALID, _select_topk,
                                       padded_lanes, padded_rows)

AXIS = "mem"


def make_memory_mesh(shards: int | None = None,
                     devices: list | None = None) -> Mesh:
    """1-D mesh over the devices carrying the store."""
    devices = devices if devices is not None else jax.devices()
    shards = shards or len(devices)
    return jax.make_mesh((shards,), (AXIS,), devices=devices[:shards])


# ---------------------------------------------------------------------------
# Jitted collectives (mesh/geometry static, shapes traced)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mesh", "cs", "required"))
def _query_sharded(mesh: Mesh, cs: int, required: int,
                   emb: jax.Array, mask: jax.Array, q: jax.Array
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single query → replicated (sim (), global logical idx (), bits ())."""

    def local(emb_s, mask_s, q):
        sim, idx = kops.memory_top1_padded(emb_s, q, mask_s, required)
        bits = mask_s[idx, 0]
        sims = jax.lax.all_gather(sim, AXIS)          # (S,)
        idxs = jax.lax.all_gather(idx, AXIS)
        bitss = jax.lax.all_gather(bits, AXIS)
        s = jnp.argmax(sims)            # first max → lowest shard on ties
        return sims[s], s.astype(jnp.int32) * cs + idxs[s], bitss[s]

    return shard_map(local, mesh=mesh,
                     in_specs=(P(AXIS, None), P(AXIS, None), P()),
                     out_specs=(P(), P(), P()), check_rep=False
                     )(emb, mask, q)


@partial(jax.jit, static_argnames=("mesh", "cs", "required"))
def _query_batch_sharded(mesh: Mesh, cs: int, required: int,
                         emb: jax.Array, mask: jax.Array, qs: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched queries → replicated (sims (B,), idx (B,), bits (B,))."""

    def local(emb_s, mask_s, qs):
        sim, idx = kops.memory_top1_batch_padded(emb_s, qs, mask_s, required)
        bits = mask_s[idx, 0]
        sims = jax.lax.all_gather(sim, AXIS)          # (S, B)
        idxs = jax.lax.all_gather(idx, AXIS)
        bitss = jax.lax.all_gather(bits, AXIS)
        s = jnp.argmax(sims, axis=0)                  # (B,)
        take = lambda a: jnp.take_along_axis(a, s[None], axis=0)[0]  # noqa: E731
        return take(sims), s.astype(jnp.int32) * cs + take(idxs), take(bitss)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(AXIS, None), P(AXIS, None), P()),
                     out_specs=(P(), P(), P()), check_rep=False
                     )(emb, mask, qs)


def _merge_topk(sims: jax.Array, rows: jax.Array, bits: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Global top-k of the (S·k, …) per-shard candidates via the
    kernel's own selection rule (:func:`…memory_topk._select_topk` —
    sim desc, global row asc), so the combined result is bit-identical
    to single-device, ties included. Global rows are unique across
    candidates (shards own disjoint slot ranges and k ≤ Cs keeps local
    padding rows out of the per-shard top-k), so the winners' mask bits
    recover through a one-hot row-match sum."""
    out_s, out_r = _select_topk(sims, rows, k)
    hit = rows[None] == out_r[:, None]             # (k, S·k, …) one-hot
    out_b = jnp.sum(jnp.where(hit, bits[None], 0), axis=1)
    return out_s, out_r, out_b


@partial(jax.jit, static_argnames=("mesh", "cs", "k", "required"))
def _query_topk_sharded(mesh: Mesh, cs: int, k: int, required: int,
                        emb: jax.Array, mask: jax.Array, q: jax.Array
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single query → replicated (sims (k,), global idx (k,), bits (k,))."""

    def local(emb_s, mask_s, q):
        sims, idx = kops.memory_topk_padded(emb_s, q, mask_s, k, required)
        bits = mask_s[idx, 0]
        s = jax.lax.axis_index(AXIS)
        S = jax.lax.psum(1, AXIS)
        cand_s = jax.lax.all_gather(sims, AXIS).reshape(S * k)
        cand_r = jax.lax.all_gather(s.astype(jnp.int32) * cs + idx,
                                    AXIS).reshape(S * k)
        cand_b = jax.lax.all_gather(bits, AXIS).reshape(S * k)
        return _merge_topk(cand_s, cand_r, cand_b, k)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(AXIS, None), P(AXIS, None), P()),
                     out_specs=(P(), P(), P()), check_rep=False
                     )(emb, mask, q)


@partial(jax.jit, static_argnames=("mesh", "cs", "k", "required"))
def _query_topk_batch_sharded(mesh: Mesh, cs: int, k: int, required: int,
                              emb: jax.Array, mask: jax.Array,
                              qs: jax.Array
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched queries → replicated ((B, k) sims, idx, bits)."""

    def local(emb_s, mask_s, qs):
        sims, idx = kops.memory_topk_batch_padded(emb_s, qs, mask_s, k,
                                                  required)      # (B, k)
        bits = mask_s[idx, 0]
        s = jax.lax.axis_index(AXIS)
        S = jax.lax.psum(1, AXIS)
        B = qs.shape[0]
        gather = lambda a: jax.lax.all_gather(                 # noqa: E731
            a.T, AXIS).reshape(S * k, B)                       # (S·k, B)
        out_s, out_r, out_b = _merge_topk(
            gather(sims), gather(s.astype(jnp.int32) * cs + idx),
            gather(bits), k)                                   # (k, B)
        return out_s.T, out_r.T, out_b.T

    return shard_map(local, mesh=mesh,
                     in_specs=(P(AXIS, None), P(AXIS, None), P()),
                     out_specs=(P(), P(), P()), check_rep=False
                     )(emb, mask, qs)


@partial(jax.jit, static_argnames=("mesh", "cs", "csp"))
def _commit_sharded(mesh: Mesh, cs: int, csp: int,
                    emb: jax.Array, mask: jax.Array,
                    rows_p: jax.Array, bits: jax.Array, slots: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Scatter K padded rows + mask bits at global logical ``slots`` —
    exactly one scatter per shard (out-of-shard entries clamp to the
    padding row and drop)."""

    def local(emb_s, mask_s, rows_p, bits, slots):
        s = jax.lax.axis_index(AXIS)
        loc = slots - s * cs
        in_range = (loc >= 0) & (loc < cs)
        rows = jnp.where(in_range, loc, csp)          # csp = OOB → dropped
        return (emb_s.at[rows].set(rows_p, mode="drop"),
                mask_s.at[rows, 0].set(bits, mode="drop"))

    return shard_map(local, mesh=mesh,
                     in_specs=(P(AXIS, None), P(AXIS, None), P(), P(), P()),
                     out_specs=(P(AXIS, None), P(AXIS, None)),
                     check_rep=False)(emb, mask, rows_p, bits, slots)


@jax.jit
def _commit_meta(guide, hard, added_at, slots, guides, hards, nows):
    """The replicated-metadata half of a commit as one fused dispatch
    (mirrors the single-device ``_add_batch_jit``)."""
    return (guide.at[slots].set(guides),
            hard.at[slots].set(hards),
            added_at.at[slots].set(nows))


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------


class ShardedMemory:
    """Row-sharded ring store with the single-device query/commit API."""

    def __init__(self, cfg: mem.MemoryConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else make_memory_mesh()
        self.shards = self.mesh.shape[AXIS]
        if cfg.capacity % self.shards:
            raise ValueError(f"capacity {cfg.capacity} not divisible by "
                             f"{self.shards} shards")
        self.cs = cfg.capacity // self.shards         # logical rows/shard
        self.csp = padded_rows(self.cs)               # padded rows/shard
        self.ep = padded_lanes(cfg.embed_dim)
        row_sharded = NamedSharding(self.mesh, P(AXIS, None))
        repl = NamedSharding(self.mesh, P())
        S, C, G = self.shards, cfg.capacity, cfg.guide_len
        self.emb = jax.device_put(
            jnp.zeros((S * self.csp, self.ep), jnp.float32), row_sharded)
        self.mask = jax.device_put(
            jnp.zeros((S * self.csp, 1), jnp.int32), row_sharded)
        self.guide = jax.device_put(jnp.zeros((C, G), jnp.int32), repl)
        self.hard = jax.device_put(jnp.zeros((C,), bool), repl)
        self.added_at = jax.device_put(jnp.zeros((C,), jnp.int32), repl)
        self.ptr = jnp.zeros((), jnp.int32)

    # -- occupancy ------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    @property
    def size_fast(self) -> int:
        return min(int(self.ptr), self.capacity)

    # -- reads ----------------------------------------------------------
    def query(self, emb: jax.Array,
              guides_only: bool = False) -> mem.QueryResult:
        sim, idx, bits = _query_sharded(self.mesh, self.cs,
                                        mem.required_bits(guides_only),
                                        self.emb, self.mask,
                                        jnp.asarray(emb))
        return mem.QueryResult(
            sim=sim, meta=mem.pack_meta_jit(idx, bits, self.hard,
                                            self.added_at, self.guide))

    def query_batch(self, embs: jax.Array,
                    guides_only: bool = False) -> mem.QueryResult:
        sims, idx, bits = _query_batch_sharded(self.mesh, self.cs,
                                               mem.required_bits(guides_only),
                                               self.emb, self.mask,
                                               jnp.asarray(embs))
        return mem.QueryResult(
            sim=sims, meta=mem.pack_meta_jit(idx, bits, self.hard,
                                             self.added_at, self.guide))

    def _check_topk(self, k: int) -> None:
        mem._check_k(k, self.capacity)
        if k > self.cs:
            # each shard must supply k real (non-padding) local rows so
            # the global merge never sees a local padding row, whose
            # global slot number would collide with the next shard's
            raise ValueError(f"retrieval k={k} exceeds the {self.cs} "
                             f"logical rows per shard ({self.shards} "
                             f"shards over capacity {self.capacity})")

    def query_topk(self, emb: jax.Array, k: int,
                   guides_only: bool = False) -> mem.TopKResult:
        self._check_topk(k)
        sims, idx, bits = _query_topk_sharded(
            self.mesh, self.cs, k, mem.required_bits(guides_only),
            self.emb, self.mask, jnp.asarray(emb))
        return mem.TopKResult(
            sim=sims, meta=mem.pack_meta_jit(idx, bits, self.hard,
                                             self.added_at, self.guide))

    def query_topk_batch(self, embs: jax.Array, k: int,
                         guides_only: bool = False) -> mem.TopKResult:
        self._check_topk(k)
        sims, idx, bits = _query_topk_batch_sharded(
            self.mesh, self.cs, k, mem.required_bits(guides_only),
            self.emb, self.mask, jnp.asarray(embs))
        return mem.TopKResult(
            sim=sims, meta=mem.pack_meta_jit(idx, bits, self.hard,
                                             self.added_at, self.guide))

    # -- writes ---------------------------------------------------------
    def add(self, emb: jax.Array, guide: jax.Array, has_guide, hard,
            now) -> None:
        self.add_batch(jnp.asarray(emb)[None], jnp.asarray(guide)[None],
                       jnp.asarray([has_guide]), jnp.asarray([hard]),
                       jnp.asarray([now], jnp.int32))

    def add_batch(self, embs: jax.Array, guides: jax.Array,
                  has_guide: jax.Array, hard: jax.Array,
                  now: jax.Array) -> None:
        """Microbatch commit at consecutive ring slots (FIFO), identical
        semantics to :func:`repro.core.memory.add_batch`."""
        K, C = embs.shape[0], self.capacity
        if K > C:
            raise ValueError(f"microbatch commit of {K} entries exceeds "
                             f"memory capacity {C}")
        slots = (self.ptr + jnp.arange(K, dtype=jnp.int32)) % C
        # same encoding helpers as MemoryState — the bit layout must never
        # diverge between the two stores
        rows_p = mem._pad_lanes(jnp.asarray(embs), self.ep)
        bits = mem._mask_bits(jnp.asarray(has_guide))
        self.emb, self.mask = _commit_sharded(
            self.mesh, self.cs, self.csp, self.emb, self.mask,
            rows_p, bits, slots)
        self.guide, self.hard, self.added_at = _commit_meta(
            self.guide, self.hard, self.added_at, slots,
            jnp.asarray(guides), jnp.asarray(hard), jnp.asarray(now))
        self.ptr = self.ptr + K

    def mark_soft(self, index: jax.Array) -> None:
        self.hard = self.hard.at[index].set(False)

    def touch(self, index: jax.Array, now: jax.Array) -> None:
        self.added_at = self.added_at.at[index].set(now)

    # -- debug / parity -------------------------------------------------
    def debug_size(self) -> int:
        """Debugging-only occupancy — a blocking cross-shard reduction
        (device sync); a method, not a property, so the sync is loud at
        call sites. Hot paths use :attr:`size_fast` / host counters (see
        :meth:`repro.core.memory.MemoryState.debug_size`)."""
        return int(jnp.sum((jnp.asarray(self.mask)[:, 0] & MASK_VALID)
                           != 0))

    def to_single_device(self) -> mem.MemoryState:
        """Gather the shards back into a single-device
        :class:`~repro.core.memory.MemoryState` (tests/checkpointing)."""
        C, E = self.cfg.capacity, self.cfg.embed_dim
        S = self.shards
        emb = jnp.asarray(self.emb).reshape(S, self.csp, self.ep)
        emb = emb[:, :self.cs].reshape(C, self.ep)
        bits = jnp.asarray(self.mask).reshape(S, self.csp)
        bits = bits[:, :self.cs].reshape(C)
        state = mem.init_memory(self.cfg)
        return dataclasses.replace(
            state,
            emb=state.emb.at[:C].set(emb),
            mask=state.mask.at[:C, 0].set(bits),
            guide=jnp.asarray(self.guide),
            hard=jnp.asarray(self.hard),
            added_at=jnp.asarray(self.added_at),
            ptr=jnp.asarray(self.ptr),
        )


# ---------------------------------------------------------------------------
# Parity self-test — run as ``python -m repro.core.memory_sharded`` with
# ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to exercise a real
# multi-shard mesh on CPU (used by tests/test_memory_sharded.py and
# benchmarks/memory_bench.py via subprocess, since forcing placeholder
# devices must happen before jax initializes).
# ---------------------------------------------------------------------------


def parity_selftest(capacity: int = 64, embed_dim: int = 16,
                    guide_len: int = 4, n_commits: int = 6,
                    n_queries: int = 16, seed: int = 0) -> dict:
    """Drive a single-device MemoryState and a ShardedMemory through the
    same commit stream (wraparound, duplicate rows for tie-breaks) and
    assert bit-identical (sim, idx) — and full metadata — on every query,
    in both mask views. Every other commit wave is routed through the
    epoch-versioned :class:`repro.core.memory.CommitBuffer` (the shadow
    queue's deferred-commit path, staged in shuffled order + flag updates
    with duplicate targets) so the buffer's sorted apply is pinned
    bit-identical across both store flavours too. Returns a summary
    dict."""
    import numpy as np

    cfg = mem.MemoryConfig(capacity=capacity, embed_dim=embed_dim,
                           guide_len=guide_len)
    rng = np.random.default_rng(seed)
    single = mem.init_memory(cfg)
    sharded = ShardedMemory(cfg)
    checks = 0
    deferred_epochs = 0
    for step in range(n_commits):
        K = int(rng.integers(1, max(2, capacity // 2)))
        embs = rng.normal(size=(K, embed_dim)).astype(np.float32)
        embs /= np.linalg.norm(embs, axis=1, keepdims=True)
        if K > 3:
            embs[2] = embs[0]          # exact duplicate → tie-break path
        guides = rng.integers(0, 50, size=(K, guide_len)).astype(np.int32)
        hg = rng.random(K) < 0.5
        hd = rng.random(K) < 0.3
        now = (np.arange(K) + step * capacity).astype(np.int32)
        args = (jnp.asarray(embs), jnp.asarray(guides), jnp.asarray(hg),
                jnp.asarray(hd), jnp.asarray(now))
        if step % 2:
            # deferred-commit sweep: stage in a shuffled order (the apply
            # must sort by logical time), plus flag updates incl. a
            # duplicate touch target (last-now-wins) — one epoch apply
            # per store, then the usual bit-identical query checks below
            order = rng.permutation(K)
            stores = [single, sharded]
            for si, store in enumerate(stores):
                buf = mem.CommitBuffer()
                for j in order:
                    buf.stage_add(embs[j], guides[j], bool(hg[j]),
                                  bool(hd[j]), int(now[j]))
                t = int(now[-1])
                buf.stage_touch(0, t + 1)
                buf.stage_touch(0, t + 2)      # duplicate → later now wins
                buf.stage_soft_clear(1, t + 1)
                stores[si], n = buf.apply(store)
                assert n == K and buf.epoch == 1 and buf.pending == 0
            single, sharded = stores
            deferred_epochs += 1
        else:
            single = mem.add_batch(single, *args)
            sharded.add_batch(*args)

        qs = rng.normal(size=(n_queries, embed_dim)).astype(np.float32)
        qs /= np.linalg.norm(qs, axis=1, keepdims=True)
        qs[0] = embs[0]                # exact stored row (duplicated above)
        topks = [k for k in (1, 2, 4, 8)
                 if k <= capacity // sharded.shards]
        for guides_only in (False, True):
            a = mem.query_batch(single, jnp.asarray(qs),
                                guides_only=guides_only).device_get()
            b = sharded.query_batch(jnp.asarray(qs),
                                    guides_only=guides_only).device_get()
            assert np.array_equal(a.sim, b.sim), (step, a.sim, b.sim)
            assert np.array_equal(a.meta, b.meta), (step, a.meta, b.meta)
            a1 = mem.query(single, jnp.asarray(qs[0]),
                           guides_only=guides_only).device_get()
            b1 = sharded.query(jnp.asarray(qs[0]),
                               guides_only=guides_only).device_get()
            assert float(a1.sim) == float(b1.sim)
            assert np.array_equal(a1.meta, b1.meta)
            checks += 2 * n_queries + 2
            # top-k: global merge of per-shard candidates must stay
            # bit-identical to the single-device kernel, ties included
            for k in topks:
                ak = mem.query_topk_batch(single, jnp.asarray(qs), k,
                                          guides_only=guides_only
                                          ).device_get()
                bk = sharded.query_topk_batch(jnp.asarray(qs), k,
                                              guides_only=guides_only
                                              ).device_get()
                assert np.array_equal(ak.sim, bk.sim), (step, k, ak.sim,
                                                        bk.sim)
                assert np.array_equal(ak.meta, bk.meta), (step, k)
                a1k = mem.query_topk(single, jnp.asarray(qs[0]), k,
                                     guides_only=guides_only).device_get()
                b1k = sharded.query_topk(jnp.asarray(qs[0]), k,
                                         guides_only=guides_only
                                         ).device_get()
                assert np.array_equal(a1k.sim, b1k.sim), (step, k)
                assert np.array_equal(a1k.meta, b1k.meta), (step, k)
                checks += 2 * n_queries * k + 2 * k
    assert sharded.size_fast == single.size_fast
    assert deferred_epochs > 0, "deferred-commit sweep never ran"
    return {"shards": sharded.shards, "capacity": capacity,
            "checks": checks, "topk_checked": topks,
            "deferred_commit_epochs": deferred_epochs,
            "bit_identical": True}


if __name__ == "__main__":
    import json
    print(json.dumps(parity_selftest()))
