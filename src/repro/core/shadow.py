"""Async shadow queue — continuous learning off the serve critical path.

RAR's adaptation loop (§III-D: weak-FM probes, strong-FM guide
generation, memory commits) is auxiliary work: the user already holds the
strong answer when it starts. The PR-1 microbatch controller ran it
*inside* ``process_batch``, so user-facing latency paid for learning. The
:class:`ShadowQueue` decouples the two planes: the serve sweep enqueues
one :class:`ShadowItem` per shadow request and returns; a drainer
coalesces pending items into shadow-microbatches, runs the three batched
shadow sweeps (weak-alone, guide-from-memory, fresh-guide) and lands all
memory writes through an epoch-versioned
:class:`repro.core.memory.CommitBuffer`, so in-flight queries always read
a consistent store snapshot.

Drain modes (``RARConfig.shadow_mode``)
---------------------------------------
* ``"inline"`` — drain synchronously inside every ``process_batch``
  (the PR-1 behaviour; the default).
* ``"deferred"`` — items accumulate across batches and drain
  synchronously at **barrier points**: automatically once
  ``shadow_flush_every`` batches are pending (0 = only on explicit
  :meth:`flush`). Because the drain runs the *identical schedule* on the
  caller's thread, ``deferred`` with flush-every-batch is byte-identical
  to ``inline`` — the machine-checkable equivalence hook that
  ``tests/test_shadow.py`` pins async correctness against.
* ``"async"`` — a daemon drainer thread wakes once ``shadow_flush_every``
  batches are pending and drains in the background; :meth:`flush` is the
  synchronous barrier (waits for the queue to empty and all commits to
  apply, re-raising any drainer exception).

Outcome resolution: shadow requests return immediately with the strong
answer and a provisional ``case="shadow_pending"`` Outcome; the drainer
mutates the same Outcome object in place (case, strong_calls,
guide_source) when its shadow pass resolves. After a :meth:`flush`
barrier every outstanding outcome is final.

Coalescing (``RARConfig.shadow_dedup_sim``): before a drain epoch the
drainer merges pending items whose embeddings are near-duplicates
(:func:`repro.core.decisions.coalesce_shadow_items`) so one shadow pass
resolves the whole group — duplicate skills enqueued before a drain no
longer each pay their own probe sweeps. The queue records the merged
item count (:attr:`ShadowQueue.items_coalesced`) and the probe calls the
followers skipped (:attr:`~ShadowQueue.reclaimed_weak_calls` /
:attr:`~ShadowQueue.reclaimed_strong_calls`).

Consistency: all store mutations (the drainer's commit-buffer apply) and
the serve path's snapshot reads happen under :attr:`store_lock`. For the
functional ``MemoryState`` the apply is a single reference swap; for the
mutable ``ShardedMemory`` the lock is what makes the multi-field update
atomic with respect to readers.

The queue itself is policy-free: the controller passes its drain function
(``MicrobatchRAR._drain_shadow``) as ``runner``; the queue only schedules
— coalescing, barriers, and the worker thread. ``drain_delay`` injects a
sleep before each drain (stress/soak-test hook, keep 0 in production).
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.rar import Outcome

MODES = ("inline", "deferred", "async")

#: provisional case label carried by a shadow request's Outcome until its
#: drain resolves it to case1/case2/case3
PENDING = "shadow_pending"


@dataclasses.dataclass
class ShadowItem:
    """One shadow request in flight: everything the drainer needs to run
    the three probe sweeps and resolve the provisional outcome."""
    seq: int                      # global enqueue order (drain tie-break)
    now: int                      # the request's logical time
    prompt: np.ndarray
    guide_request: np.ndarray
    emb: np.ndarray
    strong_ans: int               # user-facing answer, already served
    outcome: Outcome              # provisional; resolved in place at drain
    reprobe_index: int | None = None   # hard entry being re-probed, if any
    ptr_snapshot: int | None = None    # ring pointer at classification —
    #                                    eviction guard for the re-probe
    #                                    flag update (CommitBuffer)
    strong_calls: int = 1


class ShadowQueue:
    """Coalescing drain scheduler for the shadow plane (see module doc).

    ``runner(items)`` performs the actual shadow sweeps + commit apply;
    the queue guarantees each enqueued item is passed to ``runner``
    exactly once, in enqueue order, coalesced per drain epoch.
    """

    def __init__(self, runner, mode: str = "inline", flush_every: int = 1,
                 buffer=None, drain_delay: float = 0.0, store_lock=None,
                 fault_plan=None):
        if mode not in MODES:
            raise ValueError(f"shadow mode {mode!r} not in {MODES}")
        from repro.core.memory import CommitBuffer
        self.runner = runner
        self.mode = mode
        self.flush_every = flush_every
        self.buffer = buffer if buffer is not None else CommitBuffer()
        self.drain_delay = drain_delay
        # fault-injection hook: the "drain" site fires at the start of
        # every drain epoch (None = no-op)
        self.fault_plan = fault_plan
        # ``store_lock`` may be injected so several queues share one lock
        # (the fabric's replicas all serialize against the same
        # ``CommitStream.lock``); standalone queues own a private one
        self.store_lock = (store_lock if store_lock is not None
                           else threading.RLock())
        self._cv = threading.Condition()
        self._items: list[ShadowItem] = []
        self._batches = 0             # batches pending since last drain
        self._seq = 0
        self._flush_requested = False
        self._draining = False
        self._stop = False
        self._worker: threading.Thread | None = None
        self._error: BaseException | None = None
        # host-side stats (single GIL-protected writers)
        self.items_enqueued = 0
        self.items_drained = 0
        self.drains = 0
        # coalescing stats (``RARConfig.shadow_dedup_sim``): followers
        # merged into a leader's shadow pass, and the probe calls those
        # followers did not have to run (weak probes / fresh-guide strong
        # generations, counted at the leader's actual probe depth)
        self.items_coalesced = 0
        self.reclaimed_weak_calls = 0
        self.reclaimed_strong_calls = 0

    # -- enqueue --------------------------------------------------------
    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def submit(self, items: list[ShadowItem]) -> None:
        """Enqueue one serve batch's shadow items (may be empty — an empty
        batch still counts toward the flush cadence so drain latency is
        bounded in requests, not in shadow traffic)."""
        self._reraise()
        if self.mode == "inline":
            self.items_enqueued += len(items)
            if items:
                self._drain(items)
            return
        with self._cv:
            self._items.extend(items)
            self.items_enqueued += len(items)
            self._batches += 1
            due = self.flush_every > 0 and self._batches >= self.flush_every
            if self.mode == "async":
                if due:
                    self._ensure_worker()
                    self._cv.notify_all()
                return
        if due:                       # deferred: drain on caller thread
            self.flush()

    # -- barriers -------------------------------------------------------
    def flush(self, timeout: float | None = None) -> None:
        """Synchronous barrier: drain everything pending and apply all
        commits before returning. In async mode, waits for the worker
        (and re-raises any exception it hit); ``timeout`` bounds that
        wait — on expiry a :class:`TimeoutError` is raised and the
        pending work stays queued (the barrier can be retried)."""
        if self.mode == "async" and self._worker is not None \
                and self._worker.is_alive():
            with self._cv:
                self._flush_requested = True
                self._cv.notify_all()
                done = self._cv.wait_for(
                    lambda: (not self._items and not self._draining)
                    or self._error is not None, timeout=timeout)
                self._flush_requested = False
            if not done:
                raise TimeoutError(
                    f"shadow flush timed out after {timeout}s "
                    f"(drainer still busy)")
            self._reraise()
            return
        items = self._take()
        if items:
            self._drain(items)

    def drain_now(self, items: list[ShadowItem]) -> None:
        """Run one drain epoch synchronously over externally-held items —
        the deferred-probe *replay* path (items parked during a
        strong-tier outage never entered the queue). Counted in the
        enqueue/drain stats so ``items_enqueued == items_drained`` stays
        a barrier invariant."""
        if not items:
            return
        self._reraise()
        self.items_enqueued += len(items)
        self._drain(items)

    def close(self, timeout: float | None = 60) -> None:
        """Flush, then stop the worker thread. Idempotent; a later submit
        in async mode lazily restarts the worker.

        Raises on a wedged drainer instead of orphaning it: a
        :class:`TimeoutError` if the flush barrier cannot complete, a
        :class:`RuntimeError` if the worker thread does not exit within
        ``timeout`` — in both cases the worker reference is *kept* (the
        daemon is still live and may still drain into the store), so the
        caller knows the store is not quiesced and can retry."""
        self.flush(timeout=timeout)
        if self._worker is not None:
            with self._cv:
                self._stop = True
                self._cv.notify_all()
            self._worker.join(timeout=timeout)
            if self._worker.is_alive():
                raise RuntimeError(
                    f"shadow drainer did not stop within {timeout}s — "
                    f"the store is NOT quiesced (a live drainer may "
                    f"still apply commits); retry close() once it "
                    f"unwedges")
            self._worker = None
            self._stop = False

    # -- internals ------------------------------------------------------
    def _take(self) -> list[ShadowItem]:
        with self._cv:
            items, self._items = self._items, []
            self._batches = 0
            return items

    def _drain(self, items: list[ShadowItem]) -> None:
        if self.fault_plan is not None:
            # injected drainer fault: propagates like a real drain
            # exception (inline → caller; async → surfaced at barrier)
            self.fault_plan.fire("drain")
        if self.drain_delay:
            import time
            time.sleep(self.drain_delay)
        self.runner(items)
        self.items_drained += len(items)
        self.drains += 1

    def _reraise(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("shadow drainer failed") from err

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._loop,
                                            name="shadow-drainer",
                                            daemon=True)
            self._worker.start()

    def _due_locked(self) -> bool:
        if not self._items:
            return False
        return self._flush_requested or (
            self.flush_every > 0 and self._batches >= self.flush_every)

    def _loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._stop or self._due_locked())
                if self._stop and not self._items:
                    return
                items, self._items = self._items, []
                self._batches = 0
                self._draining = True
            try:
                if items:
                    self._drain(items)
            except BaseException as e:   # surfaced at the next barrier
                self._error = e
            finally:
                with self._cv:
                    self._draining = False
                    self._cv.notify_all()
