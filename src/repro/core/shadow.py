"""Async shadow queue — continuous learning off the serve critical path.

RAR's adaptation loop (§III-D: weak-FM probes, strong-FM guide
generation, memory commits) is auxiliary work: the user already holds the
strong answer when it starts. The PR-1 microbatch controller ran it
*inside* ``process_batch``, so user-facing latency paid for learning. The
:class:`ShadowQueue` decouples the two planes: the serve sweep enqueues
one :class:`ShadowItem` per shadow request and returns; a drainer
coalesces pending items into shadow-microbatches, runs the three batched
shadow sweeps (weak-alone, guide-from-memory, fresh-guide) and lands all
memory writes through an epoch-versioned
:class:`repro.core.memory.CommitBuffer`, so in-flight queries always read
a consistent store snapshot.

Drain modes (``RARConfig.shadow_mode``)
---------------------------------------
* ``"inline"`` — drain synchronously inside every ``process_batch``
  (the PR-1 behaviour; the default).
* ``"deferred"`` — items accumulate across batches and drain
  synchronously at **barrier points**: automatically once
  ``shadow_flush_every`` batches are pending (0 = only on explicit
  :meth:`flush`). Because the drain runs the *identical schedule* on the
  caller's thread, ``deferred`` with flush-every-batch is byte-identical
  to ``inline`` — the machine-checkable equivalence hook that
  ``tests/test_shadow.py`` pins async correctness against.
* ``"async"`` — a daemon drainer thread wakes once ``shadow_flush_every``
  batches are pending and drains in the background; :meth:`flush` is the
  synchronous barrier (waits for the queue to empty and all commits to
  apply, re-raising any drainer exception).
* ``"adaptive"`` — deferred-style caller-thread drains, but the *when*
  is decided by a cost model instead of a fixed cadence: a
  :class:`DrainPolicy` (default :class:`AdaptiveDrainPolicy`) estimates
  the expected staleness cost of the pending set — re-shadow probability
  × per-item probe cost, both fit online from the observed drain-cost
  history — and drains once it exceeds the amortized fixed overhead of
  one more drain epoch. ``shadow_flush_every`` is demoted to a hard
  staleness cap (drain no later than N batches; 0 = uncapped). The
  policy may be **shared by several queues** (the serving fabric
  registers every replica's queue with one policy), in which case a
  drain decision flushes the whole group — the global adaptive cadence:
  the learn replica sees every replica's staleness, not just its own.

Failed drains are never lossy: if the drainer raises (a transient
``TierError``, an injected ``drain``-site fault), the epoch's items are
re-queued **at the head** in seq order before the exception propagates,
so the next barrier retries them — ``items_enqueued == items_drained``
is restored once the fault clears, and no Outcome is stranded at
``shadow_pending``. (The drain runner is responsible for rolling back
its own partial staging — see ``MicrobatchRAR._drain_shadow`` — so a
retry is byte-identical to a first run.) The async worker holds a failed
epoch back until a new submit or an explicit flush instead of hot-
looping on a persistent error.

Outcome resolution: shadow requests return immediately with the strong
answer and a provisional ``case="shadow_pending"`` Outcome; the drainer
mutates the same Outcome object in place (case, strong_calls,
guide_source) when its shadow pass resolves. After a :meth:`flush`
barrier every outstanding outcome is final.

Coalescing (``RARConfig.shadow_dedup_sim``): before a drain epoch the
drainer merges pending items whose embeddings are near-duplicates
(:func:`repro.core.decisions.coalesce_shadow_items`) so one shadow pass
resolves the whole group — duplicate skills enqueued before a drain no
longer each pay their own probe sweeps. The queue records the merged
item count (:attr:`ShadowQueue.items_coalesced`) and the probe calls the
followers skipped (:attr:`~ShadowQueue.reclaimed_weak_calls` /
:attr:`~ShadowQueue.reclaimed_strong_calls`).

Consistency: all store mutations (the drainer's commit-buffer apply) and
the serve path's snapshot reads happen under :attr:`store_lock`. For the
functional ``MemoryState`` the apply is a single reference swap; for the
mutable ``ShardedMemory`` the lock is what makes the multi-field update
atomic with respect to readers.

Metrics: the queue mirrors its stats into a
:class:`repro.serving.metrics.MetricsRegistry` (a private one unless the
owner injects a shared registry + name prefix, as the fabric does):
depth/staleness gauges, enqueue/drain/requeue counters, and drain-cost
histograms (items, probe calls, wall seconds, staleness per epoch) — all
host-side numbers, zero device syncs. The drain-cost histograms are what
the adaptive policy fits its cost model on.

The queue itself is policy-free: the controller passes its drain function
(``MicrobatchRAR._drain_shadow``) as ``runner``; the queue only schedules
— coalescing, barriers, and the worker thread. ``drain_delay`` injects a
sleep before each drain (stress/soak-test hook, keep 0 in production).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core.rar import Outcome

MODES = ("inline", "deferred", "async", "adaptive")

#: provisional case label carried by a shadow request's Outcome until its
#: drain resolves it to case1/case2/case3
PENDING = "shadow_pending"


@dataclasses.dataclass
class ShadowItem:
    """One shadow request in flight: everything the drainer needs to run
    the three probe sweeps and resolve the provisional outcome."""
    seq: int                      # global enqueue order (drain tie-break)
    now: int                      # the request's logical time
    prompt: np.ndarray
    guide_request: np.ndarray
    emb: np.ndarray
    strong_ans: int               # user-facing answer, already served
    outcome: Outcome              # provisional; resolved in place at drain
    reprobe_index: int | None = None   # hard entry being re-probed, if any
    ptr_snapshot: int | None = None    # ring pointer at classification —
    #                                    eviction guard for the re-probe
    #                                    flag update (CommitBuffer)
    strong_calls: int = 1


class DrainPolicy:
    """Base drain policy: **always drain** — every submit triggers a
    flush, which makes ``adaptive`` mode run the exact ``deferred``
    flush-every-batch schedule (the byte-identity hook
    ``tests/test_metrics.py`` pins the adaptive plumbing against).
    Subclasses override :meth:`due` with a real cost model."""

    def __init__(self):
        self.queues: list["ShadowQueue"] = []
        self.decisions = 0            # times due() was consulted

    def register(self, q: "ShadowQueue") -> None:
        """Attach a queue to this policy's drain group. A policy shared
        across queues makes every drain decision *global*: when it fires,
        the whole group flushes (the fabric's learn replica drains every
        replica's staleness, not just the submitter's)."""
        if q not in self.queues:
            self.queues.append(q)

    # -- signals ---------------------------------------------------------
    def pending_items(self) -> int:
        """Items pending across the whole drain group (GIL-atomic list
        reads; a heuristic input, not a synchronized count)."""
        return sum(len(q._items) for q in self.queues)

    def staleness_batches(self) -> int:
        return max((q._batches for q in self.queues), default=0)

    def note_drain(self, n_items: int, seconds: float) -> None:
        """Observed cost of one successful drain epoch (called by each
        queue after its runner returns)."""

    def due(self) -> bool:
        self.decisions += 1
        return True

    def stats(self) -> dict:
        return {"policy": type(self).__name__,
                "decisions": self.decisions}


class AdaptiveDrainPolicy(DrainPolicy):
    """Global staleness-cost vs drain-cost trade, fit online.

    Model: one drain epoch over ``n`` items costs roughly
    ``overhead + n · per_item`` wall seconds. Both coefficients are
    recovered by exponentially-decayed least squares over the observed
    ``(n_items, seconds)`` drain history (the same numbers the drain-cost
    histograms record). Waiting instead of draining risks *re-shadow
    work*: a pending item's near-duplicate arriving before the drain has
    to run its own probe sweeps (exactly the waste the coalescing stats
    measure), so the expected cost of holding the pending set one more
    batch is ``pending_items × p_reshadow × per_item``, with
    ``p_reshadow`` estimated from the group's lifetime duplicate rate
    (``items_coalesced / items_drained``, Laplace-smoothed by
    ``reshadow_prior`` so an idle store starts at the prior mean). Drain
    when that expected staleness cost exceeds the fixed ``overhead`` a
    drain epoch would amortize away.

    Cold start: until the regression is well-posed (≥ 2 epochs with
    distinct sizes) every decision is "drain" — the eager schedule is
    also how the model gets its first data points. A persistent
    "never drain" verdict is bounded by the queue-level
    ``flush_every`` staleness cap, not here.
    """

    def __init__(self, decay: float = 0.95,
                 reshadow_prior: tuple[float, float] = (1.0, 9.0)):
        super().__init__()
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay={decay} must be in (0, 1]")
        self.decay = decay
        self.reshadow_prior = reshadow_prior
        self._lock = threading.Lock()
        # decayed normal-equation sums for seconds ≈ a + b·items
        self._s1 = self._sn = self._st = 0.0
        self._snn = self._snt = 0.0
        self.cost_drains = 0          # drains the cost model asked for
        self.coldstart_drains = 0     # drains forced while under-fit

    def note_drain(self, n_items: int, seconds: float) -> None:
        with self._lock:
            d = self.decay
            self._s1 = self._s1 * d + 1.0
            self._sn = self._sn * d + n_items
            self._st = self._st * d + seconds
            self._snn = self._snn * d + n_items * n_items
            self._snt = self._snt * d + n_items * seconds

    def model(self) -> tuple[float, float] | None:
        """``(overhead_secs, per_item_secs)``, or None while the decayed
        regression is singular (too little size variance to separate the
        intercept from the slope)."""
        with self._lock:
            det = self._s1 * self._snn - self._sn * self._sn
            if self._s1 < 2.0 or det <= 1e-12:
                return None
            b = (self._s1 * self._snt - self._sn * self._st) / det
            a = (self._st * self._snn - self._sn * self._snt) / det
            return max(a, 0.0), max(b, 0.0)

    def reshadow_prob(self) -> float:
        pa, pb = self.reshadow_prior
        coal = sum(q.items_coalesced for q in self.queues)
        drained = sum(q.items_drained for q in self.queues)
        return (coal + pa) / (drained + pa + pb)

    def due(self) -> bool:
        self.decisions += 1
        pending = self.pending_items()
        if pending == 0:
            return False
        m = self.model()
        if m is None:
            self.coldstart_drains += 1
            return True
        overhead, per_item = m
        if pending * self.reshadow_prob() * per_item >= overhead:
            self.cost_drains += 1
            return True
        return False

    def stats(self) -> dict:
        out = super().stats()
        m = self.model()
        out.update({"cost_drains": self.cost_drains,
                    "coldstart_drains": self.coldstart_drains,
                    "reshadow_prob": self.reshadow_prob(),
                    "overhead_secs": m[0] if m else None,
                    "per_item_secs": m[1] if m else None})
        return out


class ShadowQueue:
    """Coalescing drain scheduler for the shadow plane (see module doc).

    ``runner(items)`` performs the actual shadow sweeps + commit apply;
    the queue guarantees each enqueued item is passed to ``runner``
    exactly once *successfully*, in enqueue order, coalesced per drain
    epoch — a failed drain re-queues its items for the next barrier.
    """

    def __init__(self, runner, mode: str = "inline", flush_every: int = 1,
                 buffer=None, drain_delay: float = 0.0, store_lock=None,
                 fault_plan=None, metrics=None, metrics_prefix: str = "",
                 drain_policy: DrainPolicy | None = None):
        if mode not in MODES:
            raise ValueError(f"shadow mode {mode!r} not in {MODES}")
        from repro.core.memory import CommitBuffer
        self.runner = runner
        self.mode = mode
        self.flush_every = flush_every
        self.buffer = buffer if buffer is not None else CommitBuffer()
        self.drain_delay = drain_delay
        # fault-injection hook: the "drain" site fires at the start of
        # every drain epoch (None = no-op)
        self.fault_plan = fault_plan
        # ``store_lock`` may be injected so several queues share one lock
        # (the fabric's replicas all serialize against the same
        # ``CommitStream.lock``); standalone queues own a private one
        self.store_lock = (store_lock if store_lock is not None
                           else threading.RLock())
        self._cv = threading.Condition()
        self._items: list[ShadowItem] = []
        self._batches = 0             # batches pending since last drain
        self._seq = 0
        self._flush_requested = False
        self._draining = False
        self._stop = False
        self._worker: threading.Thread | None = None
        self._error: BaseException | None = None
        # a failed async drain re-queues its items but must not hot-loop
        # on a persistent error: held back until a new submit or flush
        self._retry_holdback = False
        # host-side stats (single GIL-protected writers)
        self.items_enqueued = 0
        self.items_drained = 0
        self.drains = 0
        self.drain_failures = 0
        self.items_requeued = 0       # failed-epoch items put back (cum.)
        # coalescing stats (``RARConfig.shadow_dedup_sim``): followers
        # merged into a leader's shadow pass, and the probe calls those
        # followers did not have to run (weak probes / fresh-guide strong
        # generations, counted at the leader's actual probe depth)
        self.items_coalesced = 0
        self.reclaimed_weak_calls = 0
        self.reclaimed_strong_calls = 0
        # staleness tracking (host logical time; no device syncs)
        self.newest_now = 0           # max ``now`` ever enqueued
        self.last_drain_now = 0       # max ``now`` drained successfully
        self._staleness_at_take = 0   # batches pending at the last take
        self._probe_calls_last = 0    # runner-reported probe calls/epoch
        # adaptive cadence: a DrainPolicy decides when to drain (created
        # here unless the owner shares one across queues — the fabric's
        # global policy)
        if mode == "adaptive" and drain_policy is None:
            drain_policy = AdaptiveDrainPolicy()
        self.drain_policy = drain_policy
        if self.drain_policy is not None:
            self.drain_policy.register(self)
        # metrics plane: mirror stats into a registry (private unless the
        # owner injects the fabric-wide one + a per-replica prefix)
        if metrics is None:
            from repro.serving.metrics import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        p = metrics_prefix
        self._m_enq = metrics.counter(p + "items_enqueued")
        self._m_drained = metrics.counter(p + "items_drained")
        self._m_drains = metrics.counter(p + "drains")
        self._m_failures = metrics.counter(p + "drain_failures")
        self._m_requeued = metrics.counter(p + "items_requeued")
        self._m_depth = metrics.gauge(p + "depth_items")
        self._m_stale_b = metrics.gauge(p + "staleness_batches")
        self._m_stale_t = metrics.gauge(p + "staleness_logical")
        self._m_h_items = metrics.histogram(p + "drain_items")
        self._m_h_secs = metrics.histogram(p + "drain_seconds")
        self._m_h_probes = metrics.histogram(p + "drain_probe_calls")
        self._m_h_stale = metrics.histogram(p + "drain_staleness_batches")

    # -- enqueue --------------------------------------------------------
    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    @property
    def staleness_logical(self) -> int:
        """Logical time between the newest enqueued item and the last
        successful drain — 0 when fully drained."""
        if not self._items:
            return 0
        return max(0, self.newest_now - self.last_drain_now)

    def _sync_gauges_locked(self) -> None:
        """Mirror depth/staleness into the registry under ONE registry
        lock hold (snapshot consistency: the three gauges always agree)."""
        with self.metrics.lock:
            self._m_depth.set(len(self._items))
            self._m_stale_b.set(self._batches)
            self._m_stale_t.set(self.staleness_logical)

    def submit(self, items: list[ShadowItem]) -> None:
        """Enqueue one serve batch's shadow items (may be empty — an empty
        batch still counts toward the flush cadence so drain latency is
        bounded in requests, not in shadow traffic)."""
        self._reraise()
        if items:
            self.newest_now = max(self.newest_now, self._max_now(items))
        if self.mode == "inline":
            self.items_enqueued += len(items)
            self._m_enq.inc(len(items))
            # a failed epoch's re-queued items retry ahead of this batch
            # (empty unless a previous inline/flush drain raised)
            pending = self._take() + items
            if pending:
                self._staleness_at_take = 1
                self._drain(pending)
            return
        with self._cv:
            self._items.extend(items)
            self.items_enqueued += len(items)
            self._m_enq.inc(len(items))
            self._batches += 1
            self._retry_holdback = False      # new data: retry is fair game
            due = self.flush_every > 0 and self._batches >= self.flush_every
            self._sync_gauges_locked()
            if self.mode == "async":
                if due:
                    self._ensure_worker()
                    self._cv.notify_all()
                return
        if self.mode == "adaptive":
            # the cadence cap OR the cost model; a shared policy makes
            # the decision global and the flush group-wide
            if due or self.drain_policy.due():
                self._drain_group()
        elif due:                     # deferred: drain on caller thread
            self.flush()

    # -- barriers -------------------------------------------------------
    def flush(self, timeout: float | None = None) -> None:
        """Synchronous barrier: drain everything pending and apply all
        commits before returning. In async mode, waits for the worker
        (and re-raises any exception it hit); ``timeout`` bounds that
        wait — on expiry a :class:`TimeoutError` is raised and the
        pending work stays queued (the barrier can be retried)."""
        if self.mode == "async" and self._worker is not None \
                and self._worker.is_alive():
            with self._cv:
                self._flush_requested = True
                self._retry_holdback = False
                self._cv.notify_all()
                done = self._cv.wait_for(
                    lambda: (not self._items and not self._draining)
                    or self._error is not None, timeout=timeout)
                self._flush_requested = False
            if not done:
                raise TimeoutError(
                    f"shadow flush timed out after {timeout}s "
                    f"(drainer still busy)")
            self._reraise()
            return
        items = self._take()
        if items:
            self._drain(items)

    def _drain_group(self) -> None:
        """Flush every queue in the drain policy's group (adaptive mode:
        a global drain decision empties all replicas' staleness, funneled
        through the shared learn-replica drain)."""
        for q in self.drain_policy.queues:
            q.flush()

    def drain_now(self, items: list[ShadowItem]) -> None:
        """Run one drain epoch synchronously over externally-held items —
        the deferred-probe *replay* path (items parked during a
        strong-tier outage never entered the queue). Counted in the
        enqueue/drain stats so ``items_enqueued == items_drained`` stays
        a barrier invariant."""
        if not items:
            return
        self._reraise()
        self.items_enqueued += len(items)
        self._m_enq.inc(len(items))
        self.newest_now = max(self.newest_now, self._max_now(items))
        self._staleness_at_take = 1
        self._drain(items)

    def close(self, timeout: float | None = 60) -> None:
        """Flush, then stop the worker thread. Idempotent; a later submit
        in async mode lazily restarts the worker.

        Raises on a wedged drainer instead of orphaning it: a
        :class:`TimeoutError` if the flush barrier cannot complete, a
        :class:`RuntimeError` if the worker thread does not exit within
        ``timeout`` — in both cases the worker reference is *kept* (the
        daemon is still live and may still drain into the store), so the
        caller knows the store is not quiesced and can retry."""
        self.flush(timeout=timeout)
        if self._worker is not None:
            with self._cv:
                self._stop = True
                self._cv.notify_all()
            self._worker.join(timeout=timeout)
            if self._worker.is_alive():
                raise RuntimeError(
                    f"shadow drainer did not stop within {timeout}s — "
                    f"the store is NOT quiesced (a live drainer may "
                    f"still apply commits); retry close() once it "
                    f"unwedges")
            self._worker = None
            self._stop = False

    # -- drain-cost reporting (runner-side hooks) -----------------------
    def note_probe_calls(self, n: int) -> None:
        """Called by the drain runner with the FM calls one epoch spent
        (weak probes + strong guide generations) — feeds the
        ``drain_probe_calls`` histogram the cost model estimates from."""
        self._probe_calls_last += n

    # -- internals ------------------------------------------------------
    @staticmethod
    def _max_now(items) -> int:
        """Newest logical time in a batch (tolerates bare test stubs
        without a ``now``)."""
        return max((getattr(it, "now", 0) or 0 for it in items),
                   default=0)

    def _take(self) -> list[ShadowItem]:
        with self._cv:
            items, self._items = self._items, []
            self._staleness_at_take = self._batches
            self._batches = 0
            self._sync_gauges_locked()
            return items

    def _requeue(self, items: list[ShadowItem]) -> None:
        """A drain epoch failed: put its items back AT THE HEAD (they
        precede anything enqueued since the take, and they are already in
        seq order), restore a pending-batch count so cadence-based drains
        still trigger, and let the exception propagate — the next barrier
        retries."""
        with self._cv:
            self._items = list(items) + self._items
            self._batches += 1
            self.items_requeued += len(items)
            self._m_requeued.inc(len(items))
            self.drain_failures += 1
            self._m_failures.inc()
            self._sync_gauges_locked()

    def _drain(self, items: list[ShadowItem]) -> None:
        stale_batches = max(1, self._staleness_at_take)
        self._probe_calls_last = 0
        t0 = time.perf_counter()
        try:
            if self.fault_plan is not None:
                # injected drainer fault: propagates like a real drain
                # exception (inline → caller; async → surfaced at
                # barrier) — and, like one, re-queues the epoch's items
                self.fault_plan.fire("drain")
            if self.drain_delay:
                time.sleep(self.drain_delay)
            self.runner(items)
        except BaseException:
            self._requeue(items)
            raise
        dt = time.perf_counter() - t0
        self.items_drained += len(items)
        self.drains += 1
        self.last_drain_now = max(self.last_drain_now,
                                  self._max_now(items))
        with self.metrics.lock:
            self._m_drained.inc(len(items))
            self._m_drains.inc()
            self._m_h_items.observe(len(items))
            self._m_h_secs.observe(dt)
            self._m_h_probes.observe(self._probe_calls_last)
            self._m_h_stale.observe(stale_batches)
            self._m_stale_t.set(self.staleness_logical)
        if self.drain_policy is not None:
            self.drain_policy.note_drain(len(items), dt)

    def _reraise(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("shadow drainer failed") from err

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._loop,
                                            name="shadow-drainer",
                                            daemon=True)
            self._worker.start()

    def _due_locked(self) -> bool:
        if not self._items:
            return False
        if self._error is not None:
            # a failed epoch's error has not been consumed by a barrier
            # yet: hold its re-queued items — re-draining now would
            # retry in a hot loop behind the barrier's back (and tear
            # the one-failure-one-requeue accounting)
            return False
        if self._retry_holdback and not self._flush_requested:
            # error consumed, but no fresh traffic/barrier since the
            # failure: wait instead of spinning on a persistent fault
            return False
        return self._flush_requested or (
            self.flush_every > 0 and self._batches >= self.flush_every)

    def _loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._stop or self._due_locked())
                if self._stop and not self._items:
                    return
                items, self._items = self._items, []
                self._staleness_at_take = self._batches
                self._batches = 0
                self._draining = True
                self._sync_gauges_locked()
            try:
                if items:
                    self._drain(items)
            except BaseException as e:   # surfaced at the next barrier;
                self._error = e          # _drain already re-queued items
                with self._cv:
                    self._retry_holdback = True
            finally:
                with self._cv:
                    self._draining = False
                    self._cv.notify_all()
