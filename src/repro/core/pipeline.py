"""Microbatched RAR controller — the batched data plane over the §III
procedure, with the shadow plane decoupled onto a queue.

:class:`MicrobatchRAR` serves B requests per step with the *same* routing
semantics as the sequential :class:`repro.core.rar.RAR` — both execute
the pure decision core (:mod:`repro.core.decisions`) for every
classification — restructured so every layer touches the device once per
microbatch instead of once per request, and so that *learning* (shadow
inference + memory commits) is scheduled separately from *serving*:

**Serve plane** (:meth:`MicrobatchRAR.process_batch` — the user-facing
critical path):

1. **Embed** the whole microbatch (or accept precomputed embeddings).
2. **Query memory once** — the multi-query top-k kernel
   (:func:`repro.core.memory.query_topk_batch`, k =
   ``cfg.retrieval_k``) streams the store through VMEM a single time for
   all B queries; entry 0 per request is the top-1 routing decision and
   the tail entries feed multi-guide splicing (``cfg.max_guides``).
3. **Partition** requests into {memory_hard, memory_guide, memory_skill,
   router_weak, shadow} — :func:`repro.core.decisions.partition` over the
   batched similarities and the static router.
4. **Serve each group with one sweep per FM tier**: strong answers for
   memory_hard + shadow come from one ``answer_batch``; all weak *serve*
   work (guided hits, bare hits, router passthroughs) is one weak sweep
   through the length-bucketed serving path.
5. **Enqueue shadow work**: each shadow request becomes a
   :class:`repro.core.shadow.ShadowItem` on the controller's
   :class:`~repro.core.shadow.ShadowQueue` and ``process_batch`` returns
   — with ``cfg.shadow_mode="async"`` the serve step pays for the serve
   sweeps alone.

**Shadow plane** (:meth:`MicrobatchRAR._drain_shadow`, invoked by the
queue per its drain mode — inline every batch, deferred at barriers, or
on a background thread): optionally coalesces near-duplicate items into
groups (``cfg.shadow_dedup_sim`` — one shadow pass resolves a whole
group, reclaiming duplicate-skill probe calls), then runs the three
batched sweeps over the group leaders (weak-alone probe, guide-from-
memory probe, fresh-guide generation + probe). What each sweep's
alignment *means* comes from
:func:`repro.core.decisions.resolve_shadow_case`. All memory writes are
staged in the epoch-versioned :class:`repro.core.memory.CommitBuffer`
and land atomically through the controller's
:class:`~repro.core.memory.CommitStream` at the end of the drain, so a
serve-plane query never observes a partially-applied shadow batch —
and every replica subscribed to the stream (the serving fabric's views)
receives the applied store in the same atomic step.

Commit semantics (documented contract): within a microbatch all memory
reads observe the store snapshot at step start; shadow writes commit at
drain-epoch end. With ``shadow_mode="inline"`` (the default) every batch
drains before ``process_batch`` returns and at B = 1 this reduces
*exactly* to ``RAR.process`` — identical Outcome stream, memory state and
FM-call counts (asserted by ``tests/test_pipeline.py``).
``shadow_mode="deferred"`` with ``shadow_flush_every=1`` runs the
identical schedule through the queue machinery and is byte-identical to
inline (asserted by ``tests/test_shadow.py`` — the machine-checkable
anchor async correctness hangs on). Deferring drains further (flush
cadence > 1, or async) widens the staleness window: a request cannot hit
an entry whose shadow pass has not drained yet, and duplicate skills
enqueued before a drain each run their own shadow pass unless
``shadow_dedup_sim`` coalesces them. This is the standard
staleness/throughput trade of batched vector-DB serving; shadow requests
return provisional ``case="shadow_pending"`` Outcomes that the drainer
resolves in place (final after any ``flush_shadow`` barrier).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import decisions
from repro.core import memory as mem
from repro.core import shadow as shq
from repro.core.fm import TierUnavailableError
from repro.core.rar import RAR, Outcome, select_guides, splice_guides


def _answers(tier, prompts: list[np.ndarray]) -> np.ndarray:
    """One logical answer sweep over possibly mixed-length prompts. The
    length-bucketed path is preferred even for uniform groups: partition
    sizes vary per microbatch, and bucketing keeps the engine's jit cache
    at O(#lengths · log B) entries instead of one per observed size.
    Tiers without it (test doubles) take the prompt list directly."""
    many = getattr(tier, "answer_many", None)
    if many is not None:
        return np.asarray(many(prompts))
    return np.asarray(tier.answer_batch(prompts))


def _guides(tier, greqs: list[np.ndarray], guide_len: int) -> np.ndarray:
    """One guide-generation sweep over possibly mixed-length requests."""
    many = getattr(tier, "generate_guides_many", None)
    if many is not None:
        return np.asarray(many(greqs, guide_len))
    return np.asarray(tier.generate_guides(greqs, guide_len))


class MicrobatchRAR(RAR):
    """Batched controller. Inherits the sequential ``process`` (so a
    microbatch of 1 can also be served request-at-a-time if desired) and
    adds :meth:`process_batch` plus the queue-scheduled shadow plane."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.metrics_registry = self._metrics_registry()
        self.shadow = self._make_shadow_queue()

    def _shadow_runner(self):
        """The queue's drain callable. The fabric's replicas override
        this so a single learn replica owns every drain."""
        return self._drain_shadow

    def _metrics_registry(self):
        """The registry the shadow queue mirrors its stats into. A
        standalone controller owns a private one; the fabric's replicas
        override this to share the fabric-wide registry (with
        per-replica name prefixes from :meth:`_metrics_prefix`)."""
        from repro.serving.metrics import MetricsRegistry
        return MetricsRegistry()

    def _metrics_prefix(self) -> str:
        return "shadow/"

    def _drain_policy(self):
        """Drain policy for ``shadow_mode="adaptive"`` — None lets the
        queue build a private :class:`~repro.core.shadow.
        AdaptiveDrainPolicy`; the fabric overrides this so every
        replica's queue shares ONE policy (the global cadence)."""
        return None

    def _make_shadow_queue(self) -> shq.ShadowQueue:
        """Build the controller's shadow queue, staged into (and locked
        against) the commit stream."""
        return shq.ShadowQueue(runner=self._shadow_runner(),
                               mode=self.cfg.shadow_mode,
                               flush_every=self.cfg.shadow_flush_every,
                               buffer=self.commit_stream.buffer,
                               store_lock=self.commit_stream.lock,
                               fault_plan=self.fault_plan,
                               metrics=self.metrics_registry,
                               metrics_prefix=self._metrics_prefix(),
                               drain_policy=self._drain_policy())

    def metrics(self) -> dict:
        """Host-side metrics snapshot — registry counters/gauges/
        histograms plus commit-stream progress and (in adaptive mode)
        the drain policy's fitted cost model. Zero device syncs: every
        number is a host-side counter."""
        out = {"registry": self.metrics_registry.snapshot(),
               "commit": {"epoch": self.commit_stream.buffer.epoch,
                          "entries_applied":
                              self.commit_stream.buffer.entries_applied,
                          "commits": self.commit_stream.commits}}
        if self.shadow.drain_policy is not None:
            out["drain_policy"] = self.shadow.drain_policy.stats()
        return out

    # ------------------------------------------------------------------
    def flush_shadow(self, timeout: float | None = None) -> None:
        """Barrier: drain all pending shadow items and apply their
        commits; every outstanding Outcome is resolved on return (except
        probes deferred behind a still-open breaker, which stay
        parked)."""
        self.replay_deferred()
        self.shadow.flush(timeout=timeout)

    def close_shadow(self) -> None:
        self.replay_deferred()
        self.shadow.close()

    def replay_deferred(self, force: bool = False) -> int:
        """Batched replay of probes deferred during a strong-tier
        outage: one strong sweep recovers the answers the probes were
        waiting on, then a synchronous drain epoch resolves them through
        the normal shadow plane (their Outcomes' ``case``/
        ``strong_calls`` update in place; ``response``/``served_by``
        stay weak). Skips while the breaker is open unless ``force``."""
        if not self.deferred_probes or \
                not (force or self._strong_ok()):
            return 0
        items, self.deferred_probes = self.deferred_probes, []
        try:
            strong_ans = _answers(self.strong,
                                  [it.prompt for it in items])
        except TierUnavailableError:
            self.deferred_probes = items + self.deferred_probes
            return 0
        for it, a in zip(items, strong_ans):
            it.strong_ans = int(a)
            it.strong_calls = 1
        # counter first: the drain epoch journals the recovery manifest,
        # which must already show these probes as replayed (the epoch's
        # WAL write is the atomic point — before it, the manifest still
        # parks them; after it, the replay is durable)
        self.probes_replayed += len(items)
        self.shadow.drain_now(items)
        return len(items)

    # ------------------------------------------------------------------
    def _lookup_batch(self, embs, guides_only: bool = False
                      ) -> mem.TopKResult:
        """One batched memory read: top-``retrieval_k`` entries per
        query, fused epilogue, one host transfer (the batched analog of
        ``RAR._lookup``)."""
        return mem.query_topk_batch(self.memory, jnp.asarray(embs),
                                    self.cfg.retrieval_k,
                                    guides_only=guides_only).device_get()

    def _snapshot_lookup(self, embs, guides_only: bool = False
                         ) -> mem.TopKResult:
        """A read under the commit stream's store lock: the drainer's
        commit apply and this snapshot serialize, so the result always
        reflects a whole number of drain epochs (no torn multi-field
        reads on the mutable sharded store)."""
        with self.shadow.store_lock:
            return self._lookup_batch(embs, guides_only=guides_only)

    # ------------------------------------------------------------------
    # Serve plane
    # ------------------------------------------------------------------
    def process_batch(self, prompts: list[np.ndarray],
                      guide_requests: list[np.ndarray],
                      keys: list | None = None,
                      embs: np.ndarray | None = None,
                      nows: list[int] | None = None) -> list[Outcome]:
        """Serve one microbatch. ``prompts[i]``/``guide_requests[i]``/
        ``keys[i]`` mirror the arguments of ``RAR.process``; ``embs`` may
        carry precomputed request embeddings (B, E). ``nows`` may carry
        pre-allocated logical time stamps (the process fabric allocates
        them from the parent's shared clock at dispatch, so a redispatch
        after a worker death reuses the *same* stamps — the byte-identity
        anchor)."""
        B = len(prompts)
        if B > self.cfg.memory.capacity:
            # every request may record one entry; reject before any FM
            # call rather than letting the commit scatter fail afterwards
            raise ValueError(
                f"microbatch of {B} exceeds memory capacity "
                f"{self.cfg.memory.capacity}")
        if keys is None:
            keys = [None] * B
        if nows is None:
            nows = self._advance_now(B)
        else:
            nows = list(nows)
            self.now = max(self.now, max(nows))   # keep the mirror sane

        if embs is None:
            embs = np.stack([np.asarray(self.embed_fn(p)) for p in prompts])
        else:
            embs = np.asarray(embs)

        # ---- phase 1: one batched top-k memory read (snapshot at batch
        # start). One dispatch (kernel + fused metadata epilogue) and one
        # host transfer of the packed struct — not a per-field gather
        # each. Entry [i, 0] is request i's top-1 routing decision; the
        # tail entries feed multi-guide splicing. The host-side ring
        # pointer is captured under the same lock: re-probe flag updates
        # staged later carry it so the commit buffer can drop them if an
        # intervening drain epoch evicts the target slot.
        with self.shadow.store_lock:
            q = self._lookup_batch(embs)
            ptr_snap = self._ptr_base + self.commit_stream.commits

        # ---- phase 2: partition (the decision core's classification —
        # the same code path the sequential controller runs per request).
        # The strong tier's breaker feeds in as a routing input: while it
        # is open, hard/shadow requests land in the degraded groups.
        part = decisions.partition(
            q, nows, self.cfg,
            lambda i: self.route_weak_fn(np.asarray(embs[i]), keys[i]),
            strong_ok=self._strong_ok())
        outcomes: list[Outcome | None] = [None] * B

        # ---- phase 3: one strong sweep (memory_hard + shadow requests).
        # The shadow requests' strong answer is user-facing (§III-D: the
        # strong FM serves while learning happens in the background), so
        # it stays on the serve plane. If the sweep itself hits an outage
        # (the routing peek raced the breaker), the whole strong side of
        # the batch degrades mid-flight — no errored requests.
        items: list[shq.ShadowItem] = []
        strong_reqs = part.hard + [i for i, _ in part.shadow]
        if strong_reqs:
            try:
                strong_ans = _answers(self.strong, [prompts[i]
                                                    for i in strong_reqs])
            except TierUnavailableError:
                part.hard_degraded += part.hard
                part.deferred += part.shadow
                part.hard, part.shadow = [], []
            else:
                for i, a in zip(part.hard, strong_ans):
                    outcomes[i] = Outcome(int(a), "strong", 1,
                                          "memory_hard")
                for (i, reprobe), a in zip(part.shadow,
                                           strong_ans[len(part.hard):]):
                    out = Outcome(int(a), "strong", 1, shq.PENDING)
                    outcomes[i] = out
                    items.append(shq.ShadowItem(
                        seq=self.shadow.next_seq(), now=nows[i],
                        prompt=prompts[i], guide_request=guide_requests[i],
                        emb=np.asarray(embs[i]), strong_ans=int(a),
                        outcome=out, reprobe_index=reprobe,
                        ptr_snapshot=ptr_snap))

        # ---- phase 4: one weak *serve* sweep (guided hits, bare hits,
        # router passthroughs). Shadow weak probes are not serve work and
        # run in the drain instead.
        weak_prompts: list[np.ndarray] = []
        weak_tags: list[tuple[str, int]] = []
        for i in part.guide:
            weak_prompts.append(splice_guides(
                prompts[i], select_guides(q.sim[i], q.has_guide[i],
                                          q.guide[i],
                                          self.cfg.sim_threshold,
                                          self.cfg.max_guides)))
            weak_tags.append(("guide", i))
        for i in part.skill:
            weak_prompts.append(prompts[i])
            weak_tags.append(("skill", i))
        for i in part.router:
            weak_prompts.append(prompts[i])
            weak_tags.append(("router", i))
        # degraded groups ride the same weak sweep (appended after the
        # regular groups, so non-degraded batches are byte-identical to
        # the pre-resilience sweep order)
        for i in part.hard_degraded:
            weak_prompts.append(prompts[i])
            weak_tags.append(("hard_degraded", i))
        deferred_reprobe = dict(part.deferred)
        for i, _ in part.deferred:
            weak_prompts.append(prompts[i])
            weak_tags.append(("deferred", i))
        if weak_prompts:
            weak_ans = _answers(self.weak, weak_prompts)
            for (tag, i), a in zip(weak_tags, weak_ans):
                a = int(a)
                if tag == "guide":
                    outcomes[i] = Outcome(a, "weak", 0, "memory_guide",
                                          guide_source="memory")
                elif tag == "skill":
                    outcomes[i] = Outcome(a, "weak", 0, "memory_skill")
                elif tag == "hard_degraded":
                    outcomes[i] = Outcome(a, "weak", 0,
                                          "memory_hard_degraded")
                elif tag == "deferred":
                    # weak serves now; the suppressed strong probe parks
                    # until the breaker closes (replay_deferred)
                    out = Outcome(a, "weak", 0, "shadow_deferred")
                    outcomes[i] = out
                    self.deferred_probes.append(shq.ShadowItem(
                        seq=self.shadow.next_seq(), now=nows[i],
                        prompt=prompts[i],
                        guide_request=guide_requests[i],
                        emb=np.asarray(embs[i]), strong_ans=-1,
                        outcome=out,
                        reprobe_index=deferred_reprobe[i],
                        ptr_snapshot=ptr_snap, strong_calls=0))
                    self.probes_deferred += 1
                else:
                    outcomes[i] = Outcome(a, "weak", 0, "router_weak")

        # ---- phase 5: hand the shadow work to the queue. Inline mode
        # drains here; deferred/async return after the serve sweeps alone.
        self.shadow.submit(items)
        return outcomes

    # ------------------------------------------------------------------
    # Shadow plane (runs wherever the queue schedules it)
    # ------------------------------------------------------------------
    def _drain_shadow(self, items: list[shq.ShadowItem]) -> None:
        """Run the three batched shadow sweeps over one coalesced drain
        epoch and apply all resulting memory writes atomically.

        Failure atomicity: if any sweep raises (a transient
        ``TierError``, an injected fault), everything this epoch touched
        is rolled back — the commit buffer's partially-staged ops, every
        item's Outcome fields, and the RQ2/coalescing counters — before
        the exception propagates. The queue re-queues the items
        (``ShadowQueue._requeue``), so the retry at the next barrier
        replays against a clean slate and is byte-identical to a first
        run: the lost-failed-epoch bugfix needs both halves."""
        buf = self.shadow.buffer
        mark = buf.mark()
        saved = [(it.strong_calls, it.outcome.case,
                  it.outcome.strong_calls, it.outcome.guide_source)
                 for it in items]
        counters = (self.guides_from_memory, self.guides_generated,
                    self.shadow.items_coalesced,
                    self.shadow.reclaimed_weak_calls,
                    self.shadow.reclaimed_strong_calls)
        try:
            self._drain_shadow_epoch(items)
        except BaseException:
            buf.rollback(mark)
            for it, (sc, case, osc, gs) in zip(items, saved):
                it.strong_calls = sc
                it.outcome.strong_calls = osc
                it.outcome.case = case
                it.outcome.guide_source = gs
            (self.guides_from_memory, self.guides_generated,
             self.shadow.items_coalesced,
             self.shadow.reclaimed_weak_calls,
             self.shadow.reclaimed_strong_calls) = counters
            raise

    def _drain_shadow_epoch(self, items: list[shq.ShadowItem]) -> None:
        buf = self.shadow.buffer
        probe_calls = 0               # FM calls this epoch (drain cost)
        empty_guide = np.zeros((self.cfg.memory.guide_len,), np.int32)

        # ---- coalescing: near-duplicate items share one shadow pass.
        # The group leader runs the probe sweeps; followers adopt its
        # resolution (their own re-probe flags still move) and skip their
        # probe calls — the reclaimed work the queue stats record. Off by
        # default (dedup_sim=None → every item is its own group, byte-
        # identical to the pre-dedup drain).
        dedup = self.cfg.shadow_dedup_sim
        if dedup is not None and len(items) > 1:
            groups = decisions.coalesce_shadow_items(
                np.stack([it.emb for it in items]), dedup)
        else:
            groups = [[j] for j in range(len(items))]
        flw = {items[g[0]].seq: [items[j] for j in g[1:]] for g in groups}
        leaders = [items[g[0]] for g in groups]
        self.shadow.items_coalesced += len(items) - len(leaders)

        probed_2a: set[int] = set()    # leader seqs that ran the 2a probe
        fresh_ran: set[int] = set()    # leader seqs that ran the 2b sweep

        def settle(it: shq.ShadowItem, stage: str, guide) -> None:
            """Apply ``stage``'s resolution (decision core) to a leader
            and its coalesced followers: the leader stages the insert and
            bumps the RQ2 counters; every member resolves its Outcome and
            moves its own re-probe flags; followers' skipped probe calls
            are tallied at the leader's actual probe depth."""
            depth = 1 + (it.seq in probed_2a) + (it.seq in fresh_ran)
            for m in [it] + flw.get(it.seq, []):
                res = decisions.resolve_shadow_case(
                    stage, m.reprobe_index is not None)
                if m is it:
                    if res.record:
                        buf.stage_add(m.emb, guide, res.has_guide,
                                      res.hard, m.now)
                    if res.guide_source == "memory":
                        self.guides_from_memory += 1
                    elif res.guide_source == "fresh":
                        self.guides_generated += 1
                else:
                    self.shadow.reclaimed_weak_calls += depth
                    if it.seq in fresh_ran:
                        self.shadow.reclaimed_strong_calls += 1
                if res.clear_hard:
                    buf.stage_soft_clear(m.reprobe_index, m.now,
                                         m.ptr_snapshot)
                if res.touch:
                    buf.stage_touch(m.reprobe_index, m.now, m.ptr_snapshot)
                m.outcome.strong_calls = m.strong_calls
                m.outcome.case = res.case
                m.outcome.guide_source = res.guide_source

        # ---- sweep 1: weak-alone probes (Case 1)
        weak_ans = _answers(self.weak, [it.prompt for it in leaders])
        probe_calls += len(leaders)
        pending: list[shq.ShadowItem] = []
        for it, a in zip(leaders, weak_ans):
            if self.aligned_fn(int(a), it.strong_ans):
                settle(it, "case1", empty_guide)
            else:
                pending.append(it)

        # ---- sweep 2: guide-from-memory probes (Case 2a), against the
        # store snapshot at drain start
        still: list[shq.ShadowItem] = []
        if pending:
            gq = self._snapshot_lookup(
                np.stack([it.emb for it in pending]), guides_only=True)
            probes, probe_items, probe_guides = [], [], []
            for j, it in enumerate(pending):
                if decisions.wants_guide_probe(float(gq.sim[j, 0]),
                                               self.cfg):
                    guides = select_guides(gq.sim[j], gq.has_guide[j],
                                           gq.guide[j],
                                           self.cfg.guide_sim_threshold,
                                           self.cfg.max_guides)
                    probes.append(splice_guides(it.prompt, guides))
                    probe_items.append(it)
                    probed_2a.add(it.seq)
                    # on success the *top* guide is recorded (one guide
                    # block per stored entry), matching the sequential
                    # controller
                    probe_guides.append(guides[0])
                else:
                    still.append(it)
            if probes:
                probe_ans = _answers(self.weak, probes)
                probe_calls += len(probes)
                for it, g, a in zip(probe_items, probe_guides, probe_ans):
                    if self.aligned_fn(int(a), it.strong_ans):
                        settle(it, "case2a", g)
                    else:
                        still.append(it)
            still.sort(key=lambda it: it.seq)

        # ---- sweep 3: fresh guides (one strong generate_guides sweep)
        # + guided weak probes (Case 2b)
        failed: list[shq.ShadowItem] = []
        if still and self.cfg.allow_fresh_guides:
            try:
                fresh = _guides(self.strong,
                                [it.guide_request for it in still],
                                self.cfg.memory.guide_len)
            except TierUnavailableError:
                # strong tier down mid-drain: no fresh guide available —
                # the items resolve as Case 3, exactly like the
                # sequential probe's degraded case-2b leg (no strong
                # call charged)
                failed = still
            else:
                for it in still:
                    it.strong_calls += 1
                    fresh_ran.add(it.seq)
                probe_calls += len(still)      # strong guide generations
                probe_ans = _answers(self.weak,
                                     [splice_guides(it.prompt, [g])
                                      for it, g in zip(still, fresh)])
                probe_calls += len(still)      # guided weak probes
                for it, g, a in zip(still, fresh, probe_ans):
                    if self.aligned_fn(int(a), it.strong_ans):
                        settle(it, "case2b", g)
                    else:
                        failed.append(it)
        else:
            failed = still

        for it in failed:                              # Case 3
            settle(it, "case3", empty_guide)

        # ---- one epoch apply through the commit stream: adds first
        # (FIFO order by logical time, matching the sequential
        # add-then-flag order), then re-probe flag updates; flag updates
        # whose pre-epoch slot this epoch's scatter just evicted are
        # dropped (CommitBuffer contract). The apply, the commit-counter
        # bump and the broadcast to every subscribed replica view happen
        # atomically under the stream's store lock.
        self.shadow.note_probe_calls(probe_calls)
        self.memory = self.commit_stream.apply(self.memory)
