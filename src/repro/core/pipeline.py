"""Microbatched RAR controller — the batched data plane over the §III
procedure.

:class:`MicrobatchRAR` serves B requests per step with the *same* routing
semantics as the sequential :class:`repro.core.rar.RAR`, restructured so
every layer touches the device once per microbatch instead of once per
request:

1. **Embed** the whole microbatch (or accept precomputed embeddings).
2. **Query memory once** — the multi-query top-k kernel
   (:func:`repro.core.memory.query_topk_batch`, k =
   ``cfg.retrieval_k``) streams the store through VMEM a single time for
   all B queries; entry 0 per request is the top-1 routing decision and
   the tail entries feed multi-guide splicing (``cfg.max_guides``).
3. **Partition** requests into {memory_hard, memory_guide, memory_skill,
   router_weak, shadow} by the batched similarities and the static router.
4. **Serve each group with one sweep per FM tier**: strong answers for
   memory_hard + shadow come from one ``answer_batch``; all weak work
   (guided hits, bare hits, router passthroughs, shadow weak-probes) is one
   weak sweep through the length-bucketed serving path.
5. **Shadow inference as three batched sweeps**: weak-alone probe,
   guide-from-memory probe, fresh-guide probe (one ``generate_guides``
   call for every request that needs one).
6. **Commit once**: all memory inserts of the microbatch land in a single
   :func:`repro.core.memory.add_batch` scatter, followed by the
   re-probe ``mark_soft``/``touch`` updates.

Microbatch-commit semantics (documented contract): within a microbatch all
memory reads observe the store snapshot at step start and all writes commit
at step end. At B = 1 this reduces *exactly* to ``RAR.process`` — identical
Outcome stream, memory state and FM-call counts (asserted by
``tests/test_pipeline.py``). At B > 1 a request cannot hit an entry written
earlier in the same microbatch; duplicate skills inside one microbatch each
run their own shadow pass and insert their own entry (first hit lands one
microbatch later). This is the standard staleness/throughput trade of
batched vector-DB serving and the basis for every future scaling PR
(sharded memory, async shadow queues, multi-host serving).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import memory as mem
from repro.core.rar import RAR, Outcome, select_guides, splice_guides


def _answers(tier, prompts: list[np.ndarray]) -> np.ndarray:
    """One logical answer sweep over possibly mixed-length prompts. The
    length-bucketed path is preferred even for uniform groups: partition
    sizes vary per microbatch, and bucketing keeps the engine's jit cache
    at O(#lengths · log B) entries instead of one per observed size.
    Tiers without it (test doubles) take the prompt list directly."""
    many = getattr(tier, "answer_many", None)
    if many is not None:
        return np.asarray(many(prompts))
    return np.asarray(tier.answer_batch(prompts))


def _guides(tier, greqs: list[np.ndarray], guide_len: int) -> np.ndarray:
    """One guide-generation sweep over possibly mixed-length requests."""
    many = getattr(tier, "generate_guides_many", None)
    if many is not None:
        return np.asarray(many(greqs, guide_len))
    return np.asarray(tier.generate_guides(greqs, guide_len))


@dataclasses.dataclass
class _Shadow:
    """Per-request shadow-inference bookkeeping inside one microbatch."""
    req: int                      # index into the microbatch
    now: int                      # this request's logical time
    reprobe_index: int | None     # hard entry being re-probed, if any
    strong_ans: int = -1
    strong_calls: int = 1
    outcome: Outcome | None = None


class MicrobatchRAR(RAR):
    """Batched controller. Inherits the sequential ``process`` (so a
    microbatch of 1 can also be served request-at-a-time if desired) and
    adds :meth:`process_batch`."""

    # ------------------------------------------------------------------
    def _lookup_batch(self, embs, guides_only: bool = False
                      ) -> mem.TopKResult:
        """One batched memory read: top-``retrieval_k`` entries per
        query, fused epilogue, one host transfer (the batched analog of
        ``RAR._lookup``)."""
        return mem.query_topk_batch(self.memory, jnp.asarray(embs),
                                    self.cfg.retrieval_k,
                                    guides_only=guides_only).device_get()

    # ------------------------------------------------------------------
    def process_batch(self, prompts: list[np.ndarray],
                      guide_requests: list[np.ndarray],
                      keys: list | None = None,
                      embs: np.ndarray | None = None) -> list[Outcome]:
        """Serve one microbatch. ``prompts[i]``/``guide_requests[i]``/
        ``keys[i]`` mirror the arguments of ``RAR.process``; ``embs`` may
        carry precomputed request embeddings (B, E)."""
        B = len(prompts)
        if B > self.cfg.memory.capacity:
            # every request may record one entry; reject before any FM
            # call rather than letting the commit scatter fail afterwards
            raise ValueError(
                f"microbatch of {B} exceeds memory capacity "
                f"{self.cfg.memory.capacity}")
        if keys is None:
            keys = [None] * B
        nows = [self.now + i + 1 for i in range(B)]
        self.now += B

        if embs is None:
            embs = np.stack([np.asarray(self.embed_fn(p)) for p in prompts])
        else:
            embs = np.asarray(embs)

        # ---- phase 1: one batched top-k memory read (snapshot at batch
        # start). One dispatch (kernel + fused metadata epilogue) and one
        # host transfer of the packed struct — not a per-field gather
        # each. Entry [i, 0] is request i's top-1 routing decision; the
        # tail entries feed multi-guide splicing.
        q = self._lookup_batch(embs)
        sims = q.sim[:, 0]
        hards = q.hard[:, 0]
        has_guides = q.has_guide[:, 0]
        added_ats = q.added_at[:, 0]
        hit_idxs = q.index[:, 0]

        # ---- phase 2: partition
        outcomes: list[Outcome | None] = [None] * B
        g_hard: list[int] = []        # memory_hard → strong serves
        g_guide: list[int] = []       # memory_guide → weak + stored guide
        g_skill: list[int] = []       # memory_skill → weak unaided
        g_router: list[int] = []      # router_weak  → weak unaided
        shadows: list[_Shadow] = []   # strong serves + background probes
        for i in range(B):
            if sims[i] >= self.cfg.sim_threshold:
                if bool(hards[i]):
                    age = nows[i] - int(added_ats[i])
                    if age < self.cfg.reprobe_period:
                        g_hard.append(i)
                    else:
                        shadows.append(_Shadow(i, nows[i], int(hit_idxs[i])))
                elif bool(has_guides[i]):
                    g_guide.append(i)
                else:
                    g_skill.append(i)
            elif self.route_weak_fn(np.asarray(embs[i]), keys[i]):
                g_router.append(i)
            else:
                shadows.append(_Shadow(i, nows[i], None))

        # ---- phase 3: one strong sweep (memory_hard + shadow requests)
        strong_reqs = g_hard + [s.req for s in shadows]
        if strong_reqs:
            strong_ans = _answers(self.strong, [prompts[i]
                                                for i in strong_reqs])
            for i, a in zip(g_hard, strong_ans):
                outcomes[i] = Outcome(int(a), "strong", 1, "memory_hard")
            for s, a in zip(shadows, strong_ans[len(g_hard):]):
                s.strong_ans = int(a)

        # ---- phase 4: one weak sweep (guided hits, bare hits, router
        # passthroughs, shadow weak-alone probes)
        weak_prompts: list[np.ndarray] = []
        weak_tags: list[tuple[str, object]] = []
        for i in g_guide:
            weak_prompts.append(splice_guides(
                prompts[i], select_guides(q.sim[i], q.has_guide[i],
                                          q.guide[i],
                                          self.cfg.sim_threshold,
                                          self.cfg.max_guides)))
            weak_tags.append(("guide", i))
        for i in g_skill:
            weak_prompts.append(prompts[i])
            weak_tags.append(("skill", i))
        for i in g_router:
            weak_prompts.append(prompts[i])
            weak_tags.append(("router", i))
        for s in shadows:
            weak_prompts.append(prompts[s.req])
            weak_tags.append(("shadow", s))

        records: list[tuple[int, np.ndarray, np.ndarray, bool, bool, int]]
        records = []          # (req, emb, guide, has_guide, hard, now)
        soft_clears: list[tuple[int, int]] = []    # (req, memory index)
        touches: list[tuple[int, int, int]] = []   # (req, index, now)
        empty_guide = np.zeros((self.cfg.memory.guide_len,), np.int32)

        def record(s: _Shadow, guide, has_guide, hard):
            records.append((s.req, embs[s.req], guide, has_guide, hard,
                            s.now))
            if s.reprobe_index is not None and not hard:
                soft_clears.append((s.req, s.reprobe_index))

        pending: list[_Shadow] = []
        if weak_prompts:
            weak_ans = _answers(self.weak, weak_prompts)
            for (tag, ref), a in zip(weak_tags, weak_ans):
                a = int(a)
                if tag == "guide":
                    outcomes[ref] = Outcome(a, "weak", 0, "memory_guide",
                                            guide_source="memory")
                elif tag == "skill":
                    outcomes[ref] = Outcome(a, "weak", 0, "memory_skill")
                elif tag == "router":
                    outcomes[ref] = Outcome(a, "weak", 0, "router_weak")
                else:                                  # shadow Case 1 probe
                    s: _Shadow = ref
                    if self.aligned_fn(a, s.strong_ans):
                        record(s, empty_guide, False, False)
                        s.outcome = Outcome(
                            s.strong_ans, "strong", s.strong_calls,
                            "case1_reprobe" if s.reprobe_index is not None
                            else "case1")
                    else:
                        pending.append(s)

        # ---- phase 5: shadow sweep 2 — guide-from-memory probes (against
        # the same batch-start snapshot)
        still: list[_Shadow] = []
        if pending:
            gq = self._lookup_batch(embs[[s.req for s in pending]],
                                    guides_only=True)
            probes, probe_shadows, probe_guides = [], [], []
            for j, s in enumerate(pending):
                if gq.sim[j, 0] >= self.cfg.guide_sim_threshold:
                    guides = select_guides(gq.sim[j], gq.has_guide[j],
                                           gq.guide[j],
                                           self.cfg.guide_sim_threshold,
                                           self.cfg.max_guides)
                    probes.append(splice_guides(prompts[s.req], guides))
                    probe_shadows.append(s)
                    # on success the *top* guide is recorded (one guide
                    # block per stored entry), matching the sequential
                    # controller
                    probe_guides.append(guides[0])
                else:
                    still.append(s)
            if probes:
                probe_ans = _answers(self.weak, probes)
                for s, g, a in zip(probe_shadows, probe_guides, probe_ans):
                    if self.aligned_fn(int(a), s.strong_ans):
                        self.guides_from_memory += 1
                        record(s, g, True, False)
                        s.outcome = Outcome(s.strong_ans, "strong",
                                            s.strong_calls, "case2",
                                            guide_source="memory")
                    else:
                        still.append(s)
            still.sort(key=lambda s: s.req)

        # ---- phase 6: shadow sweep 3 — fresh guides (one strong
        # generate_guides sweep) + guided weak probes
        failed: list[_Shadow] = []
        if still and self.cfg.allow_fresh_guides:
            for s in still:
                s.strong_calls += 1
            fresh = _guides(self.strong,
                            [guide_requests[s.req] for s in still],
                            self.cfg.memory.guide_len)
            probe_ans = _answers(self.weak,
                                 [splice_guides(prompts[s.req], [g])
                                  for s, g in zip(still, fresh)])
            for s, g, a in zip(still, fresh, probe_ans):
                if self.aligned_fn(int(a), s.strong_ans):
                    self.guides_generated += 1
                    record(s, g, True, False)
                    s.outcome = Outcome(s.strong_ans, "strong",
                                        s.strong_calls, "case2",
                                        guide_source="fresh")
                else:
                    failed.append(s)
        else:
            failed = still

        for s in failed:                               # Case 3
            if s.reprobe_index is not None:
                touches.append((s.req, s.reprobe_index, s.now))
            else:
                record(s, empty_guide, False, True)
            s.outcome = Outcome(s.strong_ans, "strong", s.strong_calls,
                                "case3")
        for s in shadows:
            outcomes[s.req] = s.outcome

        # ---- phase 7: one commit — adds first (matching sequential
        # add-then-flag order), then re-probe flag updates, in request
        # order. Flag updates target *pre-batch* entries; if the FIFO
        # scatter just evicted one (full ring), the update would hit an
        # unrelated fresh entry — e.g. clear the hard flag another request
        # just recorded — so those are dropped.
        overwritten: set[int] = set()
        if records:
            records.sort(key=lambda r: r[0])
            C = self.memory.capacity
            base_ptr = int(self.memory.ptr)
            overwritten = {(base_ptr + j) % C for j in range(len(records))}
            self.memory = mem.add_batch(
                self.memory,
                jnp.asarray(np.stack([r[1] for r in records])),
                jnp.asarray(np.stack([np.asarray(r[2], np.int32)
                                      for r in records])),
                jnp.asarray(np.asarray([r[3] for r in records], bool)),
                jnp.asarray(np.asarray([r[4] for r in records], bool)),
                jnp.asarray(np.asarray([r[5] for r in records], np.int32)))
        soft_clears = [s for s in soft_clears if s[1] not in overwritten]
        if soft_clears:
            self.memory = mem.mark_soft(
                self.memory,
                jnp.asarray(sorted({idx for _, idx in soft_clears}),
                            jnp.int32))
        # dedupe duplicate slots last-request-wins (scatter order for
        # duplicate indices is implementation-defined) — matches the
        # sequential controller, where the later touch lands last
        by_idx = {idx: now for _, idx, now in sorted(touches)
                  if idx not in overwritten}
        if by_idx:
            self.memory = mem.touch(
                self.memory,
                jnp.asarray(sorted(by_idx), jnp.int32),
                jnp.asarray([by_idx[i] for i in sorted(by_idx)], jnp.int32))
        return outcomes
