"""FM tiers — a weak or strong foundation model behind a uniform serving
facade, with per-call cost accounting (the quantity RAR minimizes).

The tier wraps a trained model + the batched serving engine. Costs are
reported in FLOPs derived from the architecture config (6·N_active per
token), so heterogeneous tiers (an SSM edge model vs. a dense cloud model)
compare on one axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.data import tokenizer as tk
from repro.data.tokenizer import Vocab
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class FMTier:
    name: str
    cfg: ModelConfig
    engine: ServingEngine
    vocab: Vocab

    @classmethod
    def create(cls, name: str, cfg: ModelConfig, params: Any,
               vocab: Vocab) -> "FMTier":
        return cls(name=name, cfg=cfg, engine=ServingEngine(cfg, params),
                   vocab=vocab)

    # ------------------------------------------------------------------
    @property
    def calls(self) -> int:
        return self.engine.calls

    @property
    def flops_spent(self) -> float:
        return self.engine.flops_spent

    # ------------------------------------------------------------------
    def answer_batch(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, Lp) uniform-length question prompts ending in ANS.
        Returns (B,) answer indices in [0, 4) (-1 if the model emitted a
        non-option token)."""
        out = np.asarray(self.engine.generate(
            {"tokens": jnp.asarray(prompts)}, max_new=1))
        ans = out[:, 0] - tk.OPTION_A
        ans[(ans < 0) | (ans > 3)] = -1
        return ans

    def answer_many(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Mixed-length variant of :meth:`answer_batch`: prompts may have
        different lengths; they are served through the engine's
        length-bucketed path in one logical sweep."""
        out = self.engine.generate_bucketed(prompts, max_new=1)
        ans = out[:, 0] - tk.OPTION_A
        ans[(ans < 0) | (ans > 3)] = -1
        return ans

    def generate_guides(self, requests: np.ndarray,
                        guide_len: int) -> np.ndarray:
        """requests: (B, Lr) guide-request prompts. Returns (B, guide_len)
        guide token blocks: [GUIDE_START, hints..., GUIDE_END, PAD...]."""
        hints = np.asarray(self.engine.generate(
            {"tokens": jnp.asarray(requests)}, max_new=2))
        return self._pack_guides(hints, guide_len)

    def generate_guides_many(self, requests: list[np.ndarray],
                             guide_len: int) -> np.ndarray:
        """Mixed-length variant of :meth:`generate_guides`."""
        hints = self.engine.generate_bucketed(requests, max_new=2)
        return self._pack_guides(hints, guide_len)

    @staticmethod
    def _pack_guides(hints: np.ndarray, guide_len: int) -> np.ndarray:
        B = hints.shape[0]
        guides = np.full((B, guide_len), tk.PAD, np.int32)
        guides[:, 0] = tk.GUIDE_START
        guides[:, 1:3] = hints
        guides[:, 3] = tk.GUIDE_END
        return guides
