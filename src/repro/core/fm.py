"""FM tiers — a weak or strong foundation model behind a uniform serving
facade, with per-call cost accounting (the quantity RAR minimizes).

The tier wraps a trained model + the batched serving engine. Costs are
reported in FLOPs derived from the architecture config (6·N_active per
token), so heterogeneous tiers (an SSM edge model vs. a dense cloud model)
compare on one axis.

Tier-call resilience (the recovery plane's FM leg)
--------------------------------------------------
A production tier is a remote service that fails and browns out.
:class:`ResilientTier` wraps any tier object (an :class:`FMTier`, a test
fake — anything exposing the ``answer_*`` / ``generate_guides_*``
surface) with:

* **retry with exponential backoff + seeded jitter** around every call —
  only :class:`TransientTierError` s are retried; application exceptions
  propagate unchanged on the first raise;
* a **circuit breaker** (closed → open → half-open) that sheds calls
  during an outage instead of hammering a dead service. The controllers
  read ``breaker.available()`` as a *routing input*: while the strong
  tier's breaker is open they serve degraded (weak-only) and defer the
  suppressed shadow probes — see :func:`repro.core.decisions.classify`;
* a **cooperative timeout**: a synchronous in-process call cannot be
  preempted, so ``timeout`` is enforced against *injected* latency
  spikes (the fault plan raises :class:`TierTimeout` instead of sleeping
  when a spike exceeds the budget) — which is exactly what the
  deterministic fault suite needs, with no real waiting.

The wrapper delegates every other attribute (``engine``, ``calls``,
``vocab``, …) to the inner tier via ``__getattr__``, and only advertises
``answer_many``/``generate_guides_many`` if the inner tier has them — so
capability probes like ``getattr(tier, "answer_many", None)`` keep
working through the wrapper.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.data import tokenizer as tk
from repro.data.tokenizer import Vocab
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class FMTier:
    name: str
    cfg: ModelConfig
    engine: ServingEngine
    vocab: Vocab

    @classmethod
    def create(cls, name: str, cfg: ModelConfig, params: Any,
               vocab: Vocab) -> "FMTier":
        return cls(name=name, cfg=cfg, engine=ServingEngine(cfg, params),
                   vocab=vocab)

    # ------------------------------------------------------------------
    @property
    def calls(self) -> int:
        return self.engine.calls

    @property
    def flops_spent(self) -> float:
        return self.engine.flops_spent

    # ------------------------------------------------------------------
    def answer_batch(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, Lp) uniform-length question prompts ending in ANS.
        Returns (B,) answer indices in [0, 4) (-1 if the model emitted a
        non-option token)."""
        out = np.asarray(self.engine.generate(
            {"tokens": jnp.asarray(prompts)}, max_new=1))
        ans = out[:, 0] - tk.OPTION_A
        ans[(ans < 0) | (ans > 3)] = -1
        return ans

    def answer_many(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Mixed-length variant of :meth:`answer_batch`: prompts may have
        different lengths; they are served through the engine's
        length-bucketed path in one logical sweep."""
        out = self.engine.generate_bucketed(prompts, max_new=1)
        ans = out[:, 0] - tk.OPTION_A
        ans[(ans < 0) | (ans > 3)] = -1
        return ans

    def generate_guides(self, requests: np.ndarray,
                        guide_len: int) -> np.ndarray:
        """requests: (B, Lr) guide-request prompts. Returns (B, guide_len)
        guide token blocks: [GUIDE_START, hints..., GUIDE_END, PAD...]."""
        hints = np.asarray(self.engine.generate(
            {"tokens": jnp.asarray(requests)}, max_new=2))
        return self._pack_guides(hints, guide_len)

    def generate_guides_many(self, requests: list[np.ndarray],
                             guide_len: int) -> np.ndarray:
        """Mixed-length variant of :meth:`generate_guides`."""
        hints = self.engine.generate_bucketed(requests, max_new=2)
        return self._pack_guides(hints, guide_len)

    @staticmethod
    def _pack_guides(hints: np.ndarray, guide_len: int) -> np.ndarray:
        B = hints.shape[0]
        guides = np.full((B, guide_len), tk.PAD, np.int32)
        guides[:, 0] = tk.GUIDE_START
        guides[:, 1:3] = hints
        guides[:, 3] = tk.GUIDE_END
        return guides


# ---------------------------------------------------------------------------
# Tier-call resilience: exception taxonomy, retry policy, circuit breaker
# ---------------------------------------------------------------------------


class TierError(RuntimeError):
    """Base of the tier-call failure taxonomy."""


class TransientTierError(TierError):
    """A retryable failure (network blip, injected fault). Only this
    family is retried by :class:`ResilientTier`; anything else is an
    application error and propagates on the first raise."""


class TierTimeout(TransientTierError):
    """The (cooperative) call timeout was exceeded."""


class InjectedTierError(TransientTierError):
    """A transient failure injected by a
    :class:`repro.serving.faults.FaultPlan` ``tier_call`` spec."""


class TierUnavailableError(TierError):
    """The tier is down *right now*: either its circuit breaker shed the
    call, or retries were exhausted. The controllers catch exactly this
    to enter degraded (weak-only) routing."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Knobs for :class:`ResilientTier` (all off by default: 0 retries,
    no timeout, no breaker — a pass-through wrapper)."""
    max_retries: int = 0
    timeout: float | None = None      # cooperative — see module docstring
    backoff_base: float = 0.02        # first retry sleep, doubled per try
    backoff_max: float = 1.0
    jitter: bool = True               # scale each sleep by U[0.5, 1.5)
    breaker_threshold: int = 0        # consecutive failures to open; 0=off
    breaker_cooldown: float = 1.0     # seconds open before a half-open probe
    breaker_adaptive: bool = False    # EWMA-driven threshold/cooldown
    breaker_ewma_alpha: float = 0.2   # error-rate EWMA smoothing


class CircuitBreaker:
    """closed → open → half-open breaker over one tier's call stream.

    * **closed** — calls pass; ``threshold`` *consecutive* failures open
      the breaker.
    * **open** — calls are shed (:class:`TierUnavailableError`) until
      ``cooldown`` seconds have passed.
    * **half-open** — one probe call is let through; success closes the
      breaker, failure re-opens it (fresh cooldown). Concurrent calls
      during the probe are shed.

    ``now_fn`` is injectable (default ``time.monotonic``) so tests drive
    the cooldown with a fake clock. ``available()`` is the non-mutating
    peek the routing layer uses: True unless open and still cooling
    down — an elapsed cooldown reads as available because the very next
    call is the half-open probe.

    With ``adaptive=True`` the breaker derives its *effective* knobs
    from an EWMA of observed per-call error rates (1 = failure,
    0 = success, smoothing ``ewma_alpha``): a tier observed to be flaky
    opens after fewer consecutive failures
    (``max(1, round(threshold · (1 − ewma)))``) and cools down longer
    (``cooldown · (1 + ewma)``); a tier with a clean history keeps the
    configured knobs exactly. Default OFF — with ``adaptive=False`` the
    arithmetic never runs and every byte-identity pin over the static
    breaker holds unchanged.
    """

    def __init__(self, threshold: int, cooldown: float,
                 now_fn=time.monotonic, *, adaptive: bool = False,
                 ewma_alpha: float = 0.2):
        if threshold < 1:
            raise ValueError(f"breaker threshold must be >= 1, "
                             f"got {threshold}")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"breaker ewma_alpha must be in (0, 1], "
                             f"got {ewma_alpha}")
        self.threshold = threshold
        self.cooldown = cooldown
        self.adaptive = adaptive
        self.ewma_alpha = ewma_alpha
        self.error_ewma = 0.0
        self._now = now_fn
        self._lock = threading.Lock()
        self.state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False
        self.opens = 0               # times the breaker tripped open
        self.shed = 0                # calls rejected while open/probing
        self.transitions = 0         # state changes (closed/open/half_open)

    # -- adaptive knobs (locked callers only) ---------------------------
    def _effective_threshold_locked(self) -> int:
        if not self.adaptive:
            return self.threshold
        return max(1, round(self.threshold * (1.0 - self.error_ewma)))

    def _effective_cooldown_locked(self) -> float:
        if not self.adaptive:
            return self.cooldown
        return self.cooldown * (1.0 + self.error_ewma)

    def _observe_locked(self, failed: bool) -> None:
        if self.adaptive:
            a = self.ewma_alpha
            self.error_ewma += a * (float(failed) - self.error_ewma)

    def available(self) -> bool:
        """Non-mutating routing peek: would a call be allowed now?"""
        with self._lock:
            if self.state != "open":
                return True
            return self._now() - self._opened_at >= \
                self._effective_cooldown_locked()

    def before_call(self) -> None:
        """Gate one call; raises :class:`TierUnavailableError` to shed."""
        with self._lock:
            if self.state == "open":
                if self._now() - self._opened_at < \
                        self._effective_cooldown_locked():
                    self.shed += 1
                    raise TierUnavailableError(
                        "circuit breaker open (cooling down)")
                self.state = "half_open"
                self.transitions += 1
                self._probing = True
                return
            if self.state == "half_open":
                if self._probing:
                    self.shed += 1
                    raise TierUnavailableError(
                        "circuit breaker half-open (probe in flight)")
                self._probing = True

    def record_success(self) -> None:
        with self._lock:
            self._observe_locked(failed=False)
            if self.state != "closed":
                self.transitions += 1
            self.state = "closed"
            self._failures = 0
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._observe_locked(failed=True)
            self._probing = False
            if self.state == "half_open":
                self._trip_locked()
                return
            self._failures += 1
            if self._failures >= self._effective_threshold_locked():
                self._trip_locked()

    def trip(self) -> None:
        """Force the breaker open (brownout drills / benchmarks)."""
        with self._lock:
            self._trip_locked()

    def _trip_locked(self) -> None:
        if self.state != "open":
            self.transitions += 1
        self.state = "open"
        self._opened_at = self._now()
        self._failures = 0
        self._probing = False
        self.opens += 1

    def stats(self) -> dict:
        with self._lock:
            out = {"state": self.state, "opens": self.opens,
                   "shed": self.shed, "transitions": self.transitions}
            if self.adaptive:
                out["error_ewma"] = self.error_ewma
                out["effective_threshold"] = \
                    self._effective_threshold_locked()
                out["effective_cooldown"] = \
                    self._effective_cooldown_locked()
            return out

    # -- crash-recovery manifest hooks ----------------------------------
    def export_state(self) -> dict:
        """Host-side snapshot for the recovery manifest. ``opened_at``
        is monotonic-clock-relative and meaningless across a process
        boundary, so an open breaker is exported as *remaining* cooldown
        semantics: restore re-opens it with a fresh cooldown (the
        conservative choice — a recovering site re-probes no sooner than
        the dead one would have)."""
        with self._lock:
            return {"state": self.state, "failures": self._failures,
                    "opens": self.opens, "shed": self.shed,
                    "transitions": self.transitions,
                    "error_ewma": self.error_ewma}

    def restore_state(self, st: dict) -> None:
        with self._lock:
            self.state = st["state"]
            self._failures = st["failures"]
            self.opens = st["opens"]
            self.shed = st["shed"]
            self.transitions = st.get("transitions", 0)
            self.error_ewma = st.get("error_ewma", 0.0)
            self._probing = False
            if self.state == "open":
                self._opened_at = self._now()   # fresh cooldown


#: tier surface methods routed through the retry/breaker path; everything
#: else delegates straight to the inner tier
_WRAPPED = ("answer_batch", "answer_many", "generate_guides",
            "generate_guides_many")


class ResilientTier:
    """Retry/breaker wrapper over any tier object (see module docstring).

    With the default :class:`RetryPolicy` this is a pure pass-through:
    same calls, same exceptions, same counters — the byte-identity pins
    hold with the wrapper installed. Wrapping is idempotent-by-check at
    the call sites (``isinstance(tier, ResilientTier)``), so a fabric
    that shares one wrapper (and one breaker) across replicas composes
    with controllers that also know how to wrap.
    """

    def __init__(self, tier, policy: RetryPolicy | None = None, *,
                 name: str | None = None, fault_plan=None, seed: int = 0,
                 sleep_fn=time.sleep, now_fn=time.monotonic):
        self.inner = tier
        self.policy = policy if policy is not None else RetryPolicy()
        self.name = name if name is not None else \
            getattr(tier, "name", "tier")
        self.fault_plan = fault_plan
        self.breaker = CircuitBreaker(
            self.policy.breaker_threshold, self.policy.breaker_cooldown,
            now_fn=now_fn, adaptive=self.policy.breaker_adaptive,
            ewma_alpha=self.policy.breaker_ewma_alpha) \
            if self.policy.breaker_threshold > 0 else None
        self._sleep = sleep_fn
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.retries = 0             # retry attempts actually made
        self.failures = 0            # transient failures observed
        self.shed_calls = 0          # calls shed by the breaker
        self.sleeps: list[float] = []  # backoff sleeps, in order (tests)

    def __getattr__(self, attr):
        # only reached when normal lookup fails → delegate to the inner
        # tier. getattr() raising AttributeError here is load-bearing:
        # capability probes (``getattr(tier, "answer_many", None)``) must
        # see exactly the inner tier's surface.
        inner = object.__getattribute__(self, "inner")
        val = getattr(inner, attr)
        if attr in _WRAPPED:
            def call(*args, **kw):
                return self._call(attr, val, *args, **kw)
            call.__name__ = attr
            return call
        return val

    def _call(self, op: str, fn, *args, **kw):
        policy = self.policy
        attempts = policy.max_retries + 1
        delay = policy.backoff_base
        for attempt in range(attempts):
            if self.breaker is not None:
                try:
                    self.breaker.before_call()
                except TierUnavailableError:
                    with self._lock:
                        self.shed_calls += 1
                    raise
            try:
                if self.fault_plan is not None:
                    self.fault_plan.fire("tier_call",
                                         timeout=policy.timeout,
                                         tier=self.name, op=op)
                out = fn(*args, **kw)
            except TransientTierError as err:
                with self._lock:
                    self.failures += 1
                if self.breaker is not None:
                    self.breaker.record_failure()
                if attempt + 1 >= attempts:
                    raise TierUnavailableError(
                        f"tier {self.name!r} {op} failed after "
                        f"{attempts} attempt(s)") from err
                sleep = min(delay, policy.backoff_max)
                if policy.jitter:
                    sleep *= 0.5 + self._rng.random()
                with self._lock:
                    self.retries += 1
                    self.sleeps.append(sleep)
                self._sleep(sleep)
                delay *= 2
            else:
                if self.breaker is not None:
                    self.breaker.record_success()
                return out

    def stats(self) -> dict:
        with self._lock:
            out = {"retries": self.retries, "failures": self.failures,
                   "shed_calls": self.shed_calls}
        if self.breaker is not None:
            out["breaker"] = self.breaker.stats()
        return out

    # -- crash-recovery manifest hooks ----------------------------------
    def export_state(self) -> dict:
        with self._lock:
            out = {"retries": self.retries, "failures": self.failures,
                   "shed_calls": self.shed_calls}
        if self.breaker is not None:
            out["breaker"] = self.breaker.export_state()
        return out

    def restore_state(self, st: dict) -> None:
        with self._lock:
            self.retries = st["retries"]
            self.failures = st["failures"]
            self.shed_calls = st["shed_calls"]
        if self.breaker is not None and st.get("breaker") is not None:
            self.breaker.restore_state(st["breaker"])
