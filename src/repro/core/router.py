"""Static predictive routing (§III-C) — the RouteLLM-style front gate.

Two implementations, matching the paper's evaluation:

* :class:`LearnedRouter` — logistic regression over request embeddings,
  trained on profiling data (weak-FM success labels), the analog of the
  preference-data-trained model routers the paper builds on.
* :class:`OracleRouter` — the paper's "ideal static router" baseline: the
  eval set is profiled with the weak FM beforehand, and exactly the
  samples the weak FM answered unaided are routed weak; everything else
  goes strong. Static post-deployment, like a perfectly-trained router.

Both return True = route to the WEAK model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LearnedRouter:
    w: jax.Array          # (E,)
    b: jax.Array          # ()
    threshold: float = 0.5

    def prob_weak_ok(self, emb: jax.Array) -> jax.Array:
        return jax.nn.sigmoid(emb @ self.w + self.b)

    def route_weak(self, emb: jax.Array) -> bool:
        return bool(self.prob_weak_ok(emb) >= self.threshold)


def train_router(embs: np.ndarray, success: np.ndarray, *,
                 steps: int = 500, lr: float = 0.5,
                 threshold: float = 0.5) -> LearnedRouter:
    """Logistic regression by full-batch gradient descent."""
    X = jnp.asarray(embs, jnp.float32)
    y = jnp.asarray(success, jnp.float32)

    def loss(params):
        w, b = params
        logits = X @ w + b
        return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logits))))

    params = (jnp.zeros((X.shape[1],), jnp.float32), jnp.zeros(()))
    grad = jax.jit(jax.grad(loss))
    for _ in range(steps):
        g = grad(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return LearnedRouter(w=params[0], b=params[1], threshold=threshold)


@dataclasses.dataclass
class OracleRouter:
    """Profiled on the eval set: routes weak iff the weak FM answered this
    exact sample unaided during profiling (paper §IV-B1)."""
    weak_ok_keys: set

    def route_weak_key(self, key) -> bool:
        return key in self.weak_ok_keys
