from repro.core.rar import RAR, RARConfig, Outcome, splice_guide
from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import ShadowItem, ShadowQueue
from repro.core.fm import FMTier
from repro.core import memory, embedder, router

__all__ = ["RAR", "RARConfig", "Outcome", "splice_guide", "MicrobatchRAR",
           "ShadowItem", "ShadowQueue", "FMTier", "memory", "embedder",
           "router"]
