"""RAR core — the §III procedure split into three planes over one
decision core.

Architecture (decision core / serve plane / learn plane):

* **Decision core** (:mod:`repro.core.decisions`) — pure, side-effect-
  free classification: request → serving group
  (``classify``/``partition``), shadow probe stage → store effects +
  Outcome case (``resolve_shadow_case``), guide selection with
  near-duplicate dedup (``select_guides``), and shadow coalescing
  (``coalesce_shadow_items``). Written exactly once; every controller
  executes it.
* **Serve plane** — the user-facing critical path.
  :class:`repro.core.rar.RAR` is the thin batch-of-1 driver (the paper's
  sequential reference semantics);
  :class:`repro.core.pipeline.MicrobatchRAR` batches it (one top-k read
  via :mod:`repro.core.memory` / :mod:`repro.core.memory_sharded`, one
  sweep per FM tier through the bucketed serving engine);
  :class:`repro.serving.fabric.ServingFabric` replicates it (N
  controllers behind a round-robin dispatcher, thread-per-replica).
* **Two-level retrieval plane** (:mod:`repro.core.memory_ivf`,
  default-off) — sub-linear memory reads for large stores.
  ``RARConfig.retrieval_clusters > 0`` wraps the store (single-device or
  sharded, wrapped exactly once even when shared across fabric replicas)
  in an :class:`~repro.core.memory_ivf.IVFMemory`: level 1 routes the
  query against P online-k-means centroids (the
  :mod:`repro.kernels.memory_ivf` kernel; centroid plane in the same
  zero-copy padded layout as the store), level 2 scans only the probed
  clusters' member rows through the **existing** top-k kernel, with the
  candidates slot-sorted so both levels and the scan share THE
  (sim desc, row asc) total order. Centroid maintenance is incremental
  on the learn path (round-robin seeding, minibatch-k-means assignment,
  running-mean update, FIFO bucket eviction with stale-entry
  neutralization on the query path); ``reindex()`` rebuilds from the
  store at attach/grow time. Cluster c lives with shard ``c % S`` — the
  per-shard centroid-subset routes merge bit-identically into the
  global route. ``retrieval_probes`` is the recall-vs-latency knob
  (CLI ``--retrieval-clusters``/``--retrieval-probes``): probing all
  clusters reproduces the exact scan's valid entries, and the exhaustive
  scan stays both the default (``retrieval_clusters = 0`` constructs no
  wrapper — byte-identical serving, pinned in
  ``tests/test_memory_ivf.py``) and the recall oracle
  (``benchmarks/memory_bench.py`` measures recall@k against it).
  Optional host-offload tiering keeps cold clusters' rows in a host
  mirror (bit-identical results, one extra sync per query) — the HBM
  tier model for stores larger than device memory. Capacity grow
  (:func:`repro.core.memory.grow_memory` /
  :meth:`~repro.core.memory.CommitStream.grow`) re-lays-out the ring in
  place — unwrapped histories keep slots/eviction guards exactly,
  wrapped histories linearize oldest-first with a slot remap — and the
  IVF plane re-buckets against the new layout.
* **Admission / scheduling plane** (:mod:`repro.serving.scheduler` +
  :mod:`repro.serving.loadgen`) — the open-loop front door above the
  serve plane, default-off (closed-loop callers keep submitting
  pre-formed microbatches unchanged). A
  :class:`~repro.serving.scheduler.ContinuousBatcher` admits *single*
  requests stamped with arrival time, stream id, priority, and an
  optional deadline, and forms microbatches under a **size-or-deadline
  close rule**: a batch closes when it fills to ``microbatch``, or when
  the *oldest* member's queueing budget — ``deadline_ms`` if stamped,
  else ``slo_ms / (1 + priority)`` — is about to breach. Formation is
  **bucket-aware** (one prompt-length bucket per open batch, so a
  closed batch hits ``ServingEngine.generate_bucketed`` as a single
  already-grouped bucket instead of fragmenting the jit cache) and
  **stream-ordered** (a stream switching buckets closes its previous
  open batch first; each stream pins to one replica), so per-stream
  FIFO — and therefore routing and strong-call counts — is exactly the
  closed-loop run's: the arrival clock and close rule move *batch
  boundaries*, never decisions (pinned in ``tests/test_scheduler.py``
  for thread and process fabrics alike). The lifecycle is
  ``arrival → admit → close → dispatch → resolve``: closed batches
  dispatch into the same ``Ticket``/``submit``/``join`` boundary both
  fabrics already expose, and per-request latency — admission→dispatch
  queueing delay and admission→resolve end-to-end — lands in the
  fabric's :class:`~repro.serving.metrics.MetricsRegistry` histograms
  (aggregate and per stream, p50/p99 via ``fabric.metrics()``, the
  serve CLI's ``--metrics-json``/``--metrics-prom``, and the open-loop
  bench rows). Formation runs in *virtual trace time* — a pure
  function of the (seedable) arrival trace from
  :mod:`repro.serving.loadgen` (Poisson, bursty on/off, replayed
  traces; per-stream rates/priorities) — so every open-loop run is
  deterministic; wall-clock pacing is a replay option, not an input to
  formation.
* **Learn plane** — shadow inference + memory commits, scheduled off the
  serve path by the :class:`repro.core.shadow.ShadowQueue`
  (inline/deferred/async drains, optional near-duplicate coalescing) and
  landed atomically through the epoch-versioned
  :class:`repro.core.memory.CommitBuffer`. The
  :class:`repro.core.memory.CommitStream` is the serve/learn interface:
  one buffer + store lock + host-side commit counter per serving site,
  broadcasting every applied epoch to all subscribed replica views.

* **Recovery plane** — fault tolerance wrapped around all three,
  default-off and byte-transparent when off:

  - *Tier resilience* (:mod:`repro.core.fm`): :class:`ResilientTier`
    adds per-call timeout + bounded retries with exponential backoff,
    and a :class:`CircuitBreaker` per tier. A strong-tier outage does
    not error requests — the decision core routes **degraded**
    (``classify``/``partition`` with ``strong_ok=False``): memory-hard
    requests serve weak-only (``memory_hard_degraded``) and shadow
    probes are parked as deferred :class:`~repro.core.shadow.ShadowItem`
    s (``shadow_deferred``), replayed through the normal drain once the
    breaker's half-open probe closes it. With ``breaker_adaptive`` the
    breaker derives its *effective* threshold/cooldown from an EWMA of
    observed per-call error rates — a tier seen to be flaky opens
    sooner and cools longer; a clean history keeps the configured
    knobs exactly.
  - *Crash-consistent memory* (:mod:`repro.core.memory`):
    :class:`MemoryJournal` write-ahead-logs every commit epoch (CRC-
    framed, fsync-before-apply) and snapshots periodically; recovery
    replays the WAL through the same ``CommitBuffer.apply_ops`` path
    the live drain uses, so the restored store is byte-identical.
    Replay stops at the first torn or bit-rotted frame with a
    structured :class:`~repro.core.memory.JournalCorruptionWarning`
    (where + why) — everything before it is recovered, never a torn
    state. Each WAL frame also carries the site's **engine-state
    manifest** (logical clock, routing/RQ2 counters, breaker state,
    engine call/token counters, deferred probes), fsynced atomically
    with the store ops it pairs with, so ``recover()`` restores the
    *whole* serving site — not just the store bytes.
  - *Replica supervision* (:mod:`repro.serving.fabric`,
    :mod:`repro.serving.procfabric`): crashed serve workers restart
    against the shared commit-stream view and their microbatches
    redispatch to a survivor (bounded). The process fabric hosts one
    OS process per replica behind the same ``Ticket``/``submit``
    boundary: workers hold serve-only state (a store mirror fed by the
    epoch broadcast), the parent keeps every authoritative effect, and
    the worker's "done" message is the atomic commit point — so a
    heartbeat-lease supervisor (missed lease → suspect → dead) can
    SIGKILL-detect, respawn, and redispatch byte-identically, reusing
    the clock stamps allocated at admission. A drain-ack gate (the
    parent acks each "done" after its drain; the worker blocks on the
    ack before its next serve) restores the thread replica's
    serve-after-drain order across the process boundary, so routing is
    byte-identical under arbitrarily deep pipelined submission.
  - *Drain-epoch retention* (:mod:`repro.core.shadow` +
    :mod:`repro.core.pipeline`): a drain epoch that *fails* mid-run
    loses nothing. The queue re-queues the failed epoch's items at the
    head (seq order preserved, retried ahead of newer work) and the
    runner rolls its partial effects back — staged commit-buffer ops
    (``CommitBuffer.mark``/``rollback``), half-resolved Outcome fields,
    and the RQ2/coalescing counters — so the retry, once the fault
    clears, is byte-identical to a first run. The async drainer holds
    retries until a barrier consumes the error (no hot retry loop);
    ``flush_shadow()`` after the fault resolves every pending Outcome
    with ``items_enqueued == items_drained``.

* **Observability + adaptive control plane** — host-side metrics and
  the cost-model drain cadence built on them, default-off and
  byte-transparent when off:

  - *Metrics* (:mod:`repro.serving.metrics`): one
    :class:`MetricsRegistry` (counters / gauges / bounded-reservoir
    histograms behind a single lock — consistent snapshots, never a
    torn read) carries per-replica queue depth, shadow staleness
    (batches + logical time), drain cost (items / probe calls / wall
    seconds per epoch), commit-stream progress and lag, jit-cache
    hits/misses, breaker transitions, and supervision events.
    **Zero device syncs**: every recorded value is already a host
    number; a metrics scrape can never stall the serve pipeline.
    Surfaced via ``fabric.metrics()`` (plus the process fabric's
    per-worker commit-epoch lag, fed by epoch-carrying heartbeats)
    and the serve CLI's ``--metrics-json``/``--metrics-every``.
  - *Adaptive drain cadence* (``shadow_mode="adaptive"``):
    a :class:`~repro.core.shadow.AdaptiveDrainPolicy` shared
    fabric-wide fits drain cost online (exponentially-decayed least
    squares over observed ``(items, seconds)`` epochs) and drains when
    the expected staleness cost — pending items × re-shadow
    probability × per-item cost — exceeds the fixed overhead a drain
    amortizes; ``shadow_flush_every`` demotes to a hard staleness cap.
    Cold start always drains, so the always-drain base policy pins
    adaptive ≡ deferred/flush-every-1 byte-identically
    (``tests/test_metrics.py``).
  - *Autoscaling hooks* (:mod:`repro.serving.fabric`): ``scale_to(n)``
    spawns replicas live into the round-robin or retires the
    highest-index slot (terminal ``"retired"`` health — dispatch skips
    it, its queued FIFO still drains, the learn replica never
    retires); ``set_autoscaler(policy)`` + ``autoscale()`` drive it
    from a ``metrics()`` snapshot behind a health gate (no resize
    while any slot is dead/mid-restart).

  - *Fault injection* (:mod:`repro.serving.faults`): a seedable
    :class:`FaultPlan` fires crashes/errors/delays/kills at the named
    logical sites (``replica_serve``, ``tier_call``, ``drain``,
    ``wal_write``, ``commit_apply``, ``heartbeat``,
    ``transport_frame``, ``clock_skew``) — every failure mode above,
    including hung workers and lease-clock skew, is reproducible
    (``random_plan(seed)`` schedules them all).

Equivalence chain (machine-checked): sequential ≡ microbatch B=1 ≡
deferred flush-every-batch ≡ async with per-batch barrier ≡ 1-replica
inline fabric — see ``tests/test_pipeline.py``, ``tests/test_shadow.py``
and ``tests/test_fabric.py``.

Failure-mode invariants (machine-checked in ``tests/test_faults.py``):

* a replica crash fires *before* any side effect, so a redispatched
  microbatch's outcomes + commit counters are byte-identical to a
  no-fault run — for thread replicas and for SIGKILL'd or hung worker
  *processes* alike (``tests/test_procfabric.py``: the "done" message
  is the only commit point, so death before it leaves nothing behind);
* a kill between WAL append and commit apply recovers to one epoch
  *ahead* of the pre-crash view, a kill before the WAL append recovers
  to the epoch *behind* — never a torn epoch either way; a torn or
  bit-rotted WAL frame stops replay exactly there, with a structured
  warning;
* killing a *whole fabric* and rebuilding it on the journal path
  restores store, logical clock, counters and breaker state to what a
  never-killed run shows at the same point (the manifest rides in the
  same fsync as the store ops — the two can never disagree);
* a strong-tier brownout serves every request weak-only with zero
  errored tickets, and the deferred probes replay exactly once after
  the breaker closes;
* with no ``FaultPlan`` and the resilience knobs at their defaults
  (``adaptive`` off, thread transport), every pre-existing
  byte-identity pin holds unchanged.
"""
from repro.core.rar import RAR, RARConfig, Outcome, splice_guide
from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import ShadowItem, ShadowQueue
from repro.core.fm import (FMTier, ResilientTier, RetryPolicy,
                           CircuitBreaker, TierError, TransientTierError,
                           TierTimeout, TierUnavailableError)
from repro.core import decisions, memory, embedder, router

__all__ = ["RAR", "RARConfig", "Outcome", "splice_guide", "MicrobatchRAR",
           "ShadowItem", "ShadowQueue", "FMTier", "ResilientTier",
           "RetryPolicy", "CircuitBreaker", "TierError",
           "TransientTierError", "TierTimeout", "TierUnavailableError",
           "decisions", "memory", "embedder", "router"]
