from repro.core.rar import RAR, RARConfig, Outcome
from repro.core.fm import FMTier
from repro.core import memory, embedder, router

__all__ = ["RAR", "RARConfig", "Outcome", "FMTier", "memory", "embedder",
           "router"]
