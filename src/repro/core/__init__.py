"""RAR core — the §III procedure split into three planes over one
decision core.

Architecture (decision core / serve plane / learn plane):

* **Decision core** (:mod:`repro.core.decisions`) — pure, side-effect-
  free classification: request → serving group
  (``classify``/``partition``), shadow probe stage → store effects +
  Outcome case (``resolve_shadow_case``), guide selection with
  near-duplicate dedup (``select_guides``), and shadow coalescing
  (``coalesce_shadow_items``). Written exactly once; every controller
  executes it.
* **Serve plane** — the user-facing critical path.
  :class:`repro.core.rar.RAR` is the thin batch-of-1 driver (the paper's
  sequential reference semantics);
  :class:`repro.core.pipeline.MicrobatchRAR` batches it (one top-k read
  via :mod:`repro.core.memory` / :mod:`repro.core.memory_sharded`, one
  sweep per FM tier through the bucketed serving engine);
  :class:`repro.serving.fabric.ServingFabric` replicates it (N
  controllers behind a round-robin dispatcher, thread-per-replica).
* **Learn plane** — shadow inference + memory commits, scheduled off the
  serve path by the :class:`repro.core.shadow.ShadowQueue`
  (inline/deferred/async drains, optional near-duplicate coalescing) and
  landed atomically through the epoch-versioned
  :class:`repro.core.memory.CommitBuffer`. The
  :class:`repro.core.memory.CommitStream` is the serve/learn interface:
  one buffer + store lock + host-side commit counter per serving site,
  broadcasting every applied epoch to all subscribed replica views.

* **Recovery plane** — fault tolerance wrapped around all three,
  default-off and byte-transparent when off:

  - *Tier resilience* (:mod:`repro.core.fm`): :class:`ResilientTier`
    adds per-call timeout + bounded retries with exponential backoff,
    and a :class:`CircuitBreaker` per tier. A strong-tier outage does
    not error requests — the decision core routes **degraded**
    (``classify``/``partition`` with ``strong_ok=False``): memory-hard
    requests serve weak-only (``memory_hard_degraded``) and shadow
    probes are parked as deferred :class:`~repro.core.shadow.ShadowItem`
    s (``shadow_deferred``), replayed through the normal drain once the
    breaker's half-open probe closes it.
  - *Crash-consistent memory* (:mod:`repro.core.memory`):
    :class:`MemoryJournal` write-ahead-logs every commit epoch (CRC-
    framed, fsync-before-apply) and snapshots periodically; recovery
    replays the WAL through the same ``CommitBuffer.apply_ops`` path
    the live drain uses, so the restored store is byte-identical.
  - *Replica supervision* (:mod:`repro.serving.fabric`): crashed serve
    workers restart against the shared commit-stream view and their
    microbatch redispatches to a survivor (bounded).
  - *Fault injection* (:mod:`repro.serving.faults`): a seedable
    :class:`FaultPlan` fires crashes/errors/delays at the named logical
    sites (``replica_serve``, ``tier_call``, ``drain``, ``wal_write``,
    ``commit_apply``) — every failure mode above is reproducible.

Equivalence chain (machine-checked): sequential ≡ microbatch B=1 ≡
deferred flush-every-batch ≡ async with per-batch barrier ≡ 1-replica
inline fabric — see ``tests/test_pipeline.py``, ``tests/test_shadow.py``
and ``tests/test_fabric.py``.

Failure-mode invariants (machine-checked in ``tests/test_faults.py``):

* a replica crash fires *before* any side effect, so a redispatched
  microbatch's outcomes + commit counters are byte-identical to a
  no-fault run;
* a kill between WAL append and commit apply recovers to one epoch
  *ahead* of the pre-crash view, a kill before the WAL append recovers
  to the epoch *behind* — never a torn epoch either way;
* a strong-tier brownout serves every request weak-only with zero
  errored tickets, and the deferred probes replay exactly once after
  the breaker closes;
* with no ``FaultPlan`` and the resilience knobs at their defaults,
  every pre-existing byte-identity pin holds unchanged.
"""
from repro.core.rar import RAR, RARConfig, Outcome, splice_guide
from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import ShadowItem, ShadowQueue
from repro.core.fm import (FMTier, ResilientTier, RetryPolicy,
                           CircuitBreaker, TierError, TransientTierError,
                           TierTimeout, TierUnavailableError)
from repro.core import decisions, memory, embedder, router

__all__ = ["RAR", "RARConfig", "Outcome", "splice_guide", "MicrobatchRAR",
           "ShadowItem", "ShadowQueue", "FMTier", "ResilientTier",
           "RetryPolicy", "CircuitBreaker", "TierError",
           "TransientTierError", "TierTimeout", "TierUnavailableError",
           "decisions", "memory", "embedder", "router"]
