"""RAR core — the §III procedure split into three planes over one
decision core.

Architecture (decision core / serve plane / learn plane):

* **Decision core** (:mod:`repro.core.decisions`) — pure, side-effect-
  free classification: request → serving group
  (``classify``/``partition``), shadow probe stage → store effects +
  Outcome case (``resolve_shadow_case``), guide selection with
  near-duplicate dedup (``select_guides``), and shadow coalescing
  (``coalesce_shadow_items``). Written exactly once; every controller
  executes it.
* **Serve plane** — the user-facing critical path.
  :class:`repro.core.rar.RAR` is the thin batch-of-1 driver (the paper's
  sequential reference semantics);
  :class:`repro.core.pipeline.MicrobatchRAR` batches it (one top-k read
  via :mod:`repro.core.memory` / :mod:`repro.core.memory_sharded`, one
  sweep per FM tier through the bucketed serving engine);
  :class:`repro.serving.fabric.ServingFabric` replicates it (N
  controllers behind a round-robin dispatcher, thread-per-replica).
* **Learn plane** — shadow inference + memory commits, scheduled off the
  serve path by the :class:`repro.core.shadow.ShadowQueue`
  (inline/deferred/async drains, optional near-duplicate coalescing) and
  landed atomically through the epoch-versioned
  :class:`repro.core.memory.CommitBuffer`. The
  :class:`repro.core.memory.CommitStream` is the serve/learn interface:
  one buffer + store lock + host-side commit counter per serving site,
  broadcasting every applied epoch to all subscribed replica views.

Equivalence chain (machine-checked): sequential ≡ microbatch B=1 ≡
deferred flush-every-batch ≡ async with per-batch barrier ≡ 1-replica
inline fabric — see ``tests/test_pipeline.py``, ``tests/test_shadow.py``
and ``tests/test_fabric.py``.
"""
from repro.core.rar import RAR, RARConfig, Outcome, splice_guide
from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import ShadowItem, ShadowQueue
from repro.core.fm import FMTier
from repro.core import decisions, memory, embedder, router

__all__ = ["RAR", "RARConfig", "Outcome", "splice_guide", "MicrobatchRAR",
           "ShadowItem", "ShadowQueue", "FMTier", "decisions", "memory",
           "embedder", "router"]
