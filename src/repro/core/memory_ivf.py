"""IVF two-level retrieval plane — sub-linear guide-store reads.

The exact store scan (:mod:`repro.core.memory`) touches all C rows per
query; at C = 65536 that single pass caps the whole serving fabric. This
module adds the ROADMAP's hierarchical memory: an inverted-file (IVF)
index over the same store, queried in two levels:

1. **Route** — score the query against P cluster centroids (the
   :mod:`repro.kernels.memory_ivf` kernel; centroid plane kept in the
   same zero-copy padded layout as the store) and take the top-P'
   clusters under THE (score desc, row asc) total order.
2. **Scan** — gather only the probed clusters' member rows into a small
   (L, Ep) buffer, *sorted by global slot*, and run the **existing**
   zero-copy top-k kernel over it. Because the candidates are
   slot-sorted, the kernel's local lowest-row tie-break equals the
   global (sim desc, slot asc) order — the result ranking is the exact
   scan's for every entry the probed clusters cover.

The exact scan stays the **default** (``RARConfig.retrieval_clusters =
0``: controllers never construct this wrapper — byte-identity pinned in
``tests/test_memory_ivf.py``) and the **oracle**: recall@k of the IVF
path is property-measured against ``mem.query_topk`` on the same backing
store, and probing *all* clusters reproduces the oracle's valid entries
exactly.

Centroid maintenance (online k-means, incrementally on add)
-----------------------------------------------------------
The first P inserts seed clusters 0..P-1 round-robin; each later insert
is assigned to the nearest centroid (batch-start centroids within one
``add_batch`` — minibatch k-means) and updates that cluster's running
mean (``csum/ccount``), renormalized for cosine routing. Member lists
are fixed-width (P, M) slot buckets with FIFO ring eviction: a bucket
overflow drops the cluster's *oldest* member from the index (bounded
recall loss, counted in :meth:`IVFMemory.stats`); a store-ring overwrite
removes the slot from its old bucket before re-bucketing. Entries
evicted from a bucket or overwritten in the ring have ``assign[slot]``
cleared, and the query path re-checks ``assign[slot] == probed cluster``
on gather — stale member-list entries can never surface (nor duplicate
a candidate). :meth:`IVFMemory.reindex` rebuilds the whole index from
the backing store (vectorized k-means with two refinement sweeps) —
used at attach time over a populated store and after
:meth:`IVFMemory.grow`.

Index mutation runs on the learn path (commit drains — it shares the
store's write serialization: the commit stream's lock covers both) and
is host-side numpy; device mirrors refresh lazily before the next query.

Cluster → shard placement
-------------------------
Over a :class:`~repro.core.memory_sharded.ShardedMemory` backing,
cluster c lives with shard ``c % S``: the route runs per-shard over that
shard's centroid *subset* and the S partial routes merge under the same
(score desc, cluster asc) order — bit-identical to routing the global
centroid plane (the merge is :func:`repro.kernels.ref._topk_select`,
THE shared total order), pinned in the test suite. This subsumes the
per-replica memory-shard follow-up: replicas probing their local subset
and merging lose nothing vs. a global route.

Host-offload tiering (cold clusters)
------------------------------------
With ``offload=True`` a host mirror of the store rows backs **cold**
clusters (not routed to within the last ``cold_after`` queries): their
candidate rows are gathered from the mirror and uploaded with the query
while hot clusters gather on-device — modelling an HBM tier that keeps
only hot clusters resident. Costs one extra host sync per query (the
routed cluster ids come back to pick the tier); results are pinned
bit-identical to the non-offload path, and :meth:`IVFMemory.stats`
reports the host/device row traffic split.

Recall-vs-latency knob: ``probes`` (CLI ``--retrieval-probes``). Scan
work is O(P + P'·M) rows instead of O(C); raising ``probes`` toward
``clusters`` trades latency for recall, reaching exactness at the top.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import memory as mem
from repro.core.memory_sharded import ShardedMemory
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.memory_topk import MASK_VALID, _round_up, padded_rows


# ---------------------------------------------------------------------------
# Jitted query path
# ---------------------------------------------------------------------------


def _route_merged(planes, q, n_probe: int):
    """Level 1 inside the jitted query: route each centroid plane (one
    per shard — a single plane when unsharded), map subset rows to global
    cluster ids, and merge the partials under the shared total order.
    Padding/sentinel subset rows map to the 2**30 sentinel id; their
    -2.0 scores drop them at the gather stage."""
    scores, cids = [], []
    for cent, cmask, cidmap in planes:
        s, c = kops.ivf_route_padded(cent, q, cmask, n_probe, MASK_VALID)
        ps = cidmap.shape[0]
        g = jnp.where(c < ps, cidmap[jnp.clip(c, 0, ps - 1)],
                      jnp.int32(2 ** 30))
        scores.append(s)
        cids.append(g)
    if len(planes) == 1:
        return scores[0], cids[0]
    return ref._topk_select(jnp.concatenate(scores),
                            jnp.concatenate(cids), n_probe)


def _route_merged_batch(planes, qs, n_probe: int):
    scores, cids = [], []
    for cent, cmask, cidmap in planes:
        s, c = kops.ivf_route_batch_padded(cent, qs, cmask, n_probe,
                                           MASK_VALID)      # (B, n_probe)
        ps = cidmap.shape[0]
        g = jnp.where(c < ps, cidmap[jnp.clip(c, 0, ps - 1)],
                      jnp.int32(2 ** 30))
        scores.append(s.T)
        cids.append(g.T)
    if len(planes) == 1:
        return scores[0].T, cids[0].T
    ms, mc = ref._topk_select(jnp.concatenate(scores, axis=0),
                              jnp.concatenate(cids, axis=0), n_probe)
    return ms.T, mc.T


def _phys_rows(slots, cs: int, csp: int):
    """Logical ring slot → physical padded row of the backing store
    (identity for a single-device store; the per-shard padded stride for
    a sharded one, matching ``memory_sharded``'s placement)."""
    return (slots // cs) * csp + (slots % cs) if cs else slots


def _gather_candidates(members, assign, scores, cids):
    """Expand routed clusters into a validated candidate slot list.
    Dead probes (score ≤ -2.0: unseeded/sentinel), empty bucket slots,
    and stale member entries (``assign`` no longer points back at the
    probed cluster — ring overwrite or bucket eviction) are all dropped
    by one boolean mask; survivors are unique."""
    P, M = members.shape
    C = assign.shape[0]
    cids_c = jnp.clip(cids, 0, P - 1)
    slots = jnp.take(members, cids_c, axis=0)
    slots = slots.reshape(slots.shape[:-2] + (-1,))          # (..., P'*M)
    owner = jnp.repeat(cids_c, M, axis=-1)
    ok = (jnp.repeat(scores, M, axis=-1) > -2.0) & (slots >= 0)
    ok = ok & (assign[jnp.clip(slots, 0, C - 1)] == owner)
    return slots, owner, ok


@partial(jax.jit, static_argnames=("k", "n_probe", "required", "cs", "csp"))
def _ivf_topk_jit(planes, members, assign, emb, mask, hard, added_at,
                  guide, q, *, k: int, n_probe: int, required: int,
                  cs: int, csp: int) -> mem.TopKResult:
    """Fused single-query IVF read: route → gather → existing top-k
    kernel → packed-meta epilogue, one jitted call (one ``device_get``
    per phase, like the exact path)."""
    C = assign.shape[0]
    scores, cids = _route_merged(planes, q, n_probe)
    slots, owner, ok = _gather_candidates(members, assign, scores, cids)
    # slot-sorted candidates: the scan kernel's local lowest-row
    # tie-break then equals the global (sim desc, slot asc) order
    order = jnp.argsort(jnp.where(ok, slots, jnp.int32(2 ** 30)))
    slots_s = slots[order]
    ok_s = ok[order]
    phys = _phys_rows(jnp.clip(slots_s, 0, C - 1), cs, csp)
    rows = jnp.where(ok_s[:, None], emb[phys], 0.0)
    bits = jnp.where(ok_s, mask[phys, 0], 0)
    L = slots.shape[0]
    Lp = padded_rows(L)
    gmem = jnp.zeros((Lp, emb.shape[1]), jnp.float32).at[:L].set(rows)
    gmask = jnp.zeros((Lp, 1), jnp.int32).at[:L, 0].set(bits)
    sims, lidx = kops.memory_topk_padded(gmem, q, gmask, k, required)
    li = jnp.clip(lidx, 0, L - 1)
    gidx = jnp.clip(slots_s[li], 0, C - 1)
    return mem.TopKResult(sim=sims,
                          meta=mem.pack_meta_parts(gidx, gmask[li, 0],
                                                   hard, added_at, guide))


@partial(jax.jit, static_argnames=("k", "n_probe", "required", "cs", "csp"))
def _ivf_topk_batch_jit(planes, members, assign, emb, mask, hard, added_at,
                        guide, qs, *, k: int, n_probe: int, required: int,
                        cs: int, csp: int) -> mem.TopKResult:
    """Fused multi-query IVF read. Candidate sets differ per query, so
    the selection runs the shared :func:`~repro.kernels.ref._topk_select`
    rounds directly over each query's gathered candidates, keyed by
    global slot — the same total order the store kernels implement.
    Memory is O(B·L·Ep); the wrapper chunks B to bound it."""
    C = assign.shape[0]
    B, E = qs.shape
    scores, cids = _route_merged_batch(planes, qs, n_probe)  # (B, n_probe)
    slots, owner, ok = _gather_candidates(members, assign, scores, cids)
    L = slots.shape[1]
    phys = _phys_rows(jnp.clip(slots, 0, C - 1), cs, csp)
    rows = jnp.where(ok[..., None], emb[phys], 0.0)          # (B, L, Ep)
    bits = jnp.where(ok, mask[phys, 0], 0)                   # (B, L)
    qp = jnp.zeros((B, emb.shape[1]), jnp.float32).at[:, :E].set(
        qs.astype(jnp.float32))
    sims = jnp.einsum("ble,be->bl", rows, qp)
    sims = jnp.where(ok & ((bits & required) == required), sims, -2.0)
    # invalid candidates get distinct above-capacity keys so multiple
    # sentinel rounds keep the -2.0 sim (mirroring the exact scan's
    # distinct masked rows) instead of collapsing to one consumed key
    keys = jnp.where(ok, slots,
                     2 ** 30 + jnp.arange(L, dtype=jnp.int32)[None, :])
    top_s, top_r = ref._topk_select(sims.T, keys.T, k)       # (k, B)
    top_s, top_r = top_s.T, top_r.T
    gidx = jnp.clip(top_r, 0, C - 1)
    hit = keys[:, :, None] == top_r[:, None, :]              # (B, L, k)
    wbits = jnp.sum(bits[:, :, None] * hit, axis=1)
    return mem.TopKResult(sim=top_s,
                          meta=mem.pack_meta_parts(gidx, wbits, hard,
                                                   added_at, guide))


@partial(jax.jit, static_argnames=("n_probe",))
def _route_jit(planes, q, *, n_probe: int):
    return _route_merged(planes, q, n_probe)


@partial(jax.jit, static_argnames=("k", "required", "cs", "csp"))
def _gather_topk_tiered_jit(emb, mask, hard, added_at, guide, slots_s,
                            hot_s, host_rows, host_bits, q, *, k: int,
                            required: int, cs: int, csp: int
                            ) -> mem.TopKResult:
    """Level-2 scan for the offload path: hot candidates gather from the
    device store, cold candidates ride in as the host-mirror gather
    (``host_rows``/``host_bits``, zero where hot). The combined buffer is
    byte-identical to the non-offload gather (the mirror is exact), so
    the result is too."""
    C = hard.shape[0]
    phys = _phys_rows(jnp.clip(slots_s, 0, C - 1), cs, csp)
    rows = jnp.where(hot_s[:, None], emb[phys], 0.0) + host_rows
    bits = jnp.where(hot_s, mask[phys, 0], 0) + host_bits
    L = slots_s.shape[0]
    Lp = padded_rows(L)
    gmem = jnp.zeros((Lp, emb.shape[1]), jnp.float32).at[:L].set(rows)
    gmask = jnp.zeros((Lp, 1), jnp.int32).at[:L, 0].set(bits)
    sims, lidx = kops.memory_topk_padded(gmem, q, gmask, k, required)
    li = jnp.clip(lidx, 0, L - 1)
    gidx = jnp.clip(slots_s[li], 0, C - 1)
    return mem.TopKResult(sim=sims,
                          meta=mem.pack_meta_parts(gidx, gmask[li, 0],
                                                   hard, added_at, guide))


# ---------------------------------------------------------------------------
# The store wrapper
# ---------------------------------------------------------------------------


class IVFMemory:
    """IVF wrapper around a backing store (:class:`MemoryState` or
    :class:`ShardedMemory`), presenting the store *method* API — so the
    :mod:`repro.core.memory` dispatchers, :class:`CommitBuffer`, and
    every controller work against it unchanged. Reads go through the
    two-level path; writes delegate to the backing store and update the
    cluster index incrementally. The backing store stays the exact
    oracle (:meth:`exact_query_topk`).

    Not journal-compatible (the WAL snapshots a raw ``MemoryState``);
    ``RARConfig`` validation rejects the combination up front.
    """

    def __init__(self, store, *, clusters: int, probes: int = 4,
                 bucket_cap: int | None = None, offload: bool = False,
                 cold_after: int = 1024):
        if isinstance(store, IVFMemory):
            raise TypeError("backing store is already IVF-wrapped")
        C = store.capacity
        if not 2 <= clusters <= C:
            raise ValueError(f"retrieval_clusters={clusters} must be in "
                             f"[2, capacity={C}]")
        if not 1 <= probes <= clusters:
            raise ValueError(f"retrieval_probes={probes} must be in "
                             f"[1, clusters={clusters}]")
        self.store = store
        self.clusters = int(clusters)
        self.probes = int(probes)
        self._sharded = isinstance(store, ShardedMemory)
        if self._sharded:
            S = store.shards
            if clusters % S:
                raise ValueError(f"clusters={clusters} not divisible by "
                                 f"{S} shards (cluster c lives with "
                                 f"shard c % S)")
            if probes > clusters // S:
                raise ValueError(f"probes={probes} exceeds the "
                                 f"{clusters // S} clusters per shard")
        self._ep = store.emb.shape[1]
        if bucket_cap is None:
            # ~4x the average cluster occupancy of a full ring: skewed
            # clusters overflow (FIFO bucket eviction) only past that
            bucket_cap = max(8, math.ceil(4 * C / self.clusters))
        self.bucket_cap = _round_up(int(bucket_cap), 8)
        self.offload = bool(offload)
        self.cold_after = int(cold_after)
        self._ptr_host = int(jax.device_get(store.ptr))
        # host-side index state (numpy; mutated on the learn path only)
        self._cent = np.zeros((self.clusters, self._ep), np.float32)
        self._csum = np.zeros((self.clusters, self._ep), np.float32)
        self._ccount = np.zeros(self.clusters, np.int64)
        self._seeded = 0
        self._assign = np.full(C, -1, np.int32)
        self._members = np.full((self.clusters, self.bucket_cap), -1,
                                np.int32)
        self._mptr = np.zeros(self.clusters, np.int64)
        if self.offload:
            self._emb_host = np.zeros((C, self._ep), np.float32)
            self._bits_host = np.zeros(C, np.int32)
            self._last_probe = np.zeros(self.clusters, np.int64)
            self._tier_hot = np.ones(self.clusters, bool)
        # stats (host counters, no device syncs)
        self.bucket_evictions = 0
        self.reindexes = 0
        self.host_fetch_rows = 0
        self.device_fetch_rows = 0
        self._qcount = 0
        self._dirty = True
        self._planes = None
        self._members_dev = None
        self._assign_dev = None
        if self._ptr_host:
            self.reindex()

    # -- delegation -----------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.store.capacity

    @property
    def guide(self):
        return self.store.guide

    @property
    def hard(self):
        return self.store.hard

    @property
    def added_at(self):
        return self.store.added_at

    @property
    def valid(self):
        return self.store.valid

    @property
    def has_guide(self):
        return self.store.has_guide

    @property
    def ptr(self):
        return self.store.ptr

    @property
    def size_fast(self) -> int:
        return min(self._ptr_host, self.capacity)

    def debug_size(self) -> int:
        return self.store.debug_size()

    # -- index maintenance ----------------------------------------------
    def _ivf_add(self, X: np.ndarray, slots: np.ndarray) -> None:
        """Online k-means + bucket update for K new rows landing at ring
        ``slots``. Assignment scores use the batch-start centroids
        (minibatch k-means); centroid running means update sequentially.
        """
        P, M = self.clusters, self.bucket_cap
        nearest = (np.argmax(X @ self._cent.T, axis=1)
                   if self._seeded == P else None)
        for j in range(X.shape[0]):
            slot = int(slots[j])
            x = X[j]
            if self._seeded < P:
                c = self._seeded        # round-robin seeding
                self._seeded += 1
            elif nearest is not None:
                c = int(nearest[j])
            else:
                c = int(np.argmax(self._cent[:self._seeded] @ x))
            self._csum[c] += x
            self._ccount[c] += 1
            m = self._csum[c] / self._ccount[c]
            n = float(np.linalg.norm(m))
            self._cent[c] = m / n if n > 0.0 else m
            prev = int(self._assign[slot])
            if prev >= 0:               # ring overwrite: unbucket first
                b = self._members[prev]
                b[b == slot] = -1
            row = self._members[c]
            pos = int(self._mptr[c]) % M
            old = int(row[pos])
            if old >= 0 and old != slot:
                self._assign[old] = -1  # bucket overflow: evict oldest
                self.bucket_evictions += 1
            row[pos] = slot
            self._mptr[c] += 1
            self._assign[slot] = c
        self._dirty = True

    def _logical_rows(self):
        st = self.store
        if self._sharded:
            phys = _phys_rows(jnp.arange(st.capacity, dtype=jnp.int32),
                              st.cs, st.csp)
            return jnp.asarray(st.emb)[phys], jnp.asarray(st.mask)[phys, 0]
        return st.emb[:st.capacity], st.mask[:st.capacity, 0]

    def reindex(self) -> None:
        """Rebuild the whole index from the backing store: vectorized
        k-means (round-robin seeding from the oldest valid rows, two
        refinement sweeps once fully seeded) + bucket rebuild keeping
        each cluster's newest ``bucket_cap`` members. One bulk store
        transfer — runs at attach/grow time, never per query."""
        C, P, M = self.capacity, self.clusters, self.bucket_cap
        emb, bits = jax.device_get(self._logical_rows())
        emb = np.asarray(emb, np.float32)
        bits = np.asarray(bits, np.int32)
        if self.offload:
            self._emb_host[:] = emb
            self._bits_host[:] = bits
        self._assign = np.full(C, -1, np.int32)
        self._members = np.full((P, M), -1, np.int32)
        self._mptr = np.zeros(P, np.int64)
        self._csum = np.zeros((P, self._ep), np.float32)
        self._ccount = np.zeros(P, np.int64)
        self._cent = np.zeros((P, self._ep), np.float32)
        self.reindexes += 1
        self._dirty = True
        slot = np.arange(C)
        vs = slot[(bits & MASK_VALID) != 0]
        if not len(vs):
            self._seeded = 0
            return
        ptr = self._ptr_host
        age = slot if ptr <= C else (slot - ptr) % C
        vs = vs[np.argsort(age[vs], kind="stable")]          # oldest first
        X = emb[vs]
        self._seeded = min(P, len(vs))
        s = self._seeded
        cent = X[:s].copy()
        a = np.zeros(len(vs), np.int64)
        sweeps = 2 if s == P else 1
        for _ in range(sweeps + 1):
            a = np.argmax(X @ cent.T, axis=1)
            csum = np.zeros((s, self._ep), np.float32)
            np.add.at(csum, a, X)
            cc = np.bincount(a, minlength=s)
            nz = cc > 0
            cent[nz] = csum[nz] / cc[nz, None]
            norms = np.linalg.norm(cent, axis=1)
            cent[norms > 0] /= norms[norms > 0, None]
        self._cent[:s] = cent
        self._csum[:s] = csum
        self._ccount[:s] = cc
        for c in range(s):
            ms = vs[a == c]                                  # oldest first
            if len(ms) > M:
                self.bucket_evictions += len(ms) - M
                ms = ms[-M:]
            self._members[c, :len(ms)] = ms
            self._mptr[c] = len(ms)
            self._assign[ms] = c

    def _refresh(self) -> None:
        """Lazy device-mirror upload: centroid plane(s) in padded kernel
        layout (per-shard subsets when sharded) + member/assign tables.
        O(P·Ep + P·M) once per index mutation, off the per-query path."""
        if not self._dirty:
            return
        P, Ep = self.clusters, self._ep
        live = self._ccount > 0
        if self._sharded:
            S = self.store.shards
            groups = [np.flatnonzero(np.arange(P) % S == s).astype(np.int32)
                      for s in range(S)]
        else:
            groups = [np.arange(P, dtype=np.int32)]
        planes = []
        for cid in groups:
            ps = len(cid)
            psp = padded_rows(ps)
            cent = np.zeros((psp, Ep), np.float32)
            cent[:ps] = self._cent[cid]
            cm = np.zeros((psp, 1), np.int32)
            cm[:ps, 0] = np.where(live[cid], MASK_VALID, 0)
            planes.append((jnp.asarray(cent), jnp.asarray(cm),
                           jnp.asarray(cid)))
        self._planes = tuple(planes)
        self._members_dev = jnp.asarray(self._members)
        self._assign_dev = jnp.asarray(self._assign)
        self._dirty = False

    # -- reads ----------------------------------------------------------
    def _geometry(self) -> tuple[int, int]:
        if self._sharded:
            return self.store.cs, self.store.csp
        return 0, 0

    def _check_topk(self, k: int) -> None:
        mem._check_k(k, self.capacity)
        budget = self.probes * self.bucket_cap
        if k > budget:
            raise ValueError(f"retrieval k={k} exceeds the probed "
                             f"candidate budget {budget} "
                             f"({self.probes} probes x {self.bucket_cap} "
                             f"bucket rows); raise probes or bucket_cap")

    def query_topk(self, emb: jax.Array, k: int,
                   guides_only: bool = False) -> mem.TopKResult:
        self._check_topk(k)
        self._refresh()
        if self.offload:
            return self._query_topk_tiered(emb, k, guides_only)
        self._qcount += 1
        cs, csp = self._geometry()
        st = self.store
        return _ivf_topk_jit(self._planes, self._members_dev,
                             self._assign_dev, st.emb, st.mask, st.hard,
                             st.added_at, st.guide, jnp.asarray(emb),
                             k=k, n_probe=self.probes,
                             required=mem.required_bits(guides_only),
                             cs=cs, csp=csp)

    def query_topk_batch(self, embs: jax.Array, k: int,
                         guides_only: bool = False,
                         _chunk: int = 8) -> mem.TopKResult:
        self._check_topk(k)
        self._refresh()
        cs, csp = self._geometry()
        st = self.store
        embs = jnp.asarray(embs)
        B = embs.shape[0]
        self._qcount += B
        outs = [_ivf_topk_batch_jit(self._planes, self._members_dev,
                                    self._assign_dev, st.emb, st.mask,
                                    st.hard, st.added_at, st.guide,
                                    embs[i:i + _chunk], k=k,
                                    n_probe=self.probes,
                                    required=mem.required_bits(guides_only),
                                    cs=cs, csp=csp)
                for i in range(0, B, _chunk)]
        if len(outs) == 1:
            return outs[0]
        return mem.TopKResult(sim=jnp.concatenate([o.sim for o in outs]),
                              meta=jnp.concatenate([o.meta for o in outs]))

    def query(self, emb: jax.Array,
              guides_only: bool = False) -> mem.QueryResult:
        r = self.query_topk(emb, 1, guides_only=guides_only)
        return mem.QueryResult(sim=r.sim[..., 0], meta=r.meta[..., 0, :])

    def query_batch(self, embs: jax.Array,
                    guides_only: bool = False) -> mem.QueryResult:
        r = self.query_topk_batch(embs, 1, guides_only=guides_only)
        return mem.QueryResult(sim=r.sim[..., 0], meta=r.meta[..., 0, :])

    def _query_topk_tiered(self, emb: jax.Array, k: int,
                           guides_only: bool) -> mem.TopKResult:
        """Offload read: route on device, sync the routed cluster ids
        (the one extra transfer the tiering costs), gather cold
        candidates from the host mirror and hot ones on-device."""
        q = jnp.asarray(emb)
        scores, cids = jax.device_get(
            _route_jit(self._planes, q, n_probe=self.probes))
        P, M, C = self.clusters, self.bucket_cap, self.capacity
        cids_c = np.clip(np.asarray(cids), 0, P - 1)
        live = np.asarray(scores) > -2.0
        # tier decision uses the state *before* this query's probes: a
        # cold cluster routed to now pays its host fetch this once, then
        # becomes hot for subsequent queries
        self._tier_hot = self._last_probe > (self._qcount -
                                             self.cold_after)
        self._last_probe[cids_c[live]] = self._qcount
        slots = self._members[cids_c].reshape(-1)
        owner = np.repeat(cids_c, M)
        ok = np.repeat(live, M) & (slots >= 0)
        ok &= self._assign[np.clip(slots, 0, C - 1)] == owner
        order = np.argsort(np.where(ok, slots, 2 ** 30), kind="stable")
        slots_s = slots[order]
        ok_s = ok[order]
        hot_s = ok_s & self._tier_hot[owner[order]]
        cold_s = ok_s & ~hot_s
        safe = np.clip(slots_s, 0, C - 1)
        host_rows = np.where(cold_s[:, None], self._emb_host[safe], 0.0)
        host_bits = np.where(cold_s, self._bits_host[safe], 0)
        self.host_fetch_rows += int(cold_s.sum())
        self.device_fetch_rows += int(hot_s.sum())
        self._qcount += 1
        cs, csp = self._geometry()
        st = self.store
        return _gather_topk_tiered_jit(
            st.emb, st.mask, st.hard, st.added_at, st.guide,
            jnp.asarray(slots_s, jnp.int32), jnp.asarray(hot_s),
            jnp.asarray(host_rows, jnp.float32),
            jnp.asarray(host_bits, jnp.int32), q, k=k,
            required=mem.required_bits(guides_only), cs=cs, csp=csp)

    # -- exact oracle ---------------------------------------------------
    def exact_query_topk(self, emb: jax.Array, k: int,
                         guides_only: bool = False) -> mem.TopKResult:
        """The exhaustive O(C) scan over the backing store — the recall
        oracle and fallback."""
        return mem.query_topk(self.store, emb, k, guides_only=guides_only)

    def exact_query_topk_batch(self, embs: jax.Array, k: int,
                               guides_only: bool = False) -> mem.TopKResult:
        return mem.query_topk_batch(self.store, embs, k,
                                    guides_only=guides_only)

    # -- writes ---------------------------------------------------------
    def add(self, emb, guide, has_guide, hard, now) -> None:
        self.add_batch(jnp.asarray(emb)[None], jnp.asarray(guide)[None],
                       jnp.asarray([has_guide]), jnp.asarray([hard]),
                       jnp.asarray([now], jnp.int32))

    def add_batch(self, embs, guides, has_guide, hard, now) -> None:
        K, C = embs.shape[0], self.capacity
        self.store = mem.add_batch(self.store, embs, guides, has_guide,
                                   hard, now)
        slots = (self._ptr_host + np.arange(K)) % C
        self._ptr_host += K
        # host copy of the committed rows (learn-path transfer, same
        # drain the store scatter runs on — never the serve path)
        X = np.asarray(jax.device_get(jnp.asarray(embs)), np.float32)
        if X.shape[1] < self._ep:
            X = np.pad(X, ((0, 0), (0, self._ep - X.shape[1])))
        if self.offload:
            hg = np.asarray(jax.device_get(jnp.asarray(has_guide)), bool)
            self._emb_host[slots] = X
            self._bits_host[slots] = np.where(hg, 3, 1)  # VALID|GUIDE
        self._ivf_add(X, slots)

    def mark_soft(self, index) -> None:
        self.store = mem.mark_soft(self.store, index)

    def touch(self, index, now) -> None:
        self.store = mem.touch(self.store, index, now)

    # -- grow-in-place --------------------------------------------------
    def grow(self, new_capacity: int):
        """Grow the backing store (:func:`repro.core.memory.grow_memory`)
        and re-bucket the clusters against the re-laid-out slots.
        Returns ``(self, remap)`` — the :meth:`CommitStream.grow`
        contract."""
        if self._sharded:
            raise NotImplementedError(
                "grow over a sharded backing store is not supported")
        self.store, remap = mem.grow_memory(self.store, new_capacity)
        self._ptr_host = int(jax.device_get(self.store.ptr))
        C = self.store.capacity
        self._assign = np.full(C, -1, np.int32)
        if self.offload:
            self._emb_host = np.zeros((C, self._ep), np.float32)
            self._bits_host = np.zeros(C, np.int32)
        self.reindex()
        return self, remap

    # -- introspection --------------------------------------------------
    def stats(self) -> dict:
        """Host-counter snapshot (no device syncs)."""
        out = {
            "clusters": self.clusters,
            "probes": self.probes,
            "bucket_cap": self.bucket_cap,
            "seeded": int(self._seeded),
            "indexed": int((self._assign >= 0).sum()),
            "bucket_evictions": self.bucket_evictions,
            "reindexes": self.reindexes,
            "queries": self._qcount,
        }
        if self.offload:
            out.update(hot_clusters=int(self._tier_hot.sum()),
                       cold_clusters=int((~self._tier_hot).sum()),
                       host_fetch_rows=self.host_fetch_rows,
                       device_fetch_rows=self.device_fetch_rows)
        return out


def wrap_store(store, cfg):
    """Apply a :class:`RARConfig`'s retrieval knobs to a freshly built
    (or injected) store: identity when IVF is off
    (``retrieval_clusters == 0``, the default) or the store is already
    wrapped — the construction sites (``RAR.__init__``, the serving
    fabrics) all route through here so a shared store is wrapped exactly
    once."""
    clusters = getattr(cfg, "retrieval_clusters", 0)
    if not clusters or isinstance(store, IVFMemory):
        return store
    return IVFMemory(store, clusters=clusters,
                     probes=getattr(cfg, "retrieval_probes", 4))
