"""Pure routing/case decision core — the paper's §III classification,
written exactly once.

Every RAR controller answers the same two questions:

1. **Routing** — given a request's top-k memory read and the static
   router, which serving path does it take?  :func:`classify` (one
   request) and :func:`partition` (a microbatch) produce the
   ``{memory_hard, memory_guide, memory_skill, router_weak, shadow}``
   groups from the packed :class:`repro.core.memory.TopKResult` fields.
2. **Shadow resolution** — given which probe stage of the shadow
   procedure first aligned, what gets recorded, which re-probe flags
   move, and what case the user's Outcome resolves to.
   :func:`resolve_shadow_case` covers Cases 1/2a/2b/3 (§III-D).

Before this module the answers were written three times — the sequential
``RAR.process``/``RAR._shadow`` pair and the batched
``MicrobatchRAR.process_batch``/``_drain_shadow`` pair — and every
replica-level feature would have meant a fourth copy.  Everything here is
pure and side-effect-free over host scalars/arrays: controllers own all
FM calls and store mutations, this module owns every decision, and the
replicated serving fabric (:mod:`repro.serving.fabric`) adds serve
replicas without touching any classification code.  The existing
byte-identity suites (B=1 ≡ sequential, deferred ≡ inline, top-1 pin)
hold because both controllers now literally execute the same functions.

Guide selection (:func:`select_guides`) and shadow coalescing
(:func:`coalesce_shadow_items`) live here too: both are pure ranking /
grouping rules over retrieval results, i.e. decisions about *what* to
serve or probe, not *how*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.data import tokenizer as tk

#: the five serving groups a classified request can land in
GROUPS = ("memory_hard", "memory_guide", "memory_skill", "router_weak",
          "shadow")

#: Outcome.case values of requests served in degraded (weak-only) mode —
#: the strong tier's breaker was open, so the strong serve / shadow probe
#: was suppressed and (for shadow) deferred for replay
DEGRADED_CASES = ("memory_hard_degraded", "shadow_deferred")

#: the shadow procedure's probe stages, in execution order; a request
#: resolves at the first stage whose weak answer aligns ("case3" = none)
SHADOW_STAGES = ("case1", "case2a", "case2b", "case3")


# ---------------------------------------------------------------------------
# Routing: request → serving group
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Route:
    """One request's routing decision. ``group`` ∈ :data:`GROUPS`;
    ``reprobe_index`` is set when a ``shadow`` route re-probes a hard
    entry past its cool-down (the entry whose flags the shadow pass may
    update). ``degraded`` marks a route whose strong-tier leg was
    suppressed because the strong tier is unavailable: a degraded
    ``memory_hard`` is served weak-only, a degraded ``shadow`` serves
    weak and defers its probe for replay."""
    group: str
    reprobe_index: int | None = None
    degraded: bool = False


def classify(sim: float, hard: bool, has_guide: bool, added_at: int,
             hit_index: int, now: int, cfg,
             route_weak: Callable[[], bool],
             strong_ok: bool = True) -> Route:
    """Classify one request from the top-1 fields of its memory read
    (entry 0 of the top-k result — bit-identical to the top-1 kernel).

    ``route_weak`` is the static router's verdict as a thunk: it is only
    evaluated on a memory miss, preserving the sequential controller's
    router call pattern (oracle routers may count calls).

    ``strong_ok`` is the strong tier's availability (its circuit
    breaker's non-mutating peek). When False, every route that would
    call the strong tier degrades instead of erroring: ``memory_hard``
    serves weak-only, hard re-probes stay ``memory_hard`` (degraded —
    no point probing an unavailable tier; the cool-down clock keeps
    running so the re-probe fires once the breaker closes), and shadow
    routes carry ``degraded=True`` so the controller serves weak and
    defers the strong probe. ``strong_ok=True`` is byte-identical to
    the pre-resilience classifier.
    """
    if sim >= cfg.sim_threshold:
        if hard:
            if now - added_at < cfg.reprobe_period:
                return Route("memory_hard", degraded=not strong_ok)
            if not strong_ok:
                return Route("memory_hard", degraded=True)
            # cool-down expired → shadow path re-probes the entry
            return Route("shadow", reprobe_index=hit_index)
        if has_guide:
            return Route("memory_guide")
        return Route("memory_skill")
    if route_weak():
        return Route("router_weak")
    return Route("shadow", degraded=not strong_ok)


@dataclasses.dataclass
class Partition:
    """A microbatch partitioned into the serving groups (request indices
    in batch order; ``shadow`` carries ``(index, reprobe_index | None)``).
    ``hard_degraded`` / ``deferred`` only populate in degraded mode
    (``strong_ok=False``): requests that would have gone to ``hard`` /
    ``shadow`` but are served weak-only instead, with ``deferred``
    probes parked for replay once the strong tier returns."""
    hard: list[int] = dataclasses.field(default_factory=list)
    guide: list[int] = dataclasses.field(default_factory=list)
    skill: list[int] = dataclasses.field(default_factory=list)
    router: list[int] = dataclasses.field(default_factory=list)
    shadow: list[tuple[int, int | None]] = dataclasses.field(
        default_factory=list)
    hard_degraded: list[int] = dataclasses.field(default_factory=list)
    deferred: list[tuple[int, int | None]] = dataclasses.field(
        default_factory=list)


def partition(q, nows: Sequence[int], cfg,
              route_weak: Callable[[int], bool],
              strong_ok: bool = True) -> Partition:
    """Partition a microbatch by its batched top-k read.

    ``q`` is the host-side :class:`~repro.core.memory.TopKResult` with
    leading (B, k) axes; ``nows[i]`` is request i's logical time;
    ``route_weak(i)`` is the static router's verdict for request i
    (evaluated lazily, only on memory misses). Request order is
    preserved inside every group, so downstream FM sweeps are
    deterministic. ``strong_ok=False`` routes the strong-dependent
    groups into ``hard_degraded`` / ``deferred`` instead (see
    :func:`classify`).
    """
    sims, hards = q.sim[:, 0], q.hard[:, 0]
    has_guides, added_ats = q.has_guide[:, 0], q.added_at[:, 0]
    hit_idxs = q.index[:, 0]
    part = Partition()
    for i in range(len(nows)):
        r = classify(float(sims[i]), bool(hards[i]), bool(has_guides[i]),
                     int(added_ats[i]), int(hit_idxs[i]), nows[i], cfg,
                     lambda: route_weak(i), strong_ok=strong_ok)
        if r.group == "memory_hard":
            (part.hard_degraded if r.degraded else part.hard).append(i)
        elif r.group == "memory_guide":
            part.guide.append(i)
        elif r.group == "memory_skill":
            part.skill.append(i)
        elif r.group == "router_weak":
            part.router.append(i)
        elif r.degraded:
            part.deferred.append((i, r.reprobe_index))
        else:
            part.shadow.append((i, r.reprobe_index))
    return part


# ---------------------------------------------------------------------------
# Shadow resolution: probe stage → store effects + Outcome case
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShadowResolution:
    """What a resolved shadow pass does for one request: the Outcome
    fields the user sees and the store effects the controller applies
    (insert / re-probe flag moves). Pure data — the controller decides
    *where* the writes land (direct store calls sequentially, the
    CommitBuffer on the batched drain)."""
    case: str                  # resolved Outcome.case
    guide_source: str | None   # "memory" | "fresh" | None
    record: bool               # insert a new memory entry
    has_guide: bool            # ... carrying the probe's guide block
    hard: bool                 # ... hard-flagged (Case 3)
    clear_hard: bool           # clear the re-probed entry's hard flag
    touch: bool                # refresh the re-probed entry's cool-down


def resolve_shadow_case(stage: str, reprobe: bool) -> ShadowResolution:
    """The single source of truth for Cases 1/2a/2b/3 (§III-D).

    ``stage`` ∈ :data:`SHADOW_STAGES` is the first probe stage whose weak
    answer aligned with the strong answer (``"case3"``: none did);
    ``reprobe`` says whether this shadow pass re-probes an existing hard
    entry (routing Case-3 follow-up) rather than a fresh memory miss.
    """
    if stage == "case1":       # weak alone aligned → bare skill entry
        return ShadowResolution(
            case="case1_reprobe" if reprobe else "case1", guide_source=None,
            record=True, has_guide=False, hard=False,
            clear_hard=reprobe, touch=False)
    if stage == "case2a":      # weak + memory guide(s) aligned
        return ShadowResolution(
            case="case2", guide_source="memory",
            record=True, has_guide=True, hard=False,
            clear_hard=reprobe, touch=False)
    if stage == "case2b":      # weak + fresh strong-FM guide aligned
        return ShadowResolution(
            case="case2", guide_source="fresh",
            record=True, has_guide=True, hard=False,
            clear_hard=reprobe, touch=False)
    if stage == "case3":       # weak failed even with guides
        return ShadowResolution(
            case="case3", guide_source=None,
            # a failed re-probe restarts the cool-down on the existing
            # entry instead of inserting a duplicate hard entry
            record=not reprobe, has_guide=False, hard=True,
            clear_hard=False, touch=reprobe)
    raise ValueError(f"shadow stage {stage!r} not in {SHADOW_STAGES}")


def wants_guide_probe(top_guide_sim: float, cfg) -> bool:
    """Case-2a gate: is the guide memory's best entry similar enough to
    probe the weak FM with retrieved guides?"""
    return top_guide_sim >= cfg.guide_sim_threshold


# ---------------------------------------------------------------------------
# Guide selection (with near-duplicate dedup before splicing)
# ---------------------------------------------------------------------------


def select_guides(sims, has_guide, guides, threshold: float,
                  max_guides: int) -> list[np.ndarray]:
    """Pick the guide blocks to splice from one (host) top-k result:
    entries above ``threshold`` that carry a guide, best-first, at most
    ``max_guides``.

    Near-duplicate guide blocks are skipped: the k retrieved entries can
    all come from one hot skill, and splicing the same guide text twice
    adds tokens without information. Two blocks are duplicates when their
    PAD-stripped token sequences are identical; the first (best-ranked)
    occurrence wins, so a duplicate never consumes a ``max_guides`` slot
    and the spliced context order stays deterministic — the retrieval
    order (sim desc, store row asc) minus exact repeats.
    """
    out: list[np.ndarray] = []
    seen: set[tuple[int, ...]] = set()
    for j in range(len(sims)):
        if len(out) >= max_guides:
            break
        if sims[j] >= threshold and bool(has_guide[j]):
            g = np.asarray(guides[j])
            key = tuple(int(t) for t in g[g != tk.PAD])
            if key in seen:
                continue
            seen.add(key)
            out.append(g)
    return out


# ---------------------------------------------------------------------------
# Shadow coalescing (intra-queue dedup before a drain epoch)
# ---------------------------------------------------------------------------


def coalesce_shadow_items(embs, dedup_sim: float) -> list[list[int]]:
    """Group pending shadow items whose embeddings are near-duplicates so
    one shadow pass resolves the whole group (the ROADMAP's
    dedup-as-a-coalescing-rule follow-up).

    Greedy in enqueue order: item j joins the first earlier group whose
    *leader* embedding has cosine ≥ ``dedup_sim`` with j's, else it
    founds its own group. Embeddings are the controller's L2-normalized
    request embeddings, so the dot product is the cosine. Returns groups
    as index lists; ``groups[g][0]`` is the leader, order is
    deterministic (leaders ascend, members ascend within a group), and
    the groups partition ``range(len(embs))`` exactly.
    """
    embs = np.asarray(embs, dtype=np.float32)
    groups: list[list[int]] = []
    leaders: list[int] = []
    for j in range(embs.shape[0]):
        placed = False
        for g, lead in enumerate(leaders):
            if float(embs[j] @ embs[lead]) >= dedup_sim:
                groups[g].append(j)
                placed = True
                break
        if not placed:
            groups.append([j])
            leaders.append(j)
    return groups
