"""Batched serving: prefill + greedy decode over the unified model API.

``ServingEngine`` maintains a jit cache keyed on (batch, prompt_len,
max_new) so repeated calls with uniform-shaped request batches (the common
case in the RAR evaluation loop: unguided / guided / guide-request prompts
each have a fixed length) hit compiled code.

``generate_bucketed`` extends this to mixed-length request groups (the
microbatched RAR controller mixes guided and unguided prompts in one
sweep): prompts are grouped by exact length — a causal LM cannot be
length-padded without shifting positions — and each group's batch dim is
padded up to a power-of-two bucket, so arbitrary traffic compiles at most
O(#lengths · log max_batch) variants instead of one per observed shape.

This is the same ``prefill`` / ``decode_step`` pair the multi-pod dry-run
lowers at production shapes — the engine is the single-host driver of it.
"""
from __future__ import annotations

import threading
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


def bucket_batch(n: int) -> int:
    """Smallest power of two ≥ n — the batch-dim bucket sizes."""
    b = 1
    while b < n:
        b *= 2
    return b


def greedy_generate(cfg: ModelConfig, params: Any, batch: dict,
                    max_new: int) -> jax.Array:
    """Greedy decode ``max_new`` tokens after the prompt.

    batch["tokens"]: (B, Lp) un-padded prompts (uniform length).
    Returns (B, max_new) int32.
    """
    tokens = batch["tokens"]
    B, Lp = tokens.shape
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = Lp + extra + max_new
    logits, cache, pos = prefill(cfg, params, batch, max_len)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(carry, _):
        tok, cache, pos = carry
        logits, cache = decode_step(cfg, params, tok, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, cache, pos + 1), tok

    (_, _, _), outs = jax.lax.scan(body, (tok, cache, pos),
                                   None, length=max_new)
    return jnp.moveaxis(outs, 0, 1)  # (B, max_new)


class ServingEngine:
    """Jit-cached greedy serving for one model."""

    def __init__(self, cfg: ModelConfig, params: Any):
        self.cfg = cfg
        self.params = params
        self._jitted: dict[tuple, Any] = {}
        self.calls = 0          # inference calls served (RAR cost metric)
        self.tokens_processed = 0
        self.jit_hits = 0       # generate() reused a compiled variant
        self.jit_misses = 0     # generate() traced + compiled a new one
        # the async shadow drainer serves sweeps on its own thread while
        # the serve plane keeps generating — the jit-cache dict and the
        # cost counters (non-atomic read-modify-writes) need a lock to
        # stay exact under that concurrency
        self._lock = threading.Lock()

    def _bill(self, calls: int, tokens: int) -> None:
        with self._lock:
            self.calls += calls
            self.tokens_processed += tokens

    def generate(self, batch: dict, max_new: int) -> jax.Array:
        tokens = batch["tokens"]
        key = (tokens.shape, max_new) + tuple(sorted(
            k for k in batch if k != "tokens"))
        with self._lock:
            fn = self._jitted.get(key)
            if fn is None:
                self.jit_misses += 1
                fn = self._jitted[key] = jax.jit(
                    partial(greedy_generate, self.cfg, max_new=max_new))
            else:
                self.jit_hits += 1
        out = fn(params=self.params, batch=batch)
        self._bill(tokens.shape[0], tokens.size + out.size)
        return out

    def generate_bucketed(self, prompts: Sequence[np.ndarray],
                          max_new: int) -> np.ndarray:
        """Serve a mixed-length prompt list in one sweep. Prompts are
        grouped by exact length; each group is padded along batch to the
        power-of-two bucket (dummy rows replicate the group's first
        prompt, their outputs are dropped and they are not billed as
        calls). ``calls`` stays logical (real requests only) while
        ``tokens_processed``/``flops_spent`` stay physical — padding rows
        do consume compute and are deliberately included there.
        Returns (N, max_new) int32 in input order."""
        by_len: dict[int, list[int]] = {}
        for i, p in enumerate(prompts):
            by_len.setdefault(len(p), []).append(i)
        out = np.zeros((len(prompts), max_new), np.int32)
        for L, idxs in sorted(by_len.items()):
            B = len(idxs)
            Bp = bucket_batch(B)
            batch = np.stack([np.asarray(prompts[i], np.int32)
                              for i in idxs] +
                             [np.asarray(prompts[idxs[0]], np.int32)] *
                             (Bp - B))
            got = np.asarray(self.generate({"tokens": jnp.asarray(batch)},
                                           max_new))
            self._bill(-(Bp - B), 0)      # padding rows are not requests
            out[idxs] = got[:B]
        return out

    @property
    def flops_spent(self) -> float:
        return self.tokens_processed * self.cfg.flops_per_token()

    def stats(self) -> dict:
        """Consistent host-side counter snapshot (one lock hold, no
        device syncs) — per-tier rows for the fabric's ``stats()`` and
        the throughput bench."""
        with self._lock:
            return {"calls": self.calls,
                    "tokens_processed": self.tokens_processed,
                    "flops_spent": self.flops_spent,
                    "jit_variants": len(self._jitted),
                    "jit_hits": self.jit_hits,
                    "jit_misses": self.jit_misses}

    # -- crash-recovery manifest hooks ----------------------------------
    def export_counters(self) -> dict:
        """The cost-accounting state (not the jit cache — compiled
        functions are rebuilt on demand) for the recovery manifest."""
        with self._lock:
            return {"calls": self.calls,
                    "tokens_processed": self.tokens_processed}

    def restore_counters(self, st: dict) -> None:
        with self._lock:
            self.calls = st["calls"]
            self.tokens_processed = st["tokens_processed"]
