"""Batched serving: prefill + greedy decode over the unified model API.

``ServingEngine`` maintains a jit cache keyed on (batch, prompt_len,
max_new) so repeated calls with uniform-shaped request batches (the common
case in the RAR evaluation loop: unguided / guided / guide-request prompts
each have a fixed length) hit compiled code.

This is the same ``prefill`` / ``decode_step`` pair the multi-pod dry-run
lowers at production shapes — the engine is the single-host driver of it.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


def greedy_generate(cfg: ModelConfig, params: Any, batch: dict,
                    max_new: int) -> jax.Array:
    """Greedy decode ``max_new`` tokens after the prompt.

    batch["tokens"]: (B, Lp) un-padded prompts (uniform length).
    Returns (B, max_new) int32.
    """
    tokens = batch["tokens"]
    B, Lp = tokens.shape
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = Lp + extra + max_new
    logits, cache, pos = prefill(cfg, params, batch, max_len)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(carry, _):
        tok, cache, pos = carry
        logits, cache = decode_step(cfg, params, tok, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, cache, pos + 1), tok

    (_, _, _), outs = jax.lax.scan(body, (tok, cache, pos),
                                   None, length=max_new)
    return jnp.moveaxis(outs, 0, 1)  # (B, max_new)


class ServingEngine:
    """Jit-cached greedy serving for one model."""

    def __init__(self, cfg: ModelConfig, params: Any):
        self.cfg = cfg
        self.params = params
        self._jitted: dict[tuple, Any] = {}
        self.calls = 0          # inference calls served (RAR cost metric)
        self.tokens_processed = 0

    def generate(self, batch: dict, max_new: int) -> jax.Array:
        tokens = batch["tokens"]
        key = (tokens.shape, max_new) + tuple(sorted(
            k for k in batch if k != "tokens"))
        if key not in self._jitted:
            self._jitted[key] = jax.jit(
                partial(greedy_generate, self.cfg, max_new=max_new))
        out = self._jitted[key](params=self.params, batch=batch)
        self.calls += tokens.shape[0]
        self.tokens_processed += tokens.size + out.size
        return out

    @property
    def flops_spent(self) -> float:
        return self.tokens_processed * self.cfg.flops_per_token()
