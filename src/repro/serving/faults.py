"""Deterministic fault-injection harness — the recovery plane's test rig.

Every failure mode the fault-tolerant fabric handles (replica crashes,
FM-tier call errors and latency spikes, shadow-drainer faults, crashes
around the commit journal's write-ahead/apply boundary) is driven from
one seedable :class:`FaultPlan`, so each scenario is *reproducible*: the
same plan against the same request stream fires the same faults at the
same logical points, run after run. With no plan installed (the default
everywhere) every injection site is a no-op and the system is
byte-identical to the pre-fault-tolerance code paths — the property the
equivalence suites pin.

Injection sites (the ``site`` string each component fires)
----------------------------------------------------------
* ``"replica_serve"`` — fired by a fabric worker as it picks up a
  microbatch, *before* any side effect (clock advance, FM call, store
  read). A matching ``"crash"`` spec raises :class:`ReplicaCrash`: the
  worker thread exits, modeling a dead worker process whose queued RPC
  was never executed — which is what makes supervised redispatch exactly
  outcome-preserving. Ids: ``replica`` (index).
* ``"tier_call"`` — fired by :class:`repro.core.fm.ResilientTier` before
  each underlying FM call. ``"error"`` raises
  :class:`InjectedTierError` (a transient, retryable failure);
  ``"delay"`` injects a latency spike of ``delay`` seconds — if the
  caller passes its cooperative ``timeout`` and the spike exceeds it,
  :class:`repro.core.fm.TierTimeout` is raised instead of sleeping, so
  timeout tests never actually wait. Ids: ``tier`` ("weak"/"strong"),
  ``op`` (method name).
* ``"drain"`` — fired by the shadow queue at the start of a drain.
  ``"error"`` raises :class:`InjectedFault` (surfaced at the next
  barrier, exactly like a real drainer exception). Ids: none.
* ``"wal_write"`` — fired by :class:`repro.core.memory.MemoryJournal`
  *before* an epoch's write-ahead record is made durable. A ``"crash"``
  models losing power before the commit hit disk: recovery restores the
  previous epoch. Ids: ``epoch``.
* ``"commit_apply"`` — fired by :class:`repro.core.memory.CommitStream`
  *after* the WAL record is durable but *before* the in-memory apply. A
  ``"crash"`` models dying mid-epoch with the commit already journaled:
  recovery replays the epoch and lands exactly one epoch *ahead* of the
  crashed process's memory — consistent either way. Ids: ``epoch``.
* ``"heartbeat"`` — fired by a process worker's heartbeat thread before
  each lease beat. ``"crash"`` kills the heartbeat thread (the worker
  keeps serving but its lease expires — a *hung-looking* worker, the
  case SIGKILL detection alone cannot cover); ``"delay"`` makes it miss
  beats. Ids: ``replica``.
* ``"transport_frame"`` — fired by
  :class:`repro.serving.transport.FramedChannel` before each send.
  ``"delay"`` injects wire latency; ``"crash"`` raises on the sending
  end mid-conversation. Ids: ``end`` ("parent"/"worker"), ``replica``.
* ``"clock_skew"`` — sampled (not fired) by the supervision plane's
  lease monitor via :meth:`FaultPlan.take_skew`: due ``"delay"`` specs
  *advance the monitor's view of time* instead of sleeping, so lease
  expiry under clock skew is testable without wall-clock waits.

Actions: alongside ``"crash"``/``"error"``/``"delay"``, ``"kill"``
SIGKILLs the **calling process** (``os.kill(os.getpid(), SIGKILL)``) —
no exception propagation, no cleanup, no atexit. Meaningful inside a
process-per-replica worker, where it models the hard machine-level
death the lease/EOF supervision plane exists to detect. In-process
callers should prefer ``"crash"``.

Matching: a spec fires when its ``site`` matches and every key of
``spec.match`` equals the id the site fired with. Each spec keeps its own
hit counter over *matching* events; it acts on hits ``at .. at+count-1``
(1-based), so "crash replica 1's third microbatch" is
``FaultSpec("replica_serve", "crash", {"replica": 1}, at=3)``.

:func:`random_plan` draws a reproducible random schedule from a seed —
the soak test's crash/recover schedule generator.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

#: the sites components fire, and what actions make sense at each
SITES = ("replica_serve", "tier_call", "drain", "wal_write",
         "commit_apply", "heartbeat", "transport_frame", "clock_skew")
ACTIONS = ("crash", "error", "delay", "kill")


class InjectedFault(RuntimeError):
    """Base of every exception raised by a :class:`FaultPlan`."""


class ReplicaCrash(InjectedFault):
    """A fabric worker died before executing its queued microbatch. The
    supervisor treats this (and only this) as redispatchable: the batch
    had no side effects yet, so re-running it elsewhere is exact."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: fire ``action`` at matching events number
    ``at .. at + count - 1`` (1-based) of ``site``."""
    site: str
    action: str
    match: tuple = ()          # ((key, value), ...) — ids that must match
    at: int = 1
    count: int = 1
    delay: float = 0.0         # seconds, for action="delay"

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"fault site {self.site!r} not in {SITES}")
        if self.action not in ACTIONS:
            raise ValueError(f"fault action {self.action!r} not in "
                             f"{ACTIONS}")
        if self.at < 1 or self.count < 1:
            raise ValueError(f"at={self.at}/count={self.count} must be "
                             f">= 1 (hit numbers are 1-based)")
        object.__setattr__(self, "match", tuple(sorted(
            dict(self.match).items())))

    def matches(self, site: str, ids: dict) -> bool:
        return site == self.site and all(
            k in ids and ids[k] == v for k, v in self.match)


class FaultPlan:
    """A deterministic schedule of injected faults (see module doc).

    Thread-safe: fabric workers, the shadow drainer and the serve thread
    may all fire concurrently; per-spec hit counters are kept under one
    lock so a spec fires exactly ``count`` times no matter which thread
    reaches it. ``fired`` records every fault actually raised/injected
    (site, action, ids) in firing order — the reproducibility probe the
    tests assert on.
    """

    def __init__(self, specs: list[FaultSpec] | tuple = (),
                 sleep_fn=time.sleep):
        self.specs = list(specs)
        self._hits = [0] * len(self.specs)
        self._lock = threading.Lock()
        self._sleep = sleep_fn
        self.fired: list[tuple[str, str, tuple]] = []

    # Plans cross the process boundary (each fabric worker carries its
    # own copy, with independent hit counters from the pickling point
    # on). Locks and bound sleep functions don't pickle — rebuild them.
    def __getstate__(self) -> dict:
        with self._lock:
            return {"specs": self.specs, "_hits": list(self._hits),
                    "fired": list(self.fired)}

    def __setstate__(self, state: dict) -> None:
        self.specs = state["specs"]
        self._hits = state["_hits"]
        self.fired = state["fired"]
        self._lock = threading.Lock()
        self._sleep = time.sleep

    # -- plan construction helpers --------------------------------------
    @staticmethod
    def replica_crash(replica: int, at: int = 1,
                      count: int = 1) -> FaultSpec:
        return FaultSpec("replica_serve", "crash",
                         (("replica", replica),), at=at, count=count)

    @staticmethod
    def tier_error(tier: str, at: int = 1, count: int = 1) -> FaultSpec:
        return FaultSpec("tier_call", "error", (("tier", tier),), at=at,
                         count=count)

    @staticmethod
    def tier_delay(tier: str, delay: float, at: int = 1,
                   count: int = 1) -> FaultSpec:
        return FaultSpec("tier_call", "delay", (("tier", tier),), at=at,
                         count=count, delay=delay)

    @staticmethod
    def drain_error(at: int = 1, count: int = 1) -> FaultSpec:
        return FaultSpec("drain", "error", at=at, count=count)

    @staticmethod
    def wal_crash(at: int = 1) -> FaultSpec:
        """Die before epoch number ``at``'s WAL record is durable."""
        return FaultSpec("wal_write", "crash", at=at)

    @staticmethod
    def apply_crash(at: int = 1) -> FaultSpec:
        """Die after epoch number ``at``'s WAL record, mid-apply."""
        return FaultSpec("commit_apply", "crash", at=at)

    @staticmethod
    def replica_kill(replica: int, at: int = 1) -> FaultSpec:
        """SIGKILL the worker *process* as it picks up its ``at``-th
        microbatch — the hard-death analog of :meth:`replica_crash`."""
        return FaultSpec("replica_serve", "kill",
                         (("replica", replica),), at=at)

    @staticmethod
    def heartbeat_crash(replica: int, at: int = 1) -> FaultSpec:
        """Kill a worker's heartbeat thread at its ``at``-th beat: the
        worker hangs from the lease monitor's point of view."""
        return FaultSpec("heartbeat", "crash", (("replica", replica),),
                         at=at)

    @staticmethod
    def transport_delay(delay: float, at: int = 1, count: int = 1,
                        end: str | None = None,
                        replica: int | None = None) -> FaultSpec:
        """Wire-latency spike on frame sends (optionally one end / one
        replica's channel only)."""
        match = []
        if end is not None:
            match.append(("end", end))
        if replica is not None:
            match.append(("replica", replica))
        return FaultSpec("transport_frame", "delay", tuple(match),
                         at=at, count=count, delay=delay)

    @staticmethod
    def clock_skew(skew: float, at: int = 1, count: int = 1) -> FaultSpec:
        """Advance the lease monitor's clock by ``skew`` seconds at its
        ``at``-th sample (see :meth:`take_skew`)."""
        return FaultSpec("clock_skew", "delay", at=at, count=count,
                         delay=skew)

    # -- firing ---------------------------------------------------------
    def fire(self, site: str, timeout: float | None = None,
             **ids) -> None:
        """Called by an instrumented component at one of its injection
        sites. Raises / sleeps according to the first matching due spec;
        a site with no matching due spec is a no-op."""
        due: FaultSpec | None = None
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.matches(site, ids):
                    self._hits[i] += 1
                    if due is None and \
                            spec.at <= self._hits[i] < spec.at + spec.count:
                        due = spec
            if due is not None:
                self.fired.append((site, due.action,
                                   tuple(sorted(ids.items()))))
        if due is None:
            return
        if due.action == "kill":
            # hard machine-level death: no exception, no cleanup. The
            # fired record above lives only in this process's copy of
            # the plan and dies with it — the *supervisor's* counters
            # (deaths/restarts) are what tests assert on.
            import os
            import signal
            os.kill(os.getpid(), signal.SIGKILL)
        if due.action == "crash":
            if site == "replica_serve":
                raise ReplicaCrash(f"injected crash at {site} {ids}")
            raise InjectedFault(f"injected crash at {site} {ids}")
        if due.action == "error":
            if site == "tier_call":
                from repro.core.fm import InjectedTierError
                raise InjectedTierError(
                    f"injected tier error at {site} {ids}")
            raise InjectedFault(f"injected error at {site} {ids}")
        # action == "delay": a latency spike. Cooperative timeout: a
        # caller with a deadline shorter than the spike times out
        # immediately instead of sleeping it through.
        if timeout is not None and due.delay > timeout:
            from repro.core.fm import TierTimeout
            raise TierTimeout(
                f"injected {due.delay}s latency spike exceeds the "
                f"{timeout}s call timeout at {site} {ids}")
        if due.delay:
            self._sleep(due.delay)

    def take_skew(self, site: str = "clock_skew", **ids) -> float:
        """Sum of due ``"delay"`` spec delays at ``site`` for this
        sample, *without sleeping* — the lease monitor adds the result
        to its monotonic clock, so injected skew perturbs lease math
        deterministically instead of stalling the monitor thread. Every
        matching spec's hit counter advances, and due specs are
        recorded in ``fired`` like any other injection."""
        total = 0.0
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.action == "delay" and spec.matches(site, ids) \
                        and spec.delay:
                    self._hits[i] += 1
                    if spec.at <= self._hits[i] < spec.at + spec.count:
                        total += spec.delay
                        self.fired.append((site, "delay",
                                           tuple(sorted(ids.items()))))
        return total

    # -- inspection -----------------------------------------------------
    @property
    def n_fired(self) -> int:
        with self._lock:
            return len(self.fired)

    def stats(self) -> dict:
        with self._lock:
            by_site: dict[str, int] = {}
            for site, _, _ in self.fired:
                by_site[site] = by_site.get(site, 0) + 1
            return {"specs": len(self.specs), "fired": len(self.fired),
                    "fired_by_site": by_site}


def random_plan(seed: int, *, replicas: int = 0, crashes: int = 0,
                tier_errors: int = 0, drain_errors: int = 0,
                wal_crashes: int = 0, apply_crashes: int = 0,
                kills: int = 0, transport_delays: int = 0,
                clock_skews: int = 0, max_jitter: float = 0.05,
                horizon: int = 50, tiers=("strong",)) -> FaultPlan:
    """A reproducible random fault schedule — the soak test's
    crash/recover generator. Draws fault positions in ``[1, horizon]``
    from a seeded generator; the same seed always yields the same plan
    (and therefore, against a deterministic stream, the same run).

    Beyond crashes/brownouts, the schedule can now cover the journal's
    kill points (``wal_crashes``/``apply_crashes``), process-level
    SIGKILLs (``kills``), and timing perturbation: seeded wire-latency
    jitter (``transport_delays``) and lease-monitor clock skew
    (``clock_skews``), each spike drawn in ``(0, max_jitter]``."""
    rng = np.random.default_rng(seed)
    specs: list[FaultSpec] = []
    for _ in range(crashes):
        specs.append(FaultPlan.replica_crash(
            int(rng.integers(0, max(replicas, 1))),
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(tier_errors):
        specs.append(FaultPlan.tier_error(
            str(rng.choice(list(tiers))),
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(drain_errors):
        specs.append(FaultPlan.drain_error(
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(wal_crashes):
        specs.append(FaultPlan.wal_crash(
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(apply_crashes):
        specs.append(FaultPlan.apply_crash(
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(kills):
        specs.append(FaultPlan.replica_kill(
            int(rng.integers(0, max(replicas, 1))),
            at=int(rng.integers(1, horizon + 1))))
    for _ in range(transport_delays):
        specs.append(FaultPlan.transport_delay(
            float(rng.uniform(0.0, max_jitter)) or max_jitter,
            at=int(rng.integers(1, horizon + 1)),
            count=int(rng.integers(1, 4))))
    for _ in range(clock_skews):
        specs.append(FaultPlan.clock_skew(
            float(rng.uniform(0.0, max_jitter)) or max_jitter,
            at=int(rng.integers(1, horizon + 1)),
            count=int(rng.integers(1, 4))))
    return FaultPlan(specs)
