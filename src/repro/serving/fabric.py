"""Replicated serving fabric — a request router/dispatcher over N serve
replicas and one learn plane (the ROADMAP's multi-host serving unit).

Topology
--------
The per-host serving unit of the data-plane PRs — bucketed engine +
:class:`repro.core.pipeline.MicrobatchRAR` — becomes the **replica**; the
fabric composes N of them behind one admission point:

* **Serve plane** — N replicas, each a ``MicrobatchRAR`` with its own
  worker thread (thread-per-replica models multi-host placement; a real
  multi-process transport slots in at the :meth:`ServingFabric.submit`
  boundary). Microbatches dispatch round-robin (or to an explicit
  replica) and serve concurrently; per-replica FIFO order is preserved.
* **Learn plane** — a **single learn replica owns every shadow drain**:
  each replica's :class:`~repro.core.shadow.ShadowQueue` keeps its own
  enqueue/drain schedule (inline / deferred / async per
  ``RARConfig.shadow_mode``), but all runners funnel into
  :meth:`ServingFabric._drain`, which serializes the drains and executes
  them on the learn replica.
* **Commit stream** — one shared
  :class:`repro.core.memory.CommitStream`: every drain stages into the
  same epoch-versioned ``CommitBuffer``, applies under the one store
  lock, and the applied store is **broadcast to every replica's view**
  in the same atomic step — a serve replica always reads a whole number
  of drain epochs, and the host-side commit counter has a single owner
  (``memory_occupancy`` stays exact at any replica count).

Shared logical clock: request timestamps must stay unique across
replicas (the ``CommitBuffer`` keys staged ops by them), so replicas
draw from one thread-safe counter instead of their private ``now``.

Equivalence anchor: with ``replicas=1`` the synchronous
:meth:`ServingFabric.process_batch` runs the identical code path as
calling ``MicrobatchRAR.process_batch`` directly — same decision core,
same drain schedule, same commit stream mechanics — and is pinned
**byte-identical** to it in ``tests/test_fabric.py`` (Outcome stream,
memory state, FM-call counts, RQ2 counters). That is the machine-
checkable base the N-replica threaded mode is built on.

Recovery plane (fault tolerance)
--------------------------------
* **Replica supervision** — every replica carries a health state
  (``healthy`` / ``suspect`` / ``dead``). A worker that dies with a
  :class:`repro.serving.faults.ReplicaCrash` (fired *before* any side
  effect of its microbatch) is marked dead, restarted against the shared
  commit-stream view, and the failed ticket's microbatch is
  **redispatched** to a surviving replica — bounded by
  ``RARConfig.max_redispatch``, after which the :class:`Ticket` surfaces
  the error exactly as an unsupervised failure would. Because the crash
  precedes the clock advance and every FM call, the redispatched run is
  *byte-identical* to a no-fault run (pinned in ``tests/test_faults.py``).
  Application exceptions (anything that is not a ``ReplicaCrash``) still
  surface on the ticket without redispatch: re-running a batch whose
  side effects already landed would double-serve it. ``suspect`` marks a
  replica whose last batch served degraded (strong tier shed) — cleared
  by the next clean serve.
* **Tier resilience** — with any ``RARConfig`` resilience knob on, the
  fabric wraps the tiers in one shared
  :class:`repro.core.fm.ResilientTier` (single breaker across replicas:
  an outage observed by one replica degrades routing on all of them).
* **Crash-consistent memory** — ``RARConfig.journal_path`` attaches a
  write-ahead :class:`repro.core.memory.MemoryJournal` to the shared
  commit stream; on construction the fabric recovers the pre-crash
  store byte-identically.
* **Bounded barriers** — :meth:`join` / :meth:`flush_shadow` take an
  optional ``timeout`` (matching :meth:`Ticket.wait`): on expiry the
  un-served tickets stay registered and a :class:`TimeoutError` is
  raised instead of blocking forever on a wedged replica.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import sys
import threading
import time

from repro.core import decisions
from repro.core import memory as mem
from repro.core.fm import ResilientTier
from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import Outcome, RARConfig, retry_policy
from repro.core.shadow import AdaptiveDrainPolicy
from repro.serving.faults import ReplicaCrash
from repro.serving.metrics import MetricsRegistry

#: replica health states (supervision). ``retired`` is terminal for a
#: slot: an autoscale-down drained its queue and stopped its worker;
#: dispatch skips it and its health is never overwritten.
HEALTH = ("healthy", "suspect", "dead", "retired")


class _SharedClock:
    """Thread-safe logical-time allocator shared by all replicas."""

    def __init__(self):
        self._now = 0
        self._lock = threading.Lock()

    def advance(self, n: int) -> list[int]:
        with self._lock:
            base = self._now
            self._now = base + n
        return list(range(base + 1, base + n + 1))

    def restore(self, now: int) -> None:
        """Resume logical time after a crash recovery (manifest)."""
        with self._lock:
            self._now = int(now)

    @property
    def now(self) -> int:
        return self._now


class _FabricReplica(MicrobatchRAR):
    """One serve replica: a ``MicrobatchRAR`` wired into the fabric's
    shared pieces — the commit stream (store views + single counter),
    the logical clock, and the learn-replica drain."""

    def __init__(self, fabric: "ServingFabric", index: int, *args,
                 **kwargs):
        self._fabric = fabric
        self.index = index
        super().__init__(*args, **kwargs)

    def _advance_now(self, n: int) -> list[int]:
        nows = self._fabric.clock.advance(n)
        self.now = nows[-1]               # diagnostic mirror
        return nows

    def _shadow_runner(self):
        # per-replica queue (own drain schedule + stats), but the runner
        # funnels into the fabric so the single learn replica executes
        # every drain against the shared commit stream
        return self._fabric._drain

    def _metrics_registry(self):
        # ONE fabric-wide registry: every replica's queue mirrors into
        # it under a per-replica prefix, so a single snapshot covers the
        # whole fabric consistently
        return self._fabric.metrics_registry

    def _metrics_prefix(self) -> str:
        return f"replica{self.index}/shadow/"

    def _drain_policy(self):
        # in adaptive mode the fabric shares ONE policy across all
        # replicas' queues — a drain decision sees the global pending
        # set and flushes the whole group (None for the other modes)
        return self._fabric.drain_policy


@dataclasses.dataclass
class Ticket:
    """Handle for one dispatched microbatch: resolves to the Outcome list
    once the owning replica's serve sweep completes (shadow outcomes may
    still be provisional until a :meth:`ServingFabric.flush_shadow`
    barrier, exactly as with a standalone ``MicrobatchRAR``).

    ``redispatches`` counts supervisor re-runs after a replica crash
    (``replica`` is rewritten to the surviving replica each time); a
    timed-out :meth:`wait` leaves the ticket fully waitable — the batch
    is still in flight, not abandoned."""
    replica: int
    outcomes: list[Outcome] | None = None
    error: BaseException | None = None
    redispatches: int = 0
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def wait(self, timeout: float | None = None) -> list[Outcome]:
        if not self._done.wait(timeout):
            raise TimeoutError("microbatch still in flight")
        if self.error is not None:
            raise RuntimeError(
                f"serve replica {self.replica} failed") from self.error
        return self.outcomes


class ServingFabric:
    """Admit → dispatch → serve across N replicas; learn on one."""

    def __init__(self, weak, strong, embed_fn, route_weak_fn,
                 cfg: RARConfig | None = None, *, replicas: int = 1,
                 memory=None, aligned_fn=None, fault_plan=None):
        if replicas < 1:
            raise ValueError(f"replicas={replicas} must be >= 1")
        cfg = cfg if cfg is not None else RARConfig()
        self.cfg = cfg
        self.fault_plan = fault_plan
        # crash-consistent memory: a journal_path attaches a WAL +
        # snapshot journal to the shared stream and recovers the
        # pre-crash store before any replica is built
        recovered, manifest = None, None
        if cfg.journal_path is not None:
            self.commit_stream, recovered, manifest = \
                mem.open_journaled_stream(
                    cfg.journal_path, cfg.memory,
                    snapshot_every=cfg.snapshot_every,
                    fault_plan=fault_plan)
        else:
            self.commit_stream = mem.CommitStream(fault_plan=fault_plan)
        # tier resilience is fabric-level: ONE shared wrapper (and
        # breaker) across replicas, so an outage seen by any replica
        # degrades routing on all of them. RAR.__init__'s isinstance
        # check makes replica construction a no-op re-wrap.
        if cfg.tier_resilience:
            policy = retry_policy(cfg)
            if not isinstance(weak, ResilientTier):
                weak = ResilientTier(weak, policy, name="weak",
                                     fault_plan=fault_plan, seed=1)
            if not isinstance(strong, ResilientTier):
                strong = ResilientTier(strong, policy, name="strong",
                                       fault_plan=fault_plan, seed=2)
        self.clock = _SharedClock()
        self._drain_lock = threading.Lock()
        # metrics plane: one registry for the whole fabric — replicas'
        # shadow queues mirror into it (per-replica prefixes), the
        # commit stream bumps its epoch counters, and ``metrics()``
        # snapshots everything consistently
        self.metrics_registry = MetricsRegistry()
        self.commit_stream.metrics = self.metrics_registry
        # global adaptive cadence: one shared policy across every
        # replica's queue (None unless shadow_mode == "adaptive")
        self.drain_policy = (AdaptiveDrainPolicy()
                             if cfg.shadow_mode == "adaptive" else None)
        # one store, N views: the functional MemoryState is shared by
        # reference and re-broadcast on every commit apply; a mutable
        # ShardedMemory is the same object in every view, made
        # reader-atomic by the stream's lock
        if memory is not None:
            store = memory
        elif recovered is not None:
            store = recovered
        else:
            store = mem.init_memory(cfg.memory)
        # two-level retrieval: wrap the shared store in the IVF plane
        # ONCE, before the replicas are built — every replica's
        # controller then shares the same index (``wrap_store`` is
        # idempotent, so the per-replica RAR wrap is a no-op)
        if cfg.retrieval_clusters:
            from repro.core.memory_ivf import wrap_store
            store = wrap_store(store, cfg)
        # construction args kept (post-ResilientTier-wrap) so the
        # autoscaler can spawn additional replicas sharing the exact
        # same tiers/breaker/commit stream
        self._replica_args = (weak, strong, embed_fn, route_weak_fn)
        self._aligned_fn = aligned_fn
        self.replicas = [
            _FabricReplica(self, i, weak, strong, embed_fn, route_weak_fn,
                           cfg, aligned_fn=aligned_fn, memory=store,
                           commit_stream=self.commit_stream,
                           fault_plan=fault_plan)
            for i in range(replicas)]
        #: the learn replica: owns every shadow drain (and therefore the
        #: RQ2 guide counters)
        self.learn = self.replicas[0]
        self._rr = 0
        self._dispatch_lock = threading.Lock()
        self._queues: list[_queue.Queue] | None = None
        # indexed parallel to ``replicas`` so a supervisor restart
        # replaces exactly its slot
        self._threads: list[threading.Thread | None] = []
        self._tickets: list[Ticket] = []
        #: supervision state, one entry per replica (∈ :data:`HEALTH`)
        self.health: list[str] = ["healthy"] * replicas
        self.deaths = 0        # worker threads lost to a ReplicaCrash
        self.restarts = 0      # supervisor restarts
        self.redispatches = 0  # microbatches re-run on a survivor
        # autoscaling (policy callable, no-op default): maps a metrics
        # snapshot to a target active-replica count; ``autoscale()``
        # applies it behind a health gate
        self.autoscale_policy = None
        self.autoscale_ticks = 0   # supervisor ticks that ran autoscale()
        self._autoscale_thread: threading.Thread | None = None
        self._autoscale_stop = threading.Event()
        self.spawned = 0       # replicas added by scale-up
        self.retired = 0       # replicas retired by scale-down
        # full-state crash consistency: the fabric-wide engine state
        # (shared clock, learn-plane counters, parked deferred probes,
        # shared breaker/engine counters) rides inside every journaled
        # WAL epoch as the recovery manifest; a rebuilt fabric on the
        # same journal path resumes serving byte-identically to an
        # unkilled one (pinned in the fault/procfabric suites)
        if self.commit_stream.journal is not None:
            self.commit_stream.state_provider = self._manifest_state
            if manifest is not None:
                self._restore_manifest(manifest)

    # -- full-state crash consistency (recovery manifest) ----------------
    def _manifest_state(self) -> dict:
        """Fabric-wide engine state journaled with every WAL epoch
        (called by the commit stream under its lock). Counters are the
        fabric-level aggregates; restore re-homes them on the learn
        replica (which owns every drain), so the aggregate views are
        exact after recovery."""
        man = {"now": self.clock.now,
               "guides_from_memory": self.guides_from_memory,
               "guides_generated": self.guides_generated,
               "probes_deferred": sum(r.probes_deferred
                                      for r in self.replicas),
               "probes_replayed": sum(r.probes_replayed
                                      for r in self.replicas),
               "deferred_probes": [it for r in self.replicas
                                   for it in r.deferred_probes],
               "tiers": {}, "engines": {}}
        for name, tier in (("weak", self.learn.weak),
                           ("strong", self.learn.strong)):
            if isinstance(tier, ResilientTier):
                man["tiers"][name] = tier.export_state()
            engine = getattr(tier, "engine", None)
            if hasattr(engine, "export_counters"):
                man["engines"][name] = engine.export_counters()
        return man

    def _restore_manifest(self, man: dict) -> None:
        self.clock.restore(man["now"])
        learn = self.learn
        learn.now = man["now"]
        learn.guides_from_memory = man["guides_from_memory"]
        learn.guides_generated = man["guides_generated"]
        learn.probes_deferred = man["probes_deferred"]
        learn.probes_replayed = man["probes_replayed"]
        learn.deferred_probes = list(man["deferred_probes"])
        for name, tier in (("weak", learn.weak),
                           ("strong", learn.strong)):
            if isinstance(tier, ResilientTier) and \
                    name in man.get("tiers", {}):
                tier.restore_state(man["tiers"][name])
            engine = getattr(tier, "engine", None)
            if hasattr(engine, "restore_counters") and \
                    name in man.get("engines", {}):
                engine.restore_counters(man["engines"][name])

    # -- learn plane ----------------------------------------------------
    def _drain(self, items) -> None:
        """Every replica queue's runner: serialize drains and execute
        them on the learn replica. The commit stream broadcasts the
        applied store to every replica view, so a drain triggered by any
        replica updates all of them atomically."""
        with self._drain_lock:
            self.learn._drain_shadow(items)

    # -- synchronous dispatch -------------------------------------------
    def _pick(self, replica: int | None) -> _FabricReplica:
        if replica is not None:
            return self.replicas[replica]
        with self._dispatch_lock:
            for _ in range(len(self.replicas)):
                i = self._rr % len(self.replicas)
                self._rr += 1
                if self.health[i] != "retired":
                    return self.replicas[i]
            return self.learn        # replica 0 never retires

    def process_batch(self, prompts, guide_requests, keys=None, embs=None,
                      replica: int | None = None) -> list[Outcome]:
        """Serve one microbatch synchronously on the caller's thread
        through one replica (round-robin by default). With ``replicas=1``
        this is bit-identical to calling
        ``MicrobatchRAR.process_batch`` directly (pinned in
        ``tests/test_fabric.py``)."""
        return self._pick(replica).process_batch(prompts, guide_requests,
                                                 keys=keys, embs=embs)

    # -- threaded dispatch ----------------------------------------------
    def _ensure_workers(self) -> None:
        # check-and-create under the dispatch lock: concurrent first
        # submits must not spawn duplicate worker sets (orphaned queues
        # would never receive the shutdown sentinel)
        with self._dispatch_lock:
            if self._queues is not None:
                return
            queues = [_queue.Queue() for _ in self.replicas]
            self._queues = queues
            self._threads = [None] * len(self.replicas)
            for i in range(len(self.replicas)):
                self._spawn_worker_locked(i)

    def _spawn_worker_locked(self, i: int) -> None:
        t = threading.Thread(target=self._worker, args=(i,),
                             name=f"serve-replica-{i}", daemon=True)
        self._threads[i] = t
        t.start()

    def _worker(self, i: int) -> None:
        q = self._queues[i]
        while True:
            task = q.get()
            if task is None:
                return
            ticket = task[0]
            try:
                if self.fault_plan is not None:
                    # the injection point is BEFORE the replica touches
                    # the batch — no clock advance, no FM call, no store
                    # write has happened — so a redispatched re-run is
                    # byte-identical to a no-fault run
                    self.fault_plan.fire("replica_serve", replica=i)
                ticket.outcomes = self.replicas[i].process_batch(
                    task[1], task[2], keys=task[3], embs=task[4])
            except ReplicaCrash as e:
                # worker dies; the supervisor restarts the slot and
                # redispatches the (side-effect-free) microbatch
                self._on_replica_crash(i, task, e)
                return
            except BaseException as e:    # surfaced at wait()/join();
                ticket.error = e          # NOT redispatched — the batch's
                ticket._done.set()        # side effects may have landed
                continue
            # supervision bookkeeping: a batch served entirely weak-only
            # because the strong tier shed marks the replica suspect
            # (strong plane impaired), a clean serve clears it. A slot
            # retired mid-flight keeps its terminal state while it
            # drains the rest of its FIFO.
            degraded = any(o.case in decisions.DEGRADED_CASES
                           for o in ticket.outcomes)
            if self.health[i] != "retired":
                self.health[i] = "suspect" if degraded else "healthy"
            ticket._done.set()

    # -- supervision -----------------------------------------------------
    def _on_replica_crash(self, i: int, task, err: BaseException) -> None:
        """Supervisor: the worker for replica ``i`` died mid-dispatch.
        Mark it dead, restart the slot against the shared commit-stream
        view (its queue — and FIFO order — survives intact), and
        redispatch the failed microbatch to a surviving replica, bounded
        by ``cfg.max_redispatch`` re-runs per ticket."""
        ticket = task[0]
        with self._dispatch_lock:
            self.health[i] = "dead"
            self.deaths += 1
            self._restart_locked(i)
            if ticket.redispatches < self.cfg.max_redispatch:
                ticket.redispatches += 1
                self.redispatches += 1
                target = self._pick_healthy_locked(exclude=i)
                ticket.replica = target
                self._queues[target].put((ticket,) + tuple(task[1:]))
            else:
                # retries exhausted: surface exactly like an
                # unsupervised failure
                ticket.error = err
                ticket._done.set()

    def _restart_locked(self, i: int) -> None:
        """Replace replica ``i``'s dead worker thread with a fresh one on
        the same queue. The replica object itself needs no rebuild: its
        store view is the shared commit stream's broadcast, so the new
        worker picks up exactly where the crash left off."""
        self._spawn_worker_locked(i)
        self.health[i] = "healthy"
        self.restarts += 1

    def _pick_healthy_locked(self, exclude: int) -> int:
        """First non-dead replica other than ``exclude`` (round-robin
        from it); falls back to ``exclude`` itself — by the time we pick,
        its slot has been restarted — so a 1-replica fabric still
        recovers."""
        n = len(self.replicas)
        for off in range(1, n):
            j = (exclude + off) % n
            if self.health[j] not in ("dead", "retired"):
                return j
        return exclude

    def _route_locked(self) -> int:
        """Round-robin over live (non-dead, non-retired) replicas. When
        every active slot is transiently marked dead — the crash window
        between a death and its supervisor restart — do NOT enqueue onto
        a dead slot (the old fall-through bug: the batch could land on a
        queue whose worker is gone and never serve). Instead pick the
        next active slot and revive it under the dispatch lock we
        already hold: if its worker thread is live the "dead" mark is
        stale (supervision already restarted it) and just clears; if the
        worker is really gone, restart it here — by the time the put
        happens the slot has a live worker either way."""
        for _ in range(len(self.replicas)):
            i = self._rr % len(self.replicas)
            self._rr += 1
            if self.health[i] not in ("dead", "retired"):
                return i
        for _ in range(len(self.replicas)):
            i = self._rr % len(self.replicas)
            self._rr += 1
            if self.health[i] == "retired":
                continue
            t = self._threads[i] if i < len(self._threads) else None
            if t is None or not t.is_alive():
                self._restart_locked(i)
            else:
                self.health[i] = "healthy"
            return i
        raise RuntimeError("no active replicas (all retired)")

    def submit(self, prompts, guide_requests, keys=None, embs=None,
               replica: int | None = None) -> Ticket:
        """Dispatch one microbatch to a replica's worker thread and
        return immediately with a :class:`Ticket`. Microbatches sent to
        the same replica serve in submission order (FIFO queue), so a
        caller that shards its stream by replica keeps per-stream
        request order — the property the throughput bench's
        replica-scaling rows rely on for identical routing."""
        self._ensure_workers()
        # one lock hold covers replica choice, ticket registration AND
        # the queue put: concurrent submitters to the same replica keep
        # lock-acquisition order = queue order (the per-replica FIFO
        # guarantee above)
        with self._dispatch_lock:
            if replica is None:
                replica = self._route_locked()
            ticket = Ticket(replica=replica)
            self._tickets.append(ticket)
            self._queues[replica].put((ticket, prompts, guide_requests,
                                       keys, embs))
        return ticket

    def join(self, timeout: float | None = None) -> None:
        """Barrier: every dispatched microbatch has served. Waits
        everything out first, then re-raises the first worker error —
        one dead microbatch cannot strand the others' tickets.

        ``timeout`` bounds the whole barrier: on expiry the not-yet-done
        tickets are re-registered (the barrier can be retried) and a
        :class:`TimeoutError` is raised."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        err: BaseException | None = None
        while True:
            with self._dispatch_lock:
                if not self._tickets:
                    break
                tickets, self._tickets = self._tickets, []
            for n, t in enumerate(tickets):
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                try:
                    t.wait(timeout=remaining)
                except TimeoutError:
                    with self._dispatch_lock:
                        self._tickets.extend(tickets[n:])
                    raise TimeoutError(
                        f"fabric join timed out after {timeout}s "
                        f"({len(tickets) - n} microbatch(es) still in "
                        f"flight; tickets stay registered — retry "
                        f"join())") from None
                except BaseException as e:
                    if err is None:
                        err = e
        if err is not None:
            raise err

    # -- barriers / lifecycle -------------------------------------------
    def flush_shadow(self, timeout: float | None = None) -> None:
        """Full barrier: all dispatched microbatches served AND every
        replica's shadow queue drained — all outstanding Outcomes final.
        ``timeout`` bounds the join leg and each replica's drain barrier
        (per-leg, not cumulative)."""
        self.join(timeout=timeout)
        for r in self.replicas:
            r.flush_shadow(timeout=timeout)

    def close_shadow(self) -> None:
        """Flush, then stop the replica workers and the replicas' shadow
        worker threads. A journaled fabric also checkpoints its manifest
        so a clean shutdown recovers byte-identically. Idempotent.

        Teardown runs in a ``finally``: a flush that raises (drainer
        error, barrier timeout) must still sentinel/join every worker
        thread and close every replica's drainer — otherwise the threads
        leak and a retried close would double-spawn. The flush error
        stays the primary exception; teardown errors surface only when
        the flush itself succeeded."""
        self.stop_autoscaler()
        try:
            self.flush_shadow()
            self.commit_stream.checkpoint()
        finally:
            teardown_err: BaseException | None = None
            if self._queues is not None:
                for q in self._queues:
                    q.put(None)
                for t in self._threads:
                    if t is not None:
                        t.join(timeout=60)
                self._queues, self._threads = None, []
            for r in self.replicas:
                try:
                    r.close_shadow()
                except BaseException as e:
                    if teardown_err is None:
                        teardown_err = e
            if teardown_err is not None and sys.exc_info()[0] is None:
                raise teardown_err

    close = close_shadow

    # -- autoscaling ----------------------------------------------------
    def set_autoscaler(self, policy) -> None:
        """Install the autoscaling policy: a callable mapping one
        ``metrics()`` snapshot to a target active-replica count (int).
        ``None`` (the default) makes :meth:`autoscale` a no-op."""
        self.autoscale_policy = policy

    @property
    def active_replicas(self) -> int:
        return sum(1 for h in self.health if h != "retired")

    def autoscale(self) -> int:
        """One autoscaling step: ask the policy for a target count from
        the current metrics and apply it behind a **health gate** — no
        resize while any slot is dead/mid-restart (supervision first,
        capacity second; a crash storm must not race fresh spawns).
        Returns the applied delta (+spawned / -retired / 0)."""
        if self.autoscale_policy is None:
            return 0
        target = int(self.autoscale_policy(self.metrics()))
        with self._dispatch_lock:
            if any(h == "dead" for h in self.health):
                return 0
            return self._scale_to_locked(target)

    def start_autoscaler(self, interval_s: float = 1.0,
                         policy=None) -> None:
        """Run :meth:`autoscale` on a supervisor tick (daemon thread)
        every ``interval_s`` seconds until :meth:`stop_autoscaler` or
        :meth:`close_shadow`. ``policy`` installs a specific policy;
        with none given and none installed, the default
        :class:`QueueLatencyAutoscaler` is used — the tick is what
        turns the policy object into an actual control loop."""
        if policy is not None:
            self.set_autoscaler(policy)
        elif self.autoscale_policy is None:
            self.set_autoscaler(QueueLatencyAutoscaler())
        if self._autoscale_thread is not None \
                and self._autoscale_thread.is_alive():
            return
        self._autoscale_stop.clear()

        def tick():
            while not self._autoscale_stop.wait(interval_s):
                try:
                    self.autoscale()
                except Exception:
                    # supervision owns replica health; a racing resize
                    # (e.g. mid-crash-storm) is skipped, not fatal
                    pass
                self.autoscale_ticks += 1

        self._autoscale_thread = threading.Thread(
            target=tick, name="fabric-autoscaler", daemon=True)
        self._autoscale_thread.start()

    def stop_autoscaler(self) -> None:
        """Stop the supervisor tick (idempotent; keeps the policy
        installed for manual :meth:`autoscale` calls)."""
        self._autoscale_stop.set()
        t = self._autoscale_thread
        if t is not None:
            t.join(timeout=10)
        self._autoscale_thread = None

    def scale_to(self, n: int) -> int:
        """Resize to ``n`` active replicas (spawn or retire); returns
        the applied delta."""
        with self._dispatch_lock:
            return self._scale_to_locked(n)

    def _scale_to_locked(self, n: int) -> int:
        if n < 1:
            raise ValueError(f"target replicas={n} must be >= 1 "
                             f"(the learn replica always serves)")
        delta = 0
        while self.active_replicas < n:
            self._spawn_replica_locked()
            delta += 1
        while self.active_replicas > n:
            self._retire_replica_locked()
            delta -= 1
        return delta

    def _spawn_replica_locked(self) -> None:
        """Append a fresh replica sharing the fabric's tiers (and
        breaker), commit stream, clock and metrics registry. Its store
        view starts at the stream's current broadcast; if the threaded
        workers are up, the slot gets its own queue + worker
        immediately, otherwise it joins the synchronous round-robin."""
        weak, strong, embed_fn, route_weak_fn = self._replica_args
        i = len(self.replicas)
        r = _FabricReplica(self, i, weak, strong, embed_fn,
                           route_weak_fn, self.cfg,
                           aligned_fn=self._aligned_fn,
                           memory=self.learn.memory,
                           commit_stream=self.commit_stream,
                           fault_plan=self.fault_plan)
        self.replicas.append(r)
        self.health.append("healthy")
        self.spawned += 1
        if self._queues is not None:
            self._queues.append(_queue.Queue())
            self._threads.append(None)
            self._spawn_worker_locked(i)

    def _retire_replica_locked(self) -> None:
        """Retire the highest-index active slot (never the learn
        replica at index 0 — it owns every drain). The mark is terminal:
        dispatch skips the slot immediately; its worker finishes the
        FIFO already queued, then exits on the sentinel — queued work is
        never dropped."""
        for i in range(len(self.replicas) - 1, 0, -1):
            if self.health[i] != "retired":
                self.health[i] = "retired"
                self.retired += 1
                if self._queues is not None:
                    self._queues[i].put(None)
                return
        raise RuntimeError("only the learn replica remains; "
                           "cannot retire it")

    # -- metrics plane ---------------------------------------------------
    def metrics(self) -> dict:
        """One host-side observability snapshot (zero device syncs —
        every number is a Python int/float already on the host):
        per-replica queue depth / health / shadow staleness + drain
        counters + commit-stream lag, commit progress, engine and
        breaker counters, supervision + autoscaling events, the adaptive
        drain policy's fitted cost model, and the raw registry snapshot
        (drain-cost histograms live there, under
        ``replica{i}/shadow/...`` names)."""
        with self._dispatch_lock:
            queues = self._queues
            health = list(self.health)
        epoch = self.commit_stream.buffer.epoch
        per = []
        for i, r in enumerate(self.replicas):
            sq = r.shadow
            per.append({
                "replica": i,
                "health": health[i] if i < len(health) else "healthy",
                "queue_depth": (queues[i].qsize()
                                if queues is not None and i < len(queues)
                                else 0),
                "shadow_pending": len(sq._items),
                "shadow_staleness_batches": sq._batches,
                "shadow_staleness_logical": sq.staleness_logical,
                "items_enqueued": sq.items_enqueued,
                "items_drained": sq.items_drained,
                "items_requeued": sq.items_requeued,
                "drain_failures": sq.drain_failures,
                "drains": sq.drains,
                # epochs applied fabric-wide vs seen by this replica's
                # store view (0 in the thread fabric's atomic broadcast;
                # the process fabric's worker mirrors can lag)
                "commit_epoch_lag":
                    epoch - getattr(r, "commit_epoch_seen", epoch),
            })
        out = {
            "replicas": per,
            "commit": {"epoch": epoch,
                       "entries_applied":
                           self.commit_stream.buffer.entries_applied,
                       "commits": self.commit_stream.commits},
            "engines": {"weak": _engine_stats(self.learn.weak),
                        "strong": _engine_stats(self.learn.strong)},
            "resilience": {"weak": _tier_stats(self.learn.weak),
                           "strong": _tier_stats(self.learn.strong)},
            "supervision": {"health": health,
                            "deaths": self.deaths,
                            "restarts": self.restarts,
                            "redispatches": self.redispatches,
                            "spawned": self.spawned,
                            "retired": self.retired,
                            "active_replicas":
                                sum(1 for h in health if h != "retired")},
            "drain_policy": (self.drain_policy.stats()
                             if self.drain_policy is not None else None),
            "autoscaler": {
                "ticks": self.autoscale_ticks,
                "policy": (self.autoscale_policy.stats()
                           if hasattr(self.autoscale_policy, "stats")
                           else None),
            },
            "registry": self.metrics_registry.snapshot(),
        }
        return out

    # -- views / accounting ---------------------------------------------
    @property
    def memory(self):
        """The (shared) store, read through the learn replica's view."""
        return self.learn.memory

    @property
    def memory_occupancy(self) -> int:
        """Exact at any replica count: the commit stream owns the single
        host-side counter every replica's occupancy derives from."""
        return self.learn.memory_occupancy

    @property
    def now(self) -> int:
        return self.clock.now

    @property
    def guides_from_memory(self) -> int:
        # drains run on the learn replica only; summing keeps this
        # correct even if a subclass re-homes the drain
        return sum(r.guides_from_memory for r in self.replicas)

    @property
    def guides_generated(self) -> int:
        return sum(r.guides_generated for r in self.replicas)

    def stats(self) -> dict:
        """Host-side fabric counters (no device syncs)."""
        return {
            "replicas": len(self.replicas),
            "now": self.clock.now,
            "memory_occupancy": self.memory_occupancy,
            "commits": self.commit_stream.commits,
            "epochs": self.commit_stream.buffer.epoch,
            "items_enqueued": sum(r.shadow.items_enqueued
                                  for r in self.replicas),
            "items_drained": sum(r.shadow.items_drained
                                 for r in self.replicas),
            "items_coalesced": sum(r.shadow.items_coalesced
                                   for r in self.replicas),
            "reclaimed_weak_calls": sum(r.shadow.reclaimed_weak_calls
                                        for r in self.replicas),
            "reclaimed_strong_calls": sum(r.shadow.reclaimed_strong_calls
                                          for r in self.replicas),
            "weak": _engine_stats(self.learn.weak),
            "strong": _engine_stats(self.learn.strong),
            # recovery plane: supervision, degraded routing, tier
            # resilience, journal — all host counters
            "health": list(self.health),
            "deaths": self.deaths,
            "restarts": self.restarts,
            "redispatches": self.redispatches,
            "spawned": self.spawned,
            "retired": self.retired,
            "probes_deferred": sum(r.probes_deferred
                                   for r in self.replicas),
            "probes_replayed": sum(r.probes_replayed
                                   for r in self.replicas),
            "weak_resilience": _tier_stats(self.learn.weak),
            "strong_resilience": _tier_stats(self.learn.strong),
            "journal": (self.commit_stream.journal.stats()
                        if self.commit_stream.journal is not None
                        else None),
            "faults": (self.fault_plan.stats()
                       if self.fault_plan is not None else None),
        }


class QueueLatencyAutoscaler:
    """Default autoscaling policy: queue depth and latency SLO →
    target active-replica count.

    Consumes one ``fabric.metrics()`` snapshot per call (the contract
    of :meth:`ServingFabric.set_autoscaler`). Scale **up** one replica
    when the mean dispatch-queue depth per active replica exceeds
    ``high_depth``, or — when an SLO is configured and the admission
    scheduler's queueing-delay histogram has samples — its p99 breaches
    ``slo_ms``. Scale **down** one replica when depth sits below
    ``low_depth`` and the p99 (if observable) is comfortably inside the
    SLO (≤ half). Targets clamp to ``[min_replicas, max_replicas]`` and
    move one step per tick: resizes are serialized through the fabric's
    dispatch lock, and a one-step policy cannot oscillate faster than
    the supervisor tick that drives it.
    """

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 8,
                 slo_ms: float | None = None, high_depth: float = 2.0,
                 low_depth: float = 0.25,
                 delay_metric: str = "sched/queue_delay_ms"):
        if min_replicas < 1 or max_replicas < min_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"[{min_replicas}, {max_replicas}]")
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.slo_ms = slo_ms
        self.high_depth = high_depth
        self.low_depth = low_depth
        self.delay_metric = delay_metric
        self.decisions = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.last_target = None
        self.last_depth = None
        self.last_p99 = None

    def _p99(self, metrics: dict) -> float | None:
        hist = (metrics.get("registry") or {}).get(self.delay_metric)
        if isinstance(hist, dict) and hist.get("count", 0) > 0:
            return hist.get("p99")
        return None

    def __call__(self, metrics: dict) -> int:
        sup = metrics.get("supervision", {})
        active = max(1, sup.get("active_replicas", 1))
        depth = sum(r.get("queue_depth", 0)
                    for r in metrics.get("replicas", ())
                    if r.get("health") != "retired")
        mean_depth = depth / active
        p99 = self._p99(metrics)
        slo_breach = (self.slo_ms is not None and p99 is not None
                      and p99 > self.slo_ms)
        target = active
        if mean_depth > self.high_depth or slo_breach:
            target = active + 1
        elif mean_depth < self.low_depth and (
                self.slo_ms is None or p99 is None
                or p99 <= self.slo_ms / 2):
            target = active - 1
        target = max(self.min_replicas, min(self.max_replicas, target))
        self.decisions += 1
        if target > active:
            self.scale_ups += 1
        elif target < active:
            self.scale_downs += 1
        self.last_target = target
        self.last_depth = mean_depth
        self.last_p99 = p99
        return target

    def stats(self) -> dict:
        return {
            "policy": type(self).__name__,
            "min_replicas": self.min_replicas,
            "max_replicas": self.max_replicas,
            "slo_ms": self.slo_ms,
            "decisions": self.decisions,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "last_target": self.last_target,
            "last_depth": self.last_depth,
            "last_p99": self.last_p99,
        }


def _tier_stats(tier) -> dict | None:
    """A tier's resilience counters, when wrapped in a
    :class:`~repro.core.fm.ResilientTier` (retries / failures / shed /
    breaker state)."""
    return tier.stats() if isinstance(tier, ResilientTier) else None


def _engine_stats(tier) -> dict | None:
    """A tier's engine counters, when it exposes them (real
    ``ServingEngine``s do; rule-based test doubles need not)."""
    fn = getattr(getattr(tier, "engine", None), "stats", None)
    return fn() if fn is not None else None
