"""Continuous-batching admission scheduler over the fabric boundary.

The fabrics (:class:`~repro.serving.fabric.ServingFabric`,
:class:`~repro.serving.procfabric.ProcessServingFabric`) expose a
microbatch-granular boundary: ``submit`` a pre-formed batch, get a
:class:`Ticket`, ``wait`` it. Everything upstream of this module hands
them batches that were partitioned ahead of time — closed-loop load.
:class:`ContinuousBatcher` is the admission layer in between: it
accepts *single* requests from an open-loop arrival stream and decides,
per request, which forming batch it joins and when that batch stops
waiting for more traffic and dispatches.

Lifecycle: **arrival → admit → close → dispatch → resolve.**

- **admit** — each request arrives stamped with a virtual arrival
  instant, stream id, priority, and optional deadline. It joins the
  open batch for its ``(replica, length-bucket)`` slot, opening one if
  needed.
- **close** (size-or-deadline rule) — a batch closes when it reaches
  ``microbatch`` requests (*size*), or when the virtual clock reaches
  the earliest queueing-budget deadline of any member (*slo*): a
  request's budget is its explicit ``deadline_ms`` if set, else
  ``slo_ms / (1 + priority)`` — higher priority, tighter budget. With
  ``slo_ms=None`` and no explicit deadlines, only size (and the final
  flush) closes batches.
- **dispatch** — a closed batch is submitted to the fabric unchanged
  through ``submit(prompts, guide_requests, keys=, embs=, replica=)``;
  admission→dispatch queueing delay is recorded per request.
- **resolve** — tickets are waited in dispatch order and
  admission→resolve end-to-end latency recorded; outcomes return in
  admission order.

Two invariants shape batch formation:

- **Bucket-aware**: batches group requests by exact prompt length (the
  grouping ``ServingEngine.generate_bucketed`` applies anyway), so an
  admission-formed batch compiles against the same padded shapes as a
  closed-loop one instead of fragmenting the jit cache.
- **Per-stream FIFO**: a stream's requests always target the same
  replica (``replica_fn``), and before a request opens/joins a batch
  other than the one holding the stream's previous in-flight request,
  that previous batch is closed first. At most one open batch ever
  contains a given stream, and batches containing a stream close in
  that stream's arrival order — so per-replica FIFO at the fabric
  preserves per-stream request order end to end.

Formation runs entirely in *virtual* time (the trace's timestamps), so
the batch partition — and therefore routing — is a deterministic
function of the trace alone. Wall-clock pacing (``pace=True``) only
maps dispatch instants onto real sleeps for honest end-to-end numbers;
it can never change what gets batched with what.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

__all__ = ["Request", "ContinuousBatcher", "serve_trace"]


@dataclasses.dataclass
class Request:
    """One admitted open-loop request.

    ``arrival_s`` is virtual seconds since trace start; ``index`` is
    the admission order (outcomes are returned sorted by it). ``key`` /
    ``emb`` pass through to ``fabric.submit`` untouched.
    """
    arrival_s: float
    stream: int
    prompt: Any
    guide_request: Any
    priority: int = 0
    deadline_ms: float | None = None
    key: Any = None
    emb: Any = None
    index: int = 0
    # filled in by the batcher
    dispatch_s: float | None = None
    batch_id: int = -1


@dataclasses.dataclass
class _OpenBatch:
    id: int
    replica: int | None
    bucket: Any
    opened_s: float
    requests: list[Request] = dataclasses.field(default_factory=list)
    deadline_s: float = float("inf")


@dataclasses.dataclass
class _Dispatch:
    batch_id: int
    replica: int | None
    bucket: Any
    reason: str
    dispatch_s: float
    requests: list[Request]
    ticket: Any
    submit_wall: float


class ContinuousBatcher:
    """Admission scheduler forming microbatches from single requests.

    Drive it with ``admit`` per arrival (in trace order), ``flush`` at
    end of stream, ``resolve`` to collect outcomes. ``advance`` may be
    called explicitly to let the virtual clock close overdue batches
    without admitting anything (e.g. at the end of a lull).

    Not thread-safe: one driver loop owns it, mirroring how a front
    door drains one arrival queue.
    """

    CLOSE_SIZE = "size"        # reached ``microbatch`` requests
    CLOSE_SLO = "slo"          # oldest member's queueing budget expired
    CLOSE_STREAM = "stream"    # stream moved on to a different bucket
    CLOSE_FLUSH = "flush"      # end-of-trace flush

    def __init__(self, fabric, *, microbatch: int, slo_ms: float | None = None,
                 replica_fn: Callable[[int], int | None] | None = None,
                 bucket_fn: Callable[[Any], Any] | None = None,
                 registry=None, pace: bool = False):
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        if slo_ms is not None and slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {slo_ms}")
        self.fabric = fabric
        self.microbatch = int(microbatch)
        self.slo_ms = slo_ms
        self.pace = pace
        if replica_fn is None:
            n = getattr(fabric, "n_workers", None)
            if n is None:
                n = len(getattr(fabric, "replicas", ())) or 1
            replica_fn = (lambda stream, _n=n: stream % _n)
        self.replica_fn = replica_fn
        # exact prompt length is the bucket generate_bucketed groups by
        self.bucket_fn = bucket_fn if bucket_fn is not None else len
        if registry is None:
            registry = getattr(fabric, "metrics_registry", None)
        if registry is None:
            from repro.serving.metrics import MetricsRegistry
            registry = MetricsRegistry()
        self.registry = registry
        self._open: dict[tuple, _OpenBatch] = {}
        self._stream_open: dict[int, _OpenBatch] = {}
        self.dispatches: list[_Dispatch] = []
        self._next_batch = 0
        self.now_s = 0.0              # virtual clock high-water mark
        self._t0_wall: float | None = None
        self.admitted = 0
        self.dispatched = 0
        self.closes = {self.CLOSE_SIZE: 0, self.CLOSE_SLO: 0,
                       self.CLOSE_STREAM: 0, self.CLOSE_FLUSH: 0}
        m = registry
        self._m_admitted = m.counter("sched/admitted")
        self._m_dispatched = m.counter("sched/dispatched")
        self._m_batches = m.counter("sched/batches")
        self._m_open = m.gauge("sched/open_requests")
        self._m_close = {r: m.counter(f"sched/close_{r}")
                         for r in self.closes}
        self._m_qd = m.histogram("sched/queue_delay_ms")
        self._m_e2e = m.histogram("sched/e2e_ms")
        self._m_batch_fill = m.histogram("sched/batch_fill")
        self._stream_hists: dict[int, tuple] = {}

    # -- virtual-time formation ----------------------------------------
    def _budget_s(self, req: Request) -> float:
        if req.deadline_ms is not None:
            return req.deadline_ms / 1e3
        if self.slo_ms is None:
            return float("inf")
        return (self.slo_ms / 1e3) / (1 + max(0, req.priority))

    def advance(self, t: float) -> None:
        """Move the virtual clock to ``t``, closing (at their deadline
        instants, oldest deadline first) every open batch whose SLO
        budget expires on the way."""
        while True:
            due = [b for b in self._open.values() if b.deadline_s <= t]
            if not due:
                break
            b = min(due, key=lambda b: (b.deadline_s, b.id))
            self._close(b, b.deadline_s, self.CLOSE_SLO)
        self.now_s = max(self.now_s, t)

    def admit(self, req: Request) -> None:
        """Admit one arrival at its virtual instant ``req.arrival_s``
        (must be non-decreasing across calls)."""
        if req.arrival_s < self.now_s - 1e-9:
            raise ValueError(
                f"arrival at t={req.arrival_s:.6f}s is in the past "
                f"(clock at {self.now_s:.6f}s) — admit in trace order")
        self.advance(req.arrival_s)
        replica = self.replica_fn(req.stream)
        key = (replica, self.bucket_fn(req.prompt))
        batch = self._open.get(key)
        prev = self._stream_open.get(req.stream)
        if prev is not None and prev is not batch:
            # per-stream FIFO: the stream's previous request sits in a
            # different forming batch — dispatch it before this request
            # can land in a newer one
            self._close(prev, req.arrival_s, self.CLOSE_STREAM)
            batch = self._open.get(key)
        if batch is None:
            batch = _OpenBatch(id=self._next_batch, replica=replica,
                               bucket=key[1], opened_s=req.arrival_s)
            self._next_batch += 1
            self._open[key] = batch
        req.batch_id = batch.id
        batch.requests.append(req)
        batch.deadline_s = min(batch.deadline_s,
                               req.arrival_s + self._budget_s(req))
        self._stream_open[req.stream] = batch
        self.admitted += 1
        self._m_admitted.inc()
        self._m_open.set(sum(len(b.requests) for b in self._open.values()))
        if len(batch.requests) >= self.microbatch:
            self._close(batch, req.arrival_s, self.CLOSE_SIZE)

    def flush(self, t: float | None = None) -> None:
        """Close every still-open batch (end of trace), oldest first,
        at virtual instant ``t`` (default: the clock's high-water
        mark)."""
        t = self.now_s if t is None else max(t, self.now_s)
        self.advance(t)
        while self._open:
            b = min(self._open.values(), key=lambda b: b.id)
            self._close(b, t, self.CLOSE_FLUSH)

    # -- dispatch -------------------------------------------------------
    def _close(self, batch: _OpenBatch, t: float, reason: str) -> None:
        for key, b in list(self._open.items()):
            if b is batch:
                del self._open[key]
                break
        for stream, b in list(self._stream_open.items()):
            if b is batch:
                del self._stream_open[stream]
        reqs = batch.requests
        if self.pace:
            if self._t0_wall is None:
                self._t0_wall = time.monotonic()
            delay = self._t0_wall + t - time.monotonic()
            if delay > 0:
                time.sleep(delay)
        prompts = [r.prompt for r in reqs]
        greqs = [r.guide_request for r in reqs]
        keys = [r.key for r in reqs]
        embs = None
        if all(r.emb is not None for r in reqs):
            embs = np.stack([np.asarray(r.emb) for r in reqs])
        submit_wall = time.monotonic()
        ticket = self.fabric.submit(prompts, greqs, keys=keys, embs=embs,
                                    replica=batch.replica)
        for r in reqs:
            r.dispatch_s = t
            qd_ms = max(0.0, (t - r.arrival_s) * 1e3)
            self._m_qd.observe(qd_ms)
            self._stream_hist(r.stream)[0].observe(qd_ms)
        self.dispatched += len(reqs)
        self.closes[reason] += 1
        self._m_dispatched.inc(len(reqs))
        self._m_batches.inc()
        self._m_close[reason].inc()
        self._m_batch_fill.observe(len(reqs))
        self._m_open.set(sum(len(b.requests) for b in self._open.values()))
        self.dispatches.append(_Dispatch(
            batch_id=batch.id, replica=batch.replica, bucket=batch.bucket,
            reason=reason, dispatch_s=t, requests=reqs, ticket=ticket,
            submit_wall=submit_wall))

    def _stream_hist(self, stream: int):
        h = self._stream_hists.get(stream)
        if h is None:
            h = (self.registry.histogram(f"sched/stream{stream}/queue_delay_ms"),
                 self.registry.histogram(f"sched/stream{stream}/e2e_ms"))
            self._stream_hists[stream] = h
        return h

    # -- resolve --------------------------------------------------------
    def resolve(self, timeout: float | None = None) -> list:
        """Wait every dispatched ticket (dispatch order) and return the
        outcomes in admission order, recording admission→resolve
        end-to-end latency per request.

        Paced runs measure true open-loop e2e against the shared wall
        epoch; unpaced (virtual-only) runs compose the virtual queueing
        delay with the measured wall service time of each batch.
        """
        outcomes: dict[int, Any] = {}
        for d in self.dispatches:
            outs = d.ticket.wait(timeout=timeout)
            resolved_wall = time.monotonic()
            for r, out in zip(d.requests, outs):
                if self.pace and self._t0_wall is not None:
                    e2e_ms = (resolved_wall - self._t0_wall
                              - r.arrival_s) * 1e3
                else:
                    e2e_ms = ((r.dispatch_s - r.arrival_s)
                              + (resolved_wall - d.submit_wall)) * 1e3
                e2e_ms = max(0.0, e2e_ms)
                self._m_e2e.observe(e2e_ms)
                self._stream_hist(r.stream)[1].observe(e2e_ms)
                outcomes[r.index] = out
        return [outcomes[i] for i in sorted(outcomes)]

    def stats(self) -> dict:
        """Formation counters for reports: admissions, dispatches,
        batch count, and close-reason breakdown."""
        return {
            "admitted": self.admitted,
            "dispatched": self.dispatched,
            "batches": len(self.dispatches),
            "open_requests": sum(len(b.requests)
                                 for b in self._open.values()),
            "closes": dict(self.closes),
        }


def serve_trace(fabric, trace, make_request, *, microbatch: int,
                slo_ms: float | None = None, replica_fn=None,
                bucket_fn=None, registry=None, pace: bool = False,
                timeout: float | None = None):
    """Drive a :class:`ContinuousBatcher` over a loadgen trace.

    ``make_request(event)`` maps each :class:`ArrivalEvent` to a
    ``(prompt, guide_request, key, emb)`` tuple — the caller owns the
    stream→content mapping (e.g. the k-th arrival of stream j serves
    that stream's k-th pool question). Returns ``(outcomes, batcher)``
    with outcomes in admission order.
    """
    batcher = ContinuousBatcher(
        fabric, microbatch=microbatch, slo_ms=slo_ms,
        replica_fn=replica_fn, bucket_fn=bucket_fn, registry=registry,
        pace=pace)
    for ev in trace:
        prompt, greq, key, emb = make_request(ev)
        batcher.admit(Request(
            arrival_s=ev.t, stream=ev.stream, priority=ev.priority,
            deadline_ms=ev.deadline_ms, prompt=prompt, guide_request=greq,
            key=key, emb=emb, index=ev.index))
    batcher.flush()
    outcomes = batcher.resolve(timeout=timeout)
    return outcomes, batcher
