"""Host-side metrics plane — counters, gauges and histograms for the
serving fabric (the ROADMAP's observability + adaptive-control item).

Design constraints, in order:

* **Zero device syncs.** Every value recorded here is a plain Python
  number already on the host (queue lengths, epoch counters, wall-clock
  seconds). Nothing in this module may touch a ``jax.Array`` — the same
  rule the transfer-free ``memory_occupancy`` counter established. A
  metrics scrape must never stall the serve pipeline on a device fence.
* **Consistent snapshots.** One :class:`MetricsRegistry` owns one lock;
  every update and the whole :meth:`MetricsRegistry.snapshot` serialize
  on it. Related metrics written under a single ``registry.lock`` hold
  (e.g. the shadow queue's enqueue counter and depth gauge) can
  therefore never be observed torn — the property
  ``tests/test_metrics.py`` stresses under the async drainer.
* **Cheap.** Update cost is one uncontended lock acquire plus an int/
  float op; histograms keep a bounded reservoir (halved by decimation
  when full), so a metric can sit on the drain path of every epoch
  without becoming the thing the metrics are measuring.

The registry is the *mechanism*; naming is the caller's policy. The
fabric uses ``replica{i}/shadow/...`` prefixes so one shared registry
carries every replica's queue gauges — which is exactly what the global
adaptive flush policy (:class:`repro.core.shadow.AdaptiveDrainPolicy`)
consumes: the learn replica reads every replica's staleness from here.
"""
from __future__ import annotations

import re
import threading


class Counter:
    """Monotone counter. ``inc`` only; a decreasing value is a bug the
    snapshot-consistency tests would flag."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def get(self) -> int:
        with self._lock:
            return self.value


class Gauge:
    """Last-write-wins instantaneous value (queue depth, staleness)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def get(self) -> float:
        with self._lock:
            return self.value


class Histogram:
    """Bounded-reservoir distribution (drain cost, staleness-at-drain).

    Keeps exact count/total plus a reservoir of observed values for
    percentiles; when the reservoir fills it is decimated (every other
    sample dropped, stride doubled) so long runs keep a uniform-ish
    spread at O(max_samples) memory. Percentiles are nearest-rank over
    the reservoir — plenty for p50/p99 reporting.
    """

    __slots__ = ("name", "count", "total", "_samples", "_stride", "_skip",
                 "_max", "_lock")

    def __init__(self, name: str, lock: threading.RLock,
                 max_samples: int = 2048):
        self.name = name
        self.count = 0
        self.total = 0.0
        self._samples: list[float] = []
        self._stride = 1          # keep every _stride-th observation
        self._skip = 0
        self._max = max_samples
        self._lock = lock

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            self._skip += 1
            if self._skip >= self._stride:
                self._skip = 0
                self._samples.append(float(v))
                if len(self._samples) >= self._max:
                    self._samples = self._samples[::2]
                    self._stride *= 2

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the reservoir (0 when empty)."""
        with self._lock:
            if not self._samples:
                return 0.0
            s = sorted(self._samples)
            k = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
            return s[k]

    @property
    def mean(self) -> float:
        with self._lock:
            return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        with self._lock:
            s = sorted(self._samples)

            def pct(p):
                if not s:
                    return 0.0
                return s[min(len(s) - 1,
                             max(0, int(round(p / 100.0 * (len(s) - 1)))))]
            return {"count": self.count, "total": self.total,
                    "mean": (self.total / self.count if self.count
                             else 0.0),
                    "p50": pct(50.0), "p99": pct(99.0)}


class MetricsRegistry:
    """Named metric store with get-or-create accessors and one shared
    lock (see module doc for why a single lock). Metric kinds are
    type-stable per name: asking for an existing name with a different
    kind raises rather than silently aliasing."""

    def __init__(self):
        self.lock = threading.RLock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind):
        with self.lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(name, self.lock)
            elif type(m) is not kind:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """One consistent host-side view: ``{name: number}`` for
        counters/gauges, ``{name: {count,total,mean,p50,p99}}`` for
        histograms. Taken under the registry lock, so no update can
        interleave mid-snapshot (no torn reads across related metrics)."""
        with self.lock:
            out = {}
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if isinstance(m, Histogram):
                    out[name] = m.summary()
                elif isinstance(m, Counter):
                    out[name] = m.value
                else:
                    out[name] = m.value
            return out

    def to_openmetrics(self) -> str:
        """Render one consistent snapshot in OpenMetrics / Prometheus
        text exposition format, scrape-ready:

        - counters → ``# TYPE name counter`` + ``name_total``
        - gauges → ``# TYPE name gauge`` + ``name``
        - histograms → ``# TYPE name summary`` with ``quantile="0.5"``
          / ``quantile="0.99"`` series plus ``name_sum``/``name_count``
          (the reservoir keeps exact count/total; quantiles are the
          same nearest-rank values :meth:`Histogram.summary` reports)

        Metric names are sanitized to the OpenMetrics charset (the
        registry's ``/``-separated paths become ``_``-separated), and
        the exposition ends with the mandatory ``# EOF`` marker.
        Rendered under the registry lock — same no-torn-reads guarantee
        as :meth:`snapshot`.
        """
        lines: list[str] = []
        with self.lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                om = _openmetrics_name(name)
                if isinstance(m, Counter):
                    lines.append(f"# TYPE {om} counter")
                    lines.append(f"{om}_total {_fmt(m.value)}")
                elif isinstance(m, Gauge):
                    lines.append(f"# TYPE {om} gauge")
                    lines.append(f"{om} {_fmt(m.value)}")
                else:
                    s = m.summary()
                    lines.append(f"# TYPE {om} summary")
                    lines.append(
                        f'{om}{{quantile="0.5"}} {_fmt(s["p50"])}')
                    lines.append(
                        f'{om}{{quantile="0.99"}} {_fmt(s["p99"])}')
                    lines.append(f"{om}_sum {_fmt(s['total'])}")
                    lines.append(f"{om}_count {s['count']}")
        lines.append("# EOF")
        return "\n".join(lines) + "\n"


def _openmetrics_name(name: str) -> str:
    """Map a registry path to the OpenMetrics name charset
    (``[a-zA-Z_:][a-zA-Z0-9_:]*``)."""
    om = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not om or not re.match(r"[a-zA-Z_:]", om[0]):
        om = "_" + om
    return om


def _fmt(v) -> str:
    """Render a metric value: ints verbatim, floats via repr (full
    precision, no scientific-notation surprises for typical ranges)."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)
