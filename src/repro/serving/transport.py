"""Framed cross-process message transport for the serving fabric.

One wire discipline for everything that crosses a process boundary:
every message is a pickle (protocol 4) payload behind an 8-byte
``<u32 length><u32 crc32>`` header — byte-for-byte the framing
:class:`repro.core.memory.MemoryJournal` uses for its write-ahead log
(the journal delegates to the helpers here, so WAL records and RPC
frames literally share one codec). The crc catches torn or corrupted
frames; a short read means the peer died mid-frame and surfaces as
:class:`ChannelClosed`, never as a half-parsed message.

:class:`FramedChannel` wraps a duplex ``multiprocessing`` ``Connection``
(one end per process). Sends are serialized under a lock so a worker's
heartbeat thread and its serve loop can share the channel; receives are
single-consumer by construction (the parent's per-worker reader thread,
the worker's main loop). A ``fault_plan`` with ``"transport_frame"``
specs perturbs the send path: ``"delay"`` injects wire latency,
``"crash"`` kills the sending end mid-conversation — the supervision
plane's detection paths are exercised without real packet loss.

Nothing in this module imports the rest of ``repro`` at module scope —
the journal and the fabric both build on it, so it stays at the bottom
of the import graph.
"""
from __future__ import annotations

import pickle
import struct
import threading
import zlib

#: shared frame header: payload length, then crc32 of the payload
HEADER = struct.Struct("<II")
PICKLE_PROTOCOL = 4


class ChannelError(RuntimeError):
    """Base of transport failures."""


class ChannelClosed(ChannelError):
    """The peer's end of the channel is gone (clean close, process exit,
    or SIGKILL — a dead process closes its pipe fd either way)."""


class FrameCorruption(ChannelError):
    """A frame arrived but its crc or header did not check out."""


def frame_payload(payload: bytes) -> bytes:
    """Prefix ``payload`` with the shared ``<u32 len><u32 crc32>``
    header. The journal's WAL writer and the RPC channel both call
    this — one framing discipline, one set of corruption tests."""
    return HEADER.pack(len(payload),
                       zlib.crc32(payload) & 0xFFFFFFFF) + payload


def frame_message(obj) -> bytes:
    """Pickle ``obj`` and frame it."""
    return frame_payload(pickle.dumps(obj, protocol=PICKLE_PROTOCOL))


def check_frame(buf: bytes, offset: int = 0) -> tuple[object, int] | None:
    """Parse one frame from ``buf`` at ``offset``.

    Returns ``(message, next_offset)``, or ``None`` when the remaining
    bytes are a clean end (nothing after ``offset``). Raises
    :class:`FrameCorruption` on a torn header, torn payload, or crc
    mismatch — the caller decides whether that is fatal (RPC) or a
    stop-and-warn (WAL tail recovery)."""
    n = len(buf) - offset
    if n == 0:
        return None
    if n < HEADER.size:
        raise FrameCorruption(
            f"torn frame header: {n} bytes, need {HEADER.size}")
    length, crc = HEADER.unpack_from(buf, offset)
    start = offset + HEADER.size
    if len(buf) - start < length:
        raise FrameCorruption(
            f"torn frame payload: {len(buf) - start} bytes, "
            f"header promised {length}")
    payload = buf[start:start + length]
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise FrameCorruption("frame crc mismatch")
    return pickle.loads(payload), start + length


class FramedChannel:
    """One end of a duplex framed pickle channel over a
    ``multiprocessing.connection.Connection``.

    The connection's own byte-frame transport carries our
    header+crc-framed payload, which is verified on receipt — SIGKILL
    mid-``send_bytes`` can only ever surface as :class:`ChannelClosed`
    or :class:`FrameCorruption`, never as a silently truncated message.
    """

    def __init__(self, conn, *, fault_plan=None, end: str = "",
                 replica: int | None = None):
        self.conn = conn
        self.fault_plan = fault_plan
        self.end = end                  # "parent" / "worker" — fault id
        self.replica = replica
        self._send_lock = threading.Lock()
        self.sent = 0
        self.received = 0

    # -- send -----------------------------------------------------------
    def send(self, obj) -> None:
        """Frame and send one message. Raises :class:`ChannelClosed` if
        the peer is gone; fires the ``"transport_frame"`` fault site
        (wire latency / send-side crash) before touching the pipe."""
        self.send_raw(frame_message(obj))

    def send_raw(self, data: bytes) -> None:
        """Send an already-framed message (``frame_message`` output).
        The epoch-broadcast path frames once and fans the identical
        bytes to every worker channel instead of re-pickling per
        subscriber; the fault site still fires per channel."""
        if self.fault_plan is not None:
            ids = {"end": self.end}
            if self.replica is not None:
                ids["replica"] = self.replica
            self.fault_plan.fire("transport_frame", **ids)
        try:
            with self._send_lock:
                self.conn.send_bytes(data)
                self.sent += 1
        except (BrokenPipeError, EOFError, OSError) as e:
            raise ChannelClosed(f"send to closed channel: {e}") from e

    # -- recv -----------------------------------------------------------
    def recv(self):
        """Block for one message. Raises :class:`ChannelClosed` when the
        peer's end is closed (including abrupt process death)."""
        try:
            buf = self.conn.recv_bytes()
        except EOFError as e:
            raise ChannelClosed("peer closed the channel") from e
        except (BrokenPipeError, OSError) as e:
            raise ChannelClosed(f"channel read failed: {e}") from e
        parsed = check_frame(buf)
        if parsed is None:
            raise FrameCorruption("empty frame")
        msg, end = parsed
        if end != len(buf):
            raise FrameCorruption(
                f"{len(buf) - end} trailing bytes after frame")
        self.received += 1
        return msg

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            return self.conn.poll(timeout)
        except (BrokenPipeError, EOFError, OSError):
            return True     # a closed pipe is "readable": recv -> Closed

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


def channel_pair(ctx=None) -> tuple:
    """A connected pair of raw duplex Connections (parent end, worker
    end). The worker end is picklable as a ``Process`` arg; each side
    wraps its own in a :class:`FramedChannel`."""
    import multiprocessing as mp
    ctx = ctx or mp
    a, b = ctx.Pipe(duplex=True)
    return a, b
