"""Seedable open-loop load generation for the admission scheduler.

Closed-loop serving (the bench's pre-partitioned microbatches) measures
*service* time only: the next batch is not offered until the previous
one resolves, so queueing delay is zero by construction. Real traffic
is open-loop — arrivals happen on their own clock whether or not the
system keeps up — and tail latency under that regime is dominated by
queueing, not service. This module generates the arrival side of that
experiment deterministically.

A *trace* is a list of :class:`ArrivalEvent`, sorted by arrival time,
with every event stamped with a virtual arrival instant (seconds since
trace start), the stream it belongs to, a priority, and an optional
per-request deadline. Three processes are provided:

- :func:`poisson_trace` — independent Poisson streams (exponential
  inter-arrival gaps) merged into one timeline;
- :func:`bursty_trace` — an on/off modulated Poisson process realised
  by *thinning* a homogeneous process at the peak rate, so the mean
  offered rate is preserved while arrivals cluster into bursts;
- :func:`trace_replay` — normalise an externally supplied trace
  (tuples, dicts, or events) into the same canonical form.

Everything is driven by ``numpy.random.default_rng(seed)``: the same
seed yields the same trace byte-for-byte, which is what makes the
scheduler's determinism pin (same trace → same routing decisions)
testable at all. Virtual timestamps decouple trace *shape* from wall
clock — the batcher forms batches in virtual time; only the bench's
pacing loop maps virtual instants onto real sleeps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ArrivalEvent", "poisson_trace", "bursty_trace", "trace_replay"]


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One open-loop arrival.

    ``t`` is the virtual arrival instant in seconds since trace start;
    ``index`` is the event's position in the merged, time-sorted trace
    (assigned by the generator — the canonical admission order).
    """
    t: float
    stream: int
    priority: int = 0
    deadline_ms: float | None = None
    index: int = 0


def _per_stream(value, streams: int, default):
    """Broadcast a scalar / cycle a sequence across ``streams``."""
    if value is None:
        return [default] * streams
    if isinstance(value, (int, float)):
        return [value] * streams
    seq = list(value)
    if not seq:
        return [default] * streams
    return [seq[j % len(seq)] for j in range(streams)]


def _counts(n, streams: int) -> list[int]:
    """Per-stream arrival counts: an int total is split round-robin
    (stream ``j`` gets arrival ``j``, ``j+streams``, … — the same shard
    rule the closed-loop bench uses), a sequence is taken verbatim."""
    if isinstance(n, (int, np.integer)):
        return [len(range(j, int(n), streams)) for j in range(streams)]
    counts = [int(c) for c in n]
    if len(counts) != streams:
        raise ValueError(
            f"per-stream counts {counts} do not match streams={streams}")
    return counts


def _merge(per_stream_times: list[np.ndarray], priorities, deadlines
           ) -> list[ArrivalEvent]:
    """Merge per-stream arrival instants into one time-sorted trace.

    Ties break by stream id then per-stream order, so the merged order
    is a pure function of the timestamps — no rng state leaks in."""
    events = []
    for j, times in enumerate(per_stream_times):
        for t in times:
            events.append((float(t), j))
    events.sort(key=lambda e: (e[0], e[1]))
    return [ArrivalEvent(t=t, stream=j, priority=int(priorities[j]),
                         deadline_ms=deadlines[j], index=i)
            for i, (t, j) in enumerate(events)]


def poisson_trace(n, rate: float, *, seed: int = 0, streams: int = 1,
                  rates=None, priorities=None, deadline_ms=None
                  ) -> list[ArrivalEvent]:
    """Merged independent Poisson arrival streams.

    ``n`` is the total arrival count (split round-robin across streams)
    or an explicit per-stream count list. ``rate`` is the *aggregate*
    offered rate in requests/second, split evenly unless ``rates``
    gives per-stream rates (cycled if shorter than ``streams``).
    ``priorities`` / ``deadline_ms`` stamp each stream's events.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    counts = _counts(n, streams)
    stream_rates = _per_stream(rates, streams, rate / streams)
    prios = _per_stream(priorities, streams, 0)
    dls = _per_stream(deadline_ms, streams, None)
    rng = np.random.default_rng(seed)
    times = []
    for j in range(streams):
        r = float(stream_rates[j])
        if r <= 0:
            raise ValueError(f"stream {j} rate must be positive, got {r}")
        gaps = rng.exponential(1.0 / r, size=counts[j])
        times.append(np.cumsum(gaps))
    return _merge(times, prios, dls)


def bursty_trace(n, rate: float, *, seed: int = 0, streams: int = 1,
                 rates=None, priorities=None, deadline_ms=None,
                 burst: float = 3.0, duty: float = 0.25,
                 period_s: float = 1.0) -> list[ArrivalEvent]:
    """On/off modulated Poisson arrivals with the same *mean* rate.

    Each period of ``period_s`` seconds spends ``duty`` of its length
    in the *on* phase at ``burst``× the stream's mean rate; the off
    phase runs at the complementary rate so the long-run offered load
    equals ``rate`` exactly (requires ``burst * duty <= 1``). Realised
    by thinning a homogeneous Poisson process at the peak rate —
    deterministic given the seed, like everything else here.
    """
    if not 0 < duty < 1:
        raise ValueError(f"duty must be in (0, 1), got {duty}")
    if burst <= 1:
        raise ValueError(f"burst must exceed 1, got {burst}")
    if burst * duty > 1:
        raise ValueError(
            f"burst*duty={burst * duty:.3f} > 1 leaves a negative off-rate")
    counts = _counts(n, streams)
    stream_rates = _per_stream(rates, streams, rate / streams)
    prios = _per_stream(priorities, streams, 0)
    dls = _per_stream(deadline_ms, streams, None)
    off_factor = (1.0 - burst * duty) / (1.0 - duty)
    rng = np.random.default_rng(seed)
    times = []
    for j in range(streams):
        r = float(stream_rates[j])
        if r <= 0:
            raise ValueError(f"stream {j} rate must be positive, got {r}")
        peak = r * burst
        accepted: list[float] = []
        t = 0.0
        while len(accepted) < counts[j]:
            t += float(rng.exponential(1.0 / peak))
            phase = (t % period_s) / period_s
            local = burst if phase < duty else off_factor
            if float(rng.random()) * burst < local:
                accepted.append(t)
        times.append(np.asarray(accepted))
    return _merge(times, prios, dls)


def trace_replay(events) -> list[ArrivalEvent]:
    """Normalise an externally supplied trace into canonical form.

    Accepts :class:`ArrivalEvent` instances, ``(t, stream[, priority
    [, deadline_ms]])`` tuples, or dicts with those keys. The result is
    time-sorted with indices reassigned and timestamps validated
    (finite, non-negative).
    """
    parsed = []
    for ev in events:
        if isinstance(ev, ArrivalEvent):
            t, s, p, d = ev.t, ev.stream, ev.priority, ev.deadline_ms
        elif isinstance(ev, dict):
            t = ev["t"]
            s = ev.get("stream", 0)
            p = ev.get("priority", 0)
            d = ev.get("deadline_ms")
        else:
            seq = tuple(ev)
            t = seq[0]
            s = seq[1] if len(seq) > 1 else 0
            p = seq[2] if len(seq) > 2 else 0
            d = seq[3] if len(seq) > 3 else None
        t = float(t)
        if not np.isfinite(t) or t < 0:
            raise ValueError(f"arrival time must be finite and >= 0: {t}")
        parsed.append((t, int(s), int(p), None if d is None else float(d)))
    parsed.sort(key=lambda e: (e[0], e[1]))
    return [ArrivalEvent(t=t, stream=s, priority=p, deadline_ms=d, index=i)
            for i, (t, s, p, d) in enumerate(parsed)]
