from repro.serving.engine import ServingEngine, greedy_generate

__all__ = ["ServingEngine", "greedy_generate"]
