from repro.serving.engine import ServingEngine, greedy_generate

__all__ = ["ServingEngine", "greedy_generate", "ServingFabric", "Ticket",
           "ProcessServingFabric", "WorkerDied", "FramedChannel",
           "ChannelClosed", "FrameCorruption",
           "FaultPlan", "FaultSpec", "InjectedFault", "ReplicaCrash",
           "random_plan",
           "MetricsRegistry", "Counter", "Gauge", "Histogram"]

_FAULTS = ("FaultPlan", "FaultSpec", "InjectedFault", "ReplicaCrash",
           "random_plan")
_TRANSPORT = ("FramedChannel", "ChannelClosed", "FrameCorruption")
_METRICS = ("MetricsRegistry", "Counter", "Gauge", "Histogram")


def __getattr__(name):
    # lazy: the fabrics build on the controller stack (core.pipeline),
    # which itself serves through this package's engine — importing them
    # eagerly here would close an import cycle during ``repro.core``'s
    # own initialization
    if name in ("ServingFabric", "Ticket"):
        from repro.serving import fabric
        return getattr(fabric, name)
    if name in ("ProcessServingFabric", "WorkerDied"):
        from repro.serving import procfabric
        return getattr(procfabric, name)
    if name in _TRANSPORT:
        from repro.serving import transport
        return getattr(transport, name)
    if name in _FAULTS:
        from repro.serving import faults
        return getattr(faults, name)
    if name in _METRICS:
        from repro.serving import metrics
        return getattr(metrics, name)
    raise AttributeError(name)
