from repro.serving.engine import ServingEngine, greedy_generate

__all__ = ["ServingEngine", "greedy_generate", "ServingFabric", "Ticket",
           "QueueLatencyAutoscaler",
           "ProcessServingFabric", "WorkerDied", "EpochLagDrainPolicy",
           "FramedChannel", "ChannelClosed", "FrameCorruption",
           "FaultPlan", "FaultSpec", "InjectedFault", "ReplicaCrash",
           "random_plan",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "ContinuousBatcher", "Request", "serve_trace",
           "ArrivalEvent", "poisson_trace", "bursty_trace", "trace_replay"]

_FAULTS = ("FaultPlan", "FaultSpec", "InjectedFault", "ReplicaCrash",
           "random_plan")
_TRANSPORT = ("FramedChannel", "ChannelClosed", "FrameCorruption")
_METRICS = ("MetricsRegistry", "Counter", "Gauge", "Histogram")
_SCHEDULER = ("ContinuousBatcher", "Request", "serve_trace")
_LOADGEN = ("ArrivalEvent", "poisson_trace", "bursty_trace",
            "trace_replay")


def __getattr__(name):
    # lazy: the fabrics build on the controller stack (core.pipeline),
    # which itself serves through this package's engine — importing them
    # eagerly here would close an import cycle during ``repro.core``'s
    # own initialization
    if name in ("ServingFabric", "Ticket", "QueueLatencyAutoscaler"):
        from repro.serving import fabric
        return getattr(fabric, name)
    if name in ("ProcessServingFabric", "WorkerDied",
                "EpochLagDrainPolicy"):
        from repro.serving import procfabric
        return getattr(procfabric, name)
    if name in _TRANSPORT:
        from repro.serving import transport
        return getattr(transport, name)
    if name in _FAULTS:
        from repro.serving import faults
        return getattr(faults, name)
    if name in _METRICS:
        from repro.serving import metrics
        return getattr(metrics, name)
    if name in _SCHEDULER:
        from repro.serving import scheduler
        return getattr(scheduler, name)
    if name in _LOADGEN:
        from repro.serving import loadgen
        return getattr(loadgen, name)
    raise AttributeError(name)
