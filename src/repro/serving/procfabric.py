"""Process-per-replica serving fabric — real OS-process isolation behind
the same ``Ticket``/``submit``/``join`` boundary as the threaded fabric.

Topology
--------
The parent process keeps everything *authoritative*: the shared
:class:`repro.core.memory.CommitStream` (store, WAL journal, recovery
manifest), the learn replica that executes every shadow drain, the
logical clock, and the supervision plane. Each **worker** is a separate
OS process holding a serve-only :class:`repro.core.pipeline.MicrobatchRAR`
built from a picklable ``replica_factory`` — its own jit caches, its own
FM tiers, its own GIL. Worker and parent speak the length-prefixed,
crc-framed pickle protocol of :mod:`repro.serving.transport` over a
duplex pipe (byte-for-byte the WAL's record framing).

Message protocol (FIFO per channel, which is what makes the ordering
guarantees below hold):

* parent → worker: ``("serve", dispatch_id, nows, prompts,
  guide_requests, keys, embs)``, ``("epoch", epoch, records,
  soft_clears, touches, n)`` (a commit-stream epoch broadcast — the
  out-of-process analog of the in-process view update),
  ``("ack", dispatch_id)`` (the drain for that batch's "done" has run —
  see below), ``("stop",)``.
* worker → parent: ``("ready", pid)``, ``("hb", seq, epoch)``
  (heartbeat; ``epoch`` is the worker mirror's last applied commit
  epoch, which gives the parent its per-worker commit-lag gauge),
  ``("done", dispatch_id, outcomes, shadow_items, deferred_items,
  engine_delta)``, ``("err", dispatch_id, exc)``.

The **"done" message is the atomic commit point**. A worker has *no*
authoritative side effects before its "done" lands: store writes only
happen in the parent's drain, the clock is advanced by the parent at
submit, and worker-local engine counters ride inside "done" as deltas.
Any death before "done" — SIGKILL mid-batch included — therefore leaves
the system exactly as if the batch was never dispatched, and the
supervisor can redispatch it (with the *same* pre-allocated ``nows``) to
a surviving worker for a byte-identical result. Shadow items funnel back
inside "done" and are re-sequenced into the parent learn replica's
queue, so drain scheduling, coalescing and commit semantics are exactly
the single-process fabric's.

After each "done" the worker blocks until the parent's ``"ack"``: the
parent sends it once the batch's drain has run (and therefore after any
epoch frames that drain broadcast, which FIFO delivers first), so the
next serve a worker executes always sees its predecessors' commits.
That is the serve-after-drain order a *thread* replica gets for free by
draining inline on its own thread — restored across the process
boundary, and what keeps routing byte-identical under arbitrarily deep
pipelined submission, not just paced one-ticket-at-a-time driving.
Every received "done" is acked, including drain-error and stale
(already-redispatched) ones — a worker never waits on an ack that
cannot arrive.

Supervision plane
-----------------
Two failure detectors feed one ``_on_worker_death`` path:

* **EOF** — a dead process (exit, SIGKILL) closes its pipe; the parent's
  per-worker reader thread sees :class:`ChannelClosed` immediately.
* **Lease expiry** — each worker beats every ``lease_interval`` seconds;
  a monitor thread marks a worker ``suspect`` after two missed beats and
  **dead** after ``lease_timeout`` without one — the *hung* worker case
  EOF can never catch. The monitor reads time through
  :meth:`FaultPlan.take_skew`, so injected clock skew perturbs lease
  math deterministically (no wall-clock stalls in tests).

Death handling is idempotent (first detector wins): mark dead, respawn a
fresh worker against the current store snapshot + epoch counters (the
folded equivalent of replaying its CommitStream subscription from the
last broadcast epoch), and redispatch every in-flight ticket under
``RARConfig.max_redispatch``. Respawned workers carry **no fault plan**
— a spent kill spec must not re-fire on the replacement. A "done" that
arrives for an already-redispatched dispatch id (a worker declared dead
by lease expiry that was merely slow) is *dropped* and counted in
``stale_drops`` — a ticket is never completed twice and a batch's
authoritative effects land at most once.

Crash recovery
--------------
``RARConfig.journal_path`` gives the parent the same WAL + snapshot +
epoch-consistent recovery manifest as the threaded fabric (the manifest
additionally carries the accumulated remote engine deltas). Killing the
whole fabric mid-run and rebuilding on the same path resumes serving
byte-identically to an unkilled run — pinned in
``tests/test_procfabric.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing as mp
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decisions
from repro.core import memory as mem
from repro.core.pipeline import MicrobatchRAR
from repro.core.shadow import AdaptiveDrainPolicy
from repro.serving import transport
from repro.serving.fabric import ServingFabric, Ticket
from repro.serving.faults import InjectedFault, ReplicaCrash
from repro.serving.transport import ChannelClosed, FramedChannel


class WorkerDied(RuntimeError):
    """A worker process died and the ticket's redispatch budget is
    exhausted — surfaced at :meth:`Ticket.wait` like any worker error."""


# ---------------------------------------------------------------------------
# Worker side (runs in the child process)
# ---------------------------------------------------------------------------


class _WorkerReplica(MicrobatchRAR):
    """Serve-only controller for one worker process: shadow items are
    *collected* instead of drained (the parent's learn replica owns the
    authoritative drain), and the queue's drain fault site is disabled —
    it fires on the parent's real drain, not the worker's collector."""

    def __init__(self, *args, **kwargs):
        self.collected: list = []
        super().__init__(*args, **kwargs)

    def _shadow_runner(self):
        return self.collected.extend

    def _make_shadow_queue(self):
        q = super()._make_shadow_queue()
        q.fault_plan = None
        return q


def _engine_counters(rep) -> dict:
    """Host-side cost counters of the worker's tiers, for delta
    shipping."""
    out = {}
    for name, tier in (("weak", rep.weak), ("strong", rep.strong)):
        engine = getattr(tier, "engine", None)
        if hasattr(engine, "export_counters"):
            out[name] = engine.export_counters()
    return out


def _counter_delta(cur: dict, prev: dict) -> dict:
    return {name: {k: cur[name][k] - prev.get(name, {}).get(k, 0)
                   for k in cur[name]} for name in cur}


def _worker_main(conn, init: dict) -> None:
    """Child-process entry point: build the serve-only replica from the
    factory, then loop on the channel until "stop" (or the parent
    disappears)."""
    channel = FramedChannel(conn, fault_plan=init["fault_plan"],
                            end="worker", replica=init["index"])
    try:
        _worker_loop(channel, init)
    except ChannelClosed:
        pass                          # parent gone — nothing to report to
    finally:
        channel.close()


def _worker_loop(channel: FramedChannel, init: dict) -> None:
    index = init["index"]
    plan = init["fault_plan"]
    parts = init["factory"]()
    store = jax.tree.map(jnp.asarray, init["store"])
    # local mirror of the parent's commit stream: epoch numbering resumes
    # where the snapshot left off, but ``commits`` restarts at 0 — the
    # snapshot's ring pointer already folds every prior commit into
    # ``_ptr_base`` (counting them again would double ``ptr_snap``)
    stream = mem.CommitStream()
    stream.buffer.epoch = init["epoch"]
    stream.buffer.entries_applied = init["entries"]
    rep = _WorkerReplica(parts["weak"], parts["strong"],
                         parts["embed_fn"], parts["route_weak_fn"],
                         init["cfg"], aligned_fn=parts.get("aligned_fn"),
                         memory=store, commit_stream=stream,
                         fault_plan=plan)

    stop_beat = threading.Event()

    def _beat() -> None:
        seq = 0
        while not stop_beat.is_set():
            if plan is not None:
                try:
                    # a "crash" here kills only this thread: the worker
                    # keeps serving but its lease expires — the
                    # hung-worker case
                    plan.fire("heartbeat", replica=index)
                except InjectedFault:
                    return
            seq += 1
            try:
                # epoch rides along: a plain int read of the mirror's
                # counter — staleness-tolerant (it is a gauge), no lock
                channel.send(("hb", seq, stream.buffer.epoch))
            except ChannelClosed:
                return
            stop_beat.wait(init["lease_interval"])

    channel.send(("ready", os.getpid()))
    threading.Thread(target=_beat, name=f"hb-{index}",
                     daemon=True).start()
    last = _engine_counters(rep)

    backlog: collections.deque = collections.deque()
    while True:
        msg = backlog.popleft() if backlog else channel.recv()
        kind = msg[0]
        if kind == "stop":
            stop_beat.set()
            return
        if kind == "epoch":
            # broadcast drain epochs, coalesced: every epoch frame
            # already queued behind this one folds into a single
            # apply_ops call. Records sort by logical time inside
            # apply_ops and flag ops carry their own pointer snapshots,
            # so the batched apply is byte-identical to applying the
            # epochs one at a time — the same path live drains and WAL
            # recovery use — while amortizing the per-apply dispatch
            # cost across a drain burst.
            _, epoch, records, soft_clears, touches, n = msg
            records = list(records)
            soft_clears = list(soft_clears)
            touches = list(touches)
            while True:
                if backlog:
                    nxt = backlog.popleft()
                elif channel.poll():
                    nxt = channel.recv()
                else:
                    break
                if nxt[0] != "epoch":
                    backlog.appendleft(nxt)
                    break
                _, epoch, more_r, more_s, more_t, m = nxt
                records += more_r
                soft_clears += more_s
                touches += more_t
                n += m
            with stream.lock:
                rep.memory, _ = stream.buffer.apply_ops(
                    rep.memory, records, soft_clears, touches)
                stream.buffer.epoch = epoch
                stream.commits += n
            continue
        # ("serve", dispatch_id, nows, prompts, greqs, keys, embs)
        _, dispatch_id, nows, prompts, greqs, keys, embs = msg
        try:
            if plan is not None:
                # before ANY side effect — a "kill" (SIGKILL) or "crash"
                # (hard exit) here leaves a batch the parent can
                # redispatch byte-identically
                plan.fire("replica_serve", replica=index)
            outcomes = rep.process_batch(prompts, greqs, keys=keys,
                                         embs=embs, nows=nows)
        except ReplicaCrash:
            os._exit(13)              # abrupt death: EOF at the parent
        except BaseException as e:    # noqa: BLE001 — shipped verbatim
            rep.collected.clear()
            rep.deferred_probes = []
            try:
                channel.send(("err", dispatch_id, e))
            except ChannelClosed:
                return
            except Exception:         # unpicklable exception: ship repr
                channel.send(("err", dispatch_id, RuntimeError(repr(e))))
            continue
        # outcome objects are shared between the outcomes list and the
        # shadow/deferred items; ship list indices instead and let the
        # parent rebind, so pickling cannot fork object identity
        out_idx = {id(o): j for j, o in enumerate(outcomes)}
        shadow_items = []
        for it in rep.collected:
            j = out_idx[id(it.outcome)]
            it.outcome = None
            shadow_items.append((j, it))
        # in place: the queue's runner is a bound method of THIS list
        rep.collected.clear()
        deferred_items = []
        for it in rep.deferred_probes:
            j = out_idx.get(id(it.outcome), -1)
            it.outcome = None
            deferred_items.append((j, it))
        rep.deferred_probes = []
        cur = _engine_counters(rep)
        delta, last = _counter_delta(cur, last), cur
        channel.send(("done", dispatch_id, outcomes, shadow_items,
                      deferred_items, delta))
        # serve-after-drain gate: block until the parent acks this
        # batch's drain. Every epoch frame received before the ack is
        # part of (or prior to) that drain, so apply them HERE — serve
        # frames that were already queued ahead of those epochs in the
        # pipe get backlogged and must not run against a stale mirror.
        # The epochs coalesce into one apply, same as the main loop.
        acc_r, acc_s, acc_t = [], [], []
        acc_n, acc_epoch = 0, None
        while True:
            nxt = channel.recv()
            gate_kind = nxt[0]
            if gate_kind == "epoch":
                _, acc_epoch, more_r, more_s, more_t, m = nxt
                acc_r += more_r
                acc_s += more_s
                acc_t += more_t
                acc_n += m
                continue
            if gate_kind == "ack":
                break
            if gate_kind == "stop":
                stop_beat.set()
                return
            backlog.append(nxt)       # serves keep their FIFO order
        if acc_epoch is not None:
            with stream.lock:
                rep.memory, _ = stream.buffer.apply_ops(
                    rep.memory, acc_r, acc_s, acc_t)
                stream.buffer.epoch = acc_epoch
                stream.commits += acc_n


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------


class _WorkerHandle:
    """Parent-side bookkeeping for one worker process."""

    def __init__(self, index: int):
        self.index = index
        self.proc = None
        self.channel: FramedChannel | None = None
        self.reader: threading.Thread | None = None
        self.inflight: dict[int, tuple] = {}   # dispatch_id -> (ticket,
        #                                        payload)
        self.last_beat = time.monotonic()
        self.ready = threading.Event()
        self.alive = False
        self.pid: int | None = None
        self.epoch: int | None = None  # last commit epoch the worker
        #                                reported (via heartbeat)


class EpochLagDrainPolicy(AdaptiveDrainPolicy):
    """Adaptive drain cadence for the process fabric's parent learn
    plane, driven by the per-worker **commit-epoch lag** the heartbeats
    already ship (``("hb", seq, epoch)`` → ``_WorkerHandle.epoch``).

    In the process fabric every drain's commits must rebroadcast to the
    worker mirrors, so the broadcast plane's state is the signal that
    matters — not just the global pending count the base policy sees:

    - lag ``0`` (every live worker has applied the authoritative
      epoch): the broadcast plane is idle, a drain ships its epoch at
      minimum staleness — drain **eagerly**;
    - lag ``>= defer_lag`` batches behind: workers are still chewing on
      earlier broadcasts; piling another epoch on the wire only grows
      the mirror gap — **defer** (the queue-level ``shadow_flush_every``
      hard cap still bounds staleness independently of this policy);
    - in between: fall through to the fitted drain-cost model.

    The lag read is a lock-free heuristic over heartbeat state: a torn
    read can only skew one cadence decision, never correctness — the
    drain itself serializes on the parent's locks as always.
    """

    def __init__(self, lag_fn, *, defer_lag: int = 4, **kwargs):
        super().__init__(**kwargs)
        if defer_lag < 1:
            raise ValueError(f"defer_lag must be >= 1, got {defer_lag}")
        self._lag_fn = lag_fn
        self.defer_lag = defer_lag
        self.lag_eager_drains = 0
        self.lag_deferrals = 0

    def due(self) -> bool:
        if self.pending_items() == 0:
            self.decisions += 1
            return False
        lag = self._lag_fn()
        if lag >= self.defer_lag:
            self.decisions += 1
            self.lag_deferrals += 1
            return False
        if lag == 0:
            self.decisions += 1
            self.lag_eager_drains += 1
            return True
        return super().due()

    def stats(self) -> dict:
        s = super().stats()
        s.update({
            "worker_epoch_lag": self._lag_fn(),
            "defer_lag": self.defer_lag,
            "lag_eager_drains": self.lag_eager_drains,
            "lag_deferrals": self.lag_deferrals,
        })
        return s


class ProcessServingFabric(ServingFabric):
    """Process-per-replica fabric (see module doc).

    ``replica_factory`` must be picklable (a module-level function or a
    ``functools.partial`` of one) and return a dict with keys ``weak``,
    ``strong``, ``embed_fn``, ``route_weak_fn`` and optionally
    ``aligned_fn`` — it is called once in the parent (learn plane) and
    once inside every worker process (serve plane), so a deterministic
    factory yields identical tiers on both sides.
    """

    def __init__(self, replica_factory, cfg=None, *, workers: int = 1,
                 fault_plan=None, lease_interval: float = 0.25,
                 lease_timeout: float = 5.0, start_method: str = "spawn"):
        if workers < 1:
            raise ValueError(f"workers={workers} must be >= 1")
        if lease_timeout <= lease_interval:
            raise ValueError(
                f"lease_timeout={lease_timeout} must exceed "
                f"lease_interval={lease_interval}")
        # referenced by the _manifest_state/_restore_manifest overrides,
        # which super().__init__ may call during journal recovery
        self._remote_engine: dict[str, dict] = {}
        self.stale_drops = 0
        self.lease_expiries = 0
        parts = replica_factory()
        super().__init__(parts["weak"], parts["strong"],
                         parts["embed_fn"], parts["route_weak_fn"],
                         cfg, replicas=1,
                         aligned_fn=parts.get("aligned_fn"),
                         fault_plan=fault_plan)
        self.replica_factory = replica_factory
        # re-entrant: _on_done holds it across the learn-plane rebind
        # AND the inline drain it may trigger (which re-acquires it via
        # ServingFabric._drain)
        self._drain_lock = threading.RLock()
        self.n_workers = workers
        self.lease_interval = lease_interval
        self.lease_timeout = lease_timeout
        self._ctx = mp.get_context(start_method)
        # workers must never journal, never drain, never defer drains:
        # the parent owns every authoritative effect
        self._worker_cfg = dataclasses.replace(
            self.cfg, journal_path=None, shadow_mode="inline",
            shadow_flush_every=1, shadow_dedup_sim=None)
        self.health = ["healthy"] * workers
        self._handles: list[_WorkerHandle] = []
        if self.cfg.shadow_mode == "adaptive":
            # the parent learn plane is the only drainer here, and every
            # drain's commits must rebroadcast to the workers — so the
            # cadence decision should see the broadcast plane's state
            # (per-worker commit-epoch lag from heartbeats), not just
            # the global pending count the thread fabric looks at
            policy = EpochLagDrainPolicy(self._max_worker_epoch_lag)
            policy.register(self.learn.shadow)
            self.learn.shadow.drain_policy = policy
            self.drain_policy = policy
        self._did = 0                 # dispatch-id allocator
        self._closed = False
        self.commit_stream.ops_listener = self._broadcast_ops
        with self._dispatch_lock:
            for i in range(workers):
                self._handles.append(self._spawn_locked(i, fault_plan))
        self._stop_monitor = threading.Event()
        self._monitor_thread = threading.Thread(
            target=self._monitor, name="lease-monitor", daemon=True)
        self._monitor_thread.start()

    # -- spawning ---------------------------------------------------------
    def _spawn_locked(self, index: int, fault_plan) -> _WorkerHandle:
        """Start worker ``index`` against the current authoritative store
        (snapshot + epoch counters — the folded equivalent of a full
        CommitStream replay). Called under ``_dispatch_lock``."""
        handle = _WorkerHandle(index)
        parent_conn, worker_conn = transport.channel_pair(self._ctx)
        handle.channel = FramedChannel(parent_conn,
                                       fault_plan=self.fault_plan,
                                       end="parent", replica=index)
        with self.commit_stream.lock:
            # ship the raw backing store across the process boundary —
            # an IVF-wrapped store unwraps here and the worker's
            # controller re-wraps (and re-indexes) from its cfg
            from repro.core.memory_ivf import IVFMemory
            snap = self.learn.memory
            if isinstance(snap, IVFMemory):
                snap = snap.store
            init = {
                "index": index,
                "factory": self.replica_factory,
                "cfg": self._worker_cfg,
                "store": jax.device_get(snap),
                "epoch": self.commit_stream.buffer.epoch,
                "entries": self.commit_stream.buffer.entries_applied,
                "fault_plan": fault_plan,
                "lease_interval": self.lease_interval,
            }
        handle.proc = self._ctx.Process(
            target=_worker_main, args=(worker_conn, init),
            name=f"serve-worker-{index}", daemon=True)
        handle.proc.start()
        worker_conn.close()           # parent drops its copy: EOF works
        handle.alive = True
        handle.epoch = init["epoch"]  # mirror starts at the snapshot
        handle.last_beat = time.monotonic()
        handle.reader = threading.Thread(
            target=self._reader, args=(handle,),
            name=f"reader-{index}", daemon=True)
        handle.reader.start()
        return handle

    # -- epoch broadcast --------------------------------------------------
    def _broadcast_ops(self, epoch, records, soft_clears, touches,
                       n) -> None:
        """Commit-stream tap (called under the stream lock after every
        applied epoch): forward the epoch's ops to every live worker —
        the cross-process analog of the in-process view broadcast. FIFO
        channel ordering guarantees a worker applies epoch k before any
        serve dispatched after k."""
        host_records = [(now, np.asarray(e), np.asarray(g, np.int32),
                         hg, hard) for now, e, g, hg, hard in records]
        msg = ("epoch", epoch, host_records, list(soft_clears),
               list(touches), n)
        data = transport.frame_message(msg)   # pickle once, fan out bytes
        for handle in self._handles:
            if handle.alive:
                try:
                    handle.channel.send_raw(data)
                except ChannelClosed:
                    pass              # the reader declares the death

    # -- dispatch ---------------------------------------------------------
    def submit(self, prompts, guide_requests, keys=None, embs=None,
               replica: int | None = None) -> Ticket:
        """Dispatch one microbatch to a worker process. Logical time is
        allocated *here*, at admission — a redispatch after a worker
        death reuses the same stamps, which is the byte-identity
        anchor."""
        if self._closed:
            raise RuntimeError("fabric is closed")
        with self._dispatch_lock:
            nows = self.clock.advance(len(prompts))
            if replica is None:
                for _ in range(self.n_workers):
                    replica = self._rr % self.n_workers
                    self._rr += 1
                    if self.health[replica] != "dead":
                        break
                if self.health[replica] == "dead":
                    # every slot is transiently marked dead: the old
                    # fall-through dispatched to whichever dead slot the
                    # pointer stopped on, orphaning the ticket on a
                    # handle the death path had already drained. Prefer
                    # a slot whose handle is live (just respawned);
                    # revive the chosen slot under the held dispatch
                    # lock if none is.
                    for off in range(self.n_workers):
                        j = (replica + off) % self.n_workers
                        if self._handles[j].alive:
                            replica = j
                            break
                    if not self._handles[replica].alive:
                        self._handles[replica] = self._spawn_locked(
                            replica, None)
                        self.restarts += 1
                    self.health[replica] = "healthy"
            ticket = Ticket(replica=replica)
            self._tickets.append(ticket)
            payload = (nows, prompts, guide_requests, keys, embs)
            self._dispatch_locked(self._handles[replica], ticket, payload)
        return ticket

    def _dispatch_locked(self, handle: _WorkerHandle, ticket: Ticket,
                         payload) -> None:
        self._did += 1
        handle.inflight[self._did] = (ticket, payload)
        try:
            handle.channel.send(("serve", self._did) + payload)
        except ChannelClosed:
            pass    # stays inflight; the death path redispatches it

    # -- reader / completion ----------------------------------------------
    def _reader(self, handle: _WorkerHandle) -> None:
        while True:
            try:
                msg = handle.channel.recv()
            except transport.ChannelError:
                if handle.alive:
                    self._on_worker_death(handle, "channel closed")
                return
            kind = msg[0]
            if kind == "ready":
                handle.pid = msg[1]
                handle.last_beat = time.monotonic()
                handle.ready.set()
            elif kind == "hb":
                handle.last_beat = time.monotonic()
                if len(msg) > 2:      # epoch-carrying heartbeat
                    handle.epoch = msg[2]
            elif kind == "done":
                handle.last_beat = time.monotonic()
                self._on_done(handle, *msg[1:])
            elif kind == "err":
                self._on_err(handle, msg[1], msg[2])

    def _on_done(self, handle: _WorkerHandle, dispatch_id: int,
                 outcomes, shadow_items, deferred_items,
                 engine_delta) -> None:
        """The batch's atomic commit point: rebind its shadow/deferred
        items into the learn plane, account the worker's engine delta,
        resolve the ticket. A dispatch id the handle no longer carries
        means the supervisor already redispatched the batch (lease-
        expired-but-alive worker) — dropped, never double-applied."""
        with self._dispatch_lock:
            entry = handle.inflight.pop(dispatch_id, None)
            if entry is None:
                self.stale_drops += 1
            else:
                ticket, _ = entry
                for name, delta in engine_delta.items():
                    acc = self._remote_engine.setdefault(
                        name, {"calls": 0, "tokens_processed": 0})
                    for k, v in delta.items():
                        acc[k] = acc.get(k, 0) + v
        if entry is None:
            # stale (already redispatched) — still ack: the sender, if
            # it is somehow alive on this channel, must not wait forever
            self._ack(handle, dispatch_id)
            return
        learn = self.learn
        ticket.outcomes = outcomes
        try:
            # the drain lock (re-entrant) serializes concurrent readers
            # across seq allocation AND the inline drain submit may run
            with self._drain_lock:
                items = []
                for idx, it in shadow_items:
                    it.outcome = outcomes[idx]
                    it.seq = learn.shadow.next_seq()
                    items.append(it)
                for idx, it in deferred_items:
                    if idx >= 0:
                        it.outcome = outcomes[idx]
                    it.seq = learn.shadow.next_seq()
                    learn.deferred_probes.append(it)
                    learn.probes_deferred += 1
                # always submitted (even empty) so deferred/async flush
                # cadence counts batches exactly like the threaded fabric
                learn.shadow.submit(items)
        except BaseException as e:    # drain faults surface on the ticket
            ticket.error = e
            self._ack(handle, dispatch_id)
            ticket._done.set()
            return
        degraded = any(o.case in decisions.DEGRADED_CASES
                       for o in outcomes)
        if self.health[handle.index] != "dead":
            self.health[handle.index] = ("suspect" if degraded
                                         else "healthy")
        # ack AFTER the drain (and its epoch broadcasts): FIFO delivery
        # of epochs-then-ack is the worker's serve-after-drain gate
        self._ack(handle, dispatch_id)
        ticket._done.set()

    def _ack(self, handle: _WorkerHandle, dispatch_id: int) -> None:
        """Release the worker's serve-after-drain gate. Sent on *every*
        done path — commit, drain error, stale drop — so a worker never
        blocks on an ack that will not come."""
        try:
            handle.channel.send(("ack", dispatch_id))
        except ChannelClosed:
            pass                      # the reader declares the death

    def _on_err(self, handle: _WorkerHandle, dispatch_id: int,
                exc: BaseException) -> None:
        """An application error inside the worker's serve — surfaced at
        the ticket, NOT redispatched (parity with the threaded fabric:
        only crashes known to precede all side effects are re-run)."""
        with self._dispatch_lock:
            entry = handle.inflight.pop(dispatch_id, None)
        if entry is None:
            self.stale_drops += 1
            return
        ticket, _ = entry
        ticket.error = exc
        ticket._done.set()

    # -- supervision ------------------------------------------------------
    def _on_worker_death(self, handle: _WorkerHandle,
                         reason: str) -> None:
        """First detector (EOF reader or lease monitor) wins; the rest
        no-op. Mark dead, respawn the slot against the current
        authoritative store, redispatch in-flight work under the budget,
        then reap the corpse outside the lock."""
        with self._dispatch_lock:
            if not handle.alive or self._closed:
                return
            handle.alive = False
            i = handle.index
            self.health[i] = "dead"
            self.deaths += 1
            inflight = sorted(handle.inflight.items())
            handle.inflight = {}
            # fresh worker, no fault plan: a spent kill spec must not
            # re-fire on the replacement
            self._handles[i] = self._spawn_locked(i, None)
            self.health[i] = "healthy"
            self.restarts += 1
            for _, (ticket, payload) in inflight:
                if ticket.redispatches < self.cfg.max_redispatch:
                    ticket.redispatches += 1
                    self.redispatches += 1
                    target = self._pick_live_locked(exclude=i)
                    ticket.replica = target
                    self._dispatch_locked(self._handles[target], ticket,
                                          payload)
                else:
                    ticket.error = WorkerDied(
                        f"worker {i} died ({reason}); redispatch budget "
                        f"({self.cfg.max_redispatch}) exhausted")
                    ticket._done.set()
        if handle.proc is not None and handle.proc.is_alive():
            handle.proc.kill()
            handle.proc.join(timeout=5)
        handle.channel.close()

    def _pick_live_locked(self, exclude: int) -> int:
        n = self.n_workers
        for off in range(1, n):
            j = (exclude + off) % n
            if self.health[j] != "dead":
                return j
        return exclude                # its slot was just respawned

    def _monitor(self) -> None:
        while not self._stop_monitor.wait(self.lease_interval / 2):
            ready = [h for h in list(self._handles)
                     if h.alive and h.ready.is_set()]
            if not ready:
                continue
            skew = 0.0
            if self.fault_plan is not None:
                # a transient spike in the monitor's view of time for
                # THIS sample (sampled only once a worker is beating, so
                # a planned spike always lands on live lease math)
                skew = self.fault_plan.take_skew("clock_skew")
            now = time.monotonic() + skew
            for handle in ready:
                overdue = now - handle.last_beat
                if overdue > self.lease_timeout:
                    self.lease_expiries += 1
                    self._on_worker_death(
                        handle, f"lease expired ({overdue:.2f}s without "
                                f"a heartbeat)")
                elif overdue > 2 * self.lease_interval and \
                        self.health[handle.index] == "healthy":
                    self.health[handle.index] = "suspect"

    # -- lifecycle --------------------------------------------------------
    def close_shadow(self) -> None:
        """Flush, stop the workers cleanly, close the learn plane, then
        checkpoint the manifest (after the final replay's epochs).
        Idempotent."""
        if self._closed:
            return
        self.flush_shadow()
        self._stop_monitor.set()
        with self._dispatch_lock:
            self._closed = True
            live = [h for h in self._handles if h.alive]
            for handle in live:
                handle.alive = False
        for handle in live:
            try:
                handle.channel.send(("stop",))
            except transport.ChannelError:
                pass
        for handle in live:
            if handle.proc is not None:
                handle.proc.join(timeout=30)
                if handle.proc.is_alive():
                    handle.proc.kill()
                    handle.proc.join(timeout=5)
            handle.channel.close()
        self.learn.close_shadow()
        self.commit_stream.checkpoint()

    close = close_shadow

    def kill(self) -> None:
        """Abandon everything without flushing or checkpointing — the
        whole-fabric crash the recovery tests simulate. The journal's
        per-epoch fsyncs are already durable; recovery rebuilds from
        them."""
        self._stop_monitor.set()
        with self._dispatch_lock:
            self._closed = True
            handles = [h for h in self._handles if h.alive]
            for handle in handles:
                handle.alive = False
        for handle in handles:
            if handle.proc is not None and handle.proc.is_alive():
                handle.proc.kill()
                handle.proc.join(timeout=5)
            handle.channel.close()
        if self.commit_stream.journal is not None:
            self.commit_stream.journal.close()

    # -- manifest / accounting --------------------------------------------
    def _manifest_state(self) -> dict:
        man = super()._manifest_state()
        man["remote_engines"] = {name: dict(acc) for name, acc
                                 in self._remote_engine.items()}
        return man

    def _restore_manifest(self, man: dict) -> None:
        super()._restore_manifest(man)
        self._remote_engine = {name: dict(acc) for name, acc
                               in man.get("remote_engines", {}).items()}

    def engine_calls(self, name: str) -> int:
        """Total inference calls of one tier across the parent (drain
        plane) and every worker ever alive (serve plane, via shipped
        deltas) — the fabric-wide RAR cost metric."""
        tier = {"weak": self.learn.weak,
                "strong": self.learn.strong}[name]
        engine = getattr(tier, "engine", None)
        local = getattr(engine, "calls", 0) if engine is not None else 0
        return local + self._remote_engine.get(name, {}).get("calls", 0)

    def _max_worker_epoch_lag(self) -> int:
        """Worst-case commit-epoch lag across live workers (0 until the
        first heartbeat reports an epoch). Lock-free: heartbeat state is
        monotone per worker and a stale read only skews one drain-
        cadence decision."""
        epoch = self.commit_stream.buffer.epoch
        lag = 0
        for h in self._handles:
            if h.alive and h.epoch is not None:
                lag = max(lag, epoch - h.epoch)
        return lag

    def metrics(self) -> dict:
        """Parent-plane metrics plus the worker plane: per-worker health,
        in-flight depth and commit-epoch lag (authoritative epoch minus
        the worker mirror's last heartbeat-reported epoch), transport
        frame counters, stale drops and lease expiries. Host-side
        counters only — no device syncs."""
        m = super().metrics()
        epoch = self.commit_stream.buffer.epoch
        with self._dispatch_lock:
            m["workers"] = [{
                "worker": h.index,
                "health": self.health[h.index],
                "alive": h.alive,
                "inflight": len(h.inflight),
                "commit_epoch_seen": h.epoch,
                "commit_epoch_lag": (max(0, epoch - h.epoch)
                                     if h.epoch is not None else None),
            } for h in self._handles]
            m["transport"] = {
                "frames_sent": sum(h.channel.sent
                                   for h in self._handles),
                "frames_received": sum(h.channel.received
                                       for h in self._handles),
            }
            m["stale_drops"] = self.stale_drops
            m["lease_expiries"] = self.lease_expiries
        return m

    def stats(self) -> dict:
        s = super().stats()
        s.update({
            "workers": self.n_workers,
            "transport": {
                "frames_sent": sum(h.channel.sent
                                   for h in self._handles),
                "frames_received": sum(h.channel.received
                                       for h in self._handles),
            },
            "stale_drops": self.stale_drops,
            "lease_expiries": self.lease_expiries,
            "remote_engines": {name: dict(acc) for name, acc
                               in self._remote_engine.items()},
        })
        return s
