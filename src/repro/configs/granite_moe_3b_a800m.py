"""Granite-MoE-3B-A800M [hf:ibm-granite/granite-3.0 family] — 32L MoE,
40 experts top-8, per-expert d_ff 512.

Notes: 24 heads and 40 experts do **not** divide the 16-way model axis —
the divisibility-fallback sharding rules route TP through d_model / d_ff
instead (see models/sharding.py); this config is the stress test for them.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                  # per expert
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base] 32 experts top-8 family",
)

SMOKE = dataclasses.replace(
    FULL, name="granite-moe-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=64, vocab_size=512, num_experts=4,
    experts_per_token=2, moe_capacity_factor=8.0, remat=False,
    param_dtype="float32")
