"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin hybrid: RG-LRU recurrent
blocks and local attention in a 2:1 pattern (r, r, a), window 2048,
MQA (kv=1, head_dim 256), d_rnn = 2560.

Hybrid tier for RAR: recurrent state keeps decode O(1) on most layers;
long_500k runs natively.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("r", "r", "a"),
    window_pattern=(2048,),    # local attention on the attention layers
    d_rnn=2560,
    d_conv=4,
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[arXiv:2402.19427] RG-LRU + local attn, 1:2",
)

SMOKE = dataclasses.replace(
    FULL, name="recurrentgemma-smoke", num_layers=3, d_model=128,
    num_heads=4, num_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
    window_pattern=(16,), d_rnn=128, remat=False, param_dtype="float32")
