"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSM with SSD
(state-space duality). d_inner = 2×2560 = 5120, 80 heads of 64, state 128.

Natural *weak/edge* tier for RAR: O(1) decode state, no KV cache —
long_500k runs natively.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=1,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    d_conv=4,
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[arXiv:2405.21060] SSD (state-space duality)",
)

SMOKE = dataclasses.replace(
    FULL, name="mamba2-2.7b-smoke", num_layers=2, d_model=128,
    vocab_size=512, ssm_state=16, ssm_head_dim=32, ssm_chunk=16, remat=False, param_dtype="float32")
