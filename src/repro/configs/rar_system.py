"""The RAR evaluation system configs — the paper's own experiment models.

Analog mapping (paper → this framework):

* Mistral-7B-instruct (weak FM)  → ``WEAK``: 3-layer dense transformer
  trained on a *subset* of skills unaided + guide-following in-context.
* GPT-4o / Llama-3-70B (strong)  → ``STRONG``: 6-layer dense transformer
  trained on all skills + guide generation.
* all-MiniLM-L12-v2 (embedder)   → ``EMBEDDER``: 4-layer contrastive
  encoder, 384-d output, cosine indexing.

The cost asymmetry the router exploits is real: STRONG is ~9× the FLOPs
of WEAK per token. At production scale any zoo architecture
(``repro.configs.get(...)``) slots into either tier; these tiny instances
exist so the full e2e evaluation runs on CPU.
"""
import dataclasses

from repro.core.embedder import EmbedderConfig
from repro.core.rar import RARConfig
from repro.data.tokenizer import Vocab
from repro.models.config import ModelConfig

_VOCAB = Vocab(n_domains=3)

WEAK = ModelConfig(
    name="rar-weak",
    family="dense",
    num_layers=3,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=384,
    vocab_size=_VOCAB.size,
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat=False,
    param_dtype="float32",
    source="paper-analog: Mistral-7B (weak tier)",
)

STRONG = ModelConfig(
    name="rar-strong",
    family="dense",
    num_layers=4,
    d_model=192,
    num_heads=6,
    num_kv_heads=6,
    head_dim=32,
    d_ff=576,
    vocab_size=_VOCAB.size,
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat=False,
    param_dtype="float32",
    source="paper-analog: gpt-4o / Llama-3-70B (strong tier)",
)

EMBEDDER = EmbedderConfig(
    vocab_size=_VOCAB.size,
    d_model=128,
    num_layers=4,
    num_heads=4,
    d_ff=256,
    embed_dim=384,
)

FULL = STRONG  # registry convention
SMOKE = dataclasses.replace(WEAK, name="rar-weak-smoke", num_layers=2)


def make_rar_config(*, sim_threshold: float = 0.6,
                    guide_sim_threshold: float | None = None,
                    retrieval_k: int = 1, max_guides: int | None = None,
                    shadow_mode: str = "inline",
                    shadow_flush_every: int | None = None,
                    shadow_dedup_sim: float | None = None,
                    retrieval_clusters: int = 0,
                    retrieval_probes: int = 4,
                    **kw) -> RARConfig:
    """The system's RARConfig defaults in one place (thresholds calibrated
    to ``EMBEDDER``, see :class:`repro.core.rar.RARConfig`). The
    multi-guide knobs plumb straight through: ``retrieval_k`` widens every
    memory read to the top-k entries and ``max_guides`` (default: follow
    retrieval_k) caps how many retrieved guides are spliced into the weak
    FM's prompt. ``shadow_mode``/``shadow_flush_every``/
    ``shadow_dedup_sim`` schedule the shadow plane (inline per batch,
    deferred at barriers, or on a background drainer thread, with
    optional near-duplicate coalescing before each drain —
    :mod:`repro.core.shadow`); the flush cadence defaults to every batch
    and coalescing defaults to off. ``retrieval_clusters``/
    ``retrieval_probes`` turn on the two-level (IVF) retrieval plane —
    0 clusters (the default) keeps the exact store scan
    (:mod:`repro.core.memory_ivf`). Used by ``launch.serve`` and the
    experiment stages so the serving CLI and the evaluation suite can't
    drift apart."""
    if guide_sim_threshold is None:
        guide_sim_threshold = sim_threshold
    if max_guides is None:
        max_guides = retrieval_k
    if shadow_flush_every is None:
        shadow_flush_every = 1
    return RARConfig(sim_threshold=sim_threshold,
                     guide_sim_threshold=guide_sim_threshold,
                     retrieval_k=retrieval_k, max_guides=max_guides,
                     shadow_mode=shadow_mode,
                     shadow_flush_every=shadow_flush_every,
                     shadow_dedup_sim=shadow_dedup_sim,
                     retrieval_clusters=retrieval_clusters,
                     retrieval_probes=retrieval_probes,
                     **kw)
