"""Whisper-medium [arXiv:2212.04356] — encoder-decoder; the conv/mel
frontend is a STUB per the brief: ``input_specs`` provides precomputed
frame embeddings (B, 1500, d_model); we implement the transformer
encoder + decoder backbone.

Adaptations recorded in DESIGN.md: RoPE instead of learned positions,
RMSNorm instead of biased LayerNorm (TPU-idiomatic conventions; dims are
the assigned whisper-medium dims). long_500k is skipped for this arch —
a 500k-token decoder cache contradicts the enc-dec design (448-token
decoder context in the source model).
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,             # decoder layers
    encoder_layers=24,
    encoder_frames=1500,       # 30 s of audio after the (stubbed) conv stack
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    gated_mlp=False,           # whisper uses plain GELU MLPs
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[arXiv:2212.04356] enc-dec, conv frontend (stub)",
)

SMOKE = dataclasses.replace(
    FULL, name="whisper-medium-smoke", num_layers=2, encoder_layers=2,
    encoder_frames=16, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512, remat=False, param_dtype="float32")
