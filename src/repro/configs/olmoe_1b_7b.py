"""OLMoE-1B-7B [arXiv:2409.02060] — 16L MoE, 64 experts top-8,
per-expert d_ff 1024. 64 experts divide the model axis exactly →
clean expert parallelism (4 experts per device group)."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,                 # per expert
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    tie_embeddings=False,
    source="[arXiv:2409.02060] 64 experts top-8",
)

SMOKE = dataclasses.replace(
    FULL, name="olmoe-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, head_dim=32, d_ff=64, vocab_size=512, num_experts=4,
    experts_per_token=2, moe_capacity_factor=8.0, remat=False,
    param_dtype="float32")
