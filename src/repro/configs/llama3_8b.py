"""Llama-3-8B [arXiv:2407.21783] — dense GQA decoder, 128k vocab.

The paper's own evaluation uses Llama-3-70B-instruct as one of its *strong*
FMs; the 8B sibling is the assigned pool config and slots into RAR as
either tier.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm_type="rmsnorm",
    tie_embeddings=False,
    source="[arXiv:2407.21783] GQA, 128k vocab",
)

SMOKE = dataclasses.replace(
    FULL, name="llama3-8b-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, remat=False, param_dtype="float32")
