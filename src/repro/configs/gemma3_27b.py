"""Gemma-3-27B [hf:google/gemma-3-1b-pt family] — dense GQA with 5:1
local:global attention (window 1024 local layers), 128k context, 256k vocab.

The 5:1 interleave rides through the layer scan as a per-layer window array;
the §Perf log shows the static-window superblock variant.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),  # 5 local : 1 global
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[hf:google/gemma-3-1b-pt] 5:1 local:global, 128k context",
)

SMOKE = dataclasses.replace(
    FULL, name="gemma3-27b-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    window_pattern=(8, 0), remat=False, param_dtype="float32")
