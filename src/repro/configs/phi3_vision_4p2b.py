"""Phi-3-Vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
language backbone + CLIP vision tower (STUB per the brief: ``input_specs``
provides precomputed patch embeddings at d_model; we implement the decoder
that consumes them).
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_patches=256,          # stub vision frontend output length
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    tie_embeddings=False,
    source="[hf:microsoft/Phi-3-vision-128k-instruct] phi3-mini + CLIP (stub)",
)

SMOKE = dataclasses.replace(
    FULL, name="phi-3-vision-4.2b-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    num_patches=8, remat=False, param_dtype="float32")
