"""Architecture registry.

Each module defines ``FULL`` (the assigned production config, exact dims
from the pool spec) and ``SMOKE`` (a reduced same-family variant: ≤2 layers,
d_model ≤ 512, ≤4 experts) used by CPU tests. ``get(name)`` /
``get_smoke(name)`` look them up; ``--arch <id>`` in the launchers resolves
through here.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "granite_moe_3b_a800m",
    "gemma3_27b",
    "mamba2_2p7b",
    "deepseek_coder_33b",
    "phi3_vision_4p2b",
    "olmoe_1b_7b",
    "recurrentgemma_2b",
    "olmo_1b",
    "whisper_medium",
    "llama3_8b",
)

# public ids (dashes) → module names
ALIASES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "gemma3-27b": "gemma3_27b",
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "olmo-1b": "olmo_1b",
    "whisper-medium": "whisper_medium",
    "llama3-8b": "llama3_8b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_archs() -> list[str]:
    return list(ALIASES.keys())
