"""OLMo-1B [arXiv:2402.00838] — dense decoder with **non-parametric
LayerNorm** (no learned scale/bias)."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    rope_theta=10_000.0,
    norm_type="nonparametric_ln",
    tie_embeddings=True,
    source="[arXiv:2402.00838] non-parametric LN",
)

SMOKE = dataclasses.replace(
    FULL, name="olmo-1b-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512, remat=False, param_dtype="float32")
