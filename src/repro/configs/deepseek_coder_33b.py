"""DeepSeek-Coder-33B [arXiv:2401.14196] — llama-architecture dense GQA.

Largest dense config in the pool; the FSDP-vs-TP sharding split matters
most here (33B params → AdamW state must shard over both mesh axes).
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    norm_type="rmsnorm",
    tie_embeddings=False,
    source="[arXiv:2401.14196] llama-arch",
)

SMOKE = dataclasses.replace(
    FULL, name="deepseek-coder-33b-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    remat=False, param_dtype="float32")
