"""Roofline term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis()`` on the SPMD-partitioned executable reports per-device
flops/bytes, so per-device / per-chip-peak is exactly the brief's global
formula. collective_bytes is **not** in cost_analysis — we parse the
optimized post-partition HLO and sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,512,1024]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)[^=]*?\b(" + "|".join(_COLLECTIVES) +
    r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes (per device) from optimized HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-done(" in line:   # async pairs: count the -start only
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                # tuple holds (operand, result) for async starts; count once
                out[kind] += _shape_bytes(dtype, dims) // 2 * 2
            out[kind] //= 2
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: dict
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collectives": self.collectives,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    # sum every "bytes accessed..." key (operands + outputs)
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=float(sum(coll.values())),
        collectives=coll,
    )
