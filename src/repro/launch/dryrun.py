import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) pair this lowers + compiles the
real step function (train_step / prefill / serve_step) against the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — from
ShapeDtypeStruct stand-ins (no allocation), prints
``compiled.memory_analysis()`` (fits?) and ``compiled.cost_analysis()``
(roofline terms), and appends a JSON record consumed by
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse   # noqa: E402
import json
import time
import traceback
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import specs as SP
from repro.launch.analytic import analytic_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.models import decode_step, prefill
from repro.models import sharding as shd
from repro.training import make_train_step

DEFAULT_OUT = "experiments/dryrun_results.json"


def _logit_sharding(mesh, logits_shape):
    """Batch on data axes when divisible; vocab on model when divisible."""
    b = shd.batch_axes(mesh)
    spec = shd.spec_from_prefs(logits_shape, [(0, b), (1, "model")], mesh)
    return NamedSharding(mesh, spec)


def _apply_overrides(cfg, overrides: dict | None):
    """--set key=value config overrides (perf variants, §Perf log)."""
    if not overrides:
        return cfg
    import dataclasses as _dc
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return _dc.replace(cfg, **typed)


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               donate: bool = True, overrides: dict | None = None):
    """Lower + compile one (arch, shape, mesh) combination.

    Returns (compiled, lowered, spec, mesh)."""
    cfg0 = _apply_overrides(configs.get(arch), overrides)
    spec = SP.input_specs(cfg0, shape_name)
    cfg = spec["cfg"]
    mesh = make_production_mesh(multi_pod=multi_pod)

    with mesh:
        mode = "train" if spec["kind"] == "train" else "serve"
        p_shard = shd.param_shardings(spec["params"], mesh, mode)

        if spec["kind"] == "train":
            step = make_train_step(cfg, grad_accum=spec["grad_accum"],
                                   batch_axes=shd.batch_axes(mesh))
            o_shard = shd.param_shardings(spec["opt_state"], mesh, mode)
            b_shard = shd.batch_shardings(spec["batch"], mesh)
            metrics_shard = jax.tree.map(
                lambda _: shd.replicated(mesh),
                {"ce": 0, "aux": 0, "accuracy": 0, "loss": 0, "lr": 0,
                 "grad_norm": 0})
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, metrics_shard),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(spec["params"], spec["opt_state"],
                                   spec["batch"])

        elif spec["kind"] == "prefill":
            fn = partial(prefill, cfg, max_len=spec["max_len"])
            b_shard = shd.batch_shardings(spec["batch"], mesh)
            out_shape = jax.eval_shape(fn, spec["params"], spec["batch"])
            c_shard = shd.cache_shardings(out_shape[1], mesh)
            out_shard = (_logit_sharding(mesh, out_shape[0].shape), c_shard,
                         shd.replicated(mesh))
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                             out_shardings=out_shard)
            lowered = jitted.lower(spec["params"], spec["batch"])

        else:  # decode — serve_step: ONE token against a seq_len cache
            fn = partial(decode_step, cfg)
            c_shard = shd.cache_shardings(spec["cache"], mesh)
            t_shard = shd.batch_shardings(spec["tokens"], mesh)
            B = spec["tokens"].shape[0]
            out_shard = (_logit_sharding(mesh, (B, cfg.vocab_size)), c_shard)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, t_shard, c_shard,
                              shd.replicated(mesh)),
                out_shardings=out_shard,
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(spec["params"], spec["tokens"],
                                   spec["cache"], spec["pos"])

        compiled = lowered.compile()
    return compiled, lowered, spec, mesh


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            verbose: bool = True, overrides: dict | None = None,
            variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    if not SP.supported(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant, "status": "skipped",
                "reason": "architectural (see DESIGN.md §7)"}
    t0 = time.perf_counter()
    try:
        compiled, lowered, spec, mesh = lower_pair(
            arch, shape_name, multi_pod=multi_pod, overrides=overrides)
    except Exception as e:  # a failure here is a bug in the system
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant,
                "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    roof = analyze(compiled)
    n_chips = 512 if multi_pod else 256
    model_flops = (6.0 * spec["cfg"].active_param_count() *
                   _tokens_processed(spec)) / n_chips
    from repro.launch.specs import INPUT_SHAPES
    seq, gbatch, _ = INPUT_SHAPES[shape_name]
    ana = analytic_terms(spec["cfg"], spec["kind"], gbatch, seq, n_chips,
                         spec.get("grad_accum", 1))

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant,
        "status": "ok", "compile_s": round(compile_s, 1),
        "kind": spec["kind"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": roof.to_dict(),
        "analytic": {
            **ana,
            "compute_s": ana["flops_per_device"] / 197e12,
            "memory_s": ana["hbm_bytes_per_device"] / 819e9,
        },
        "grad_accum": spec.get("grad_accum", 1),
        "model_flops_per_device": model_flops,
        "useful_flops_ratio": (model_flops / roof.flops_per_device
                               if roof.flops_per_device else None),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}: "
              f"compile {compile_s:.1f}s")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={roof.compute_s * 1e3:.3f}ms "
              f"memory={roof.memory_s * 1e3:.3f}ms "
              f"collective={roof.collective_s * 1e3:.3f}ms "
              f"dominant={roof.dominant}")
    return rec


def _tokens_processed(spec) -> float:
    """Global token count of one step (for MODEL_FLOPS = 6·N·D)."""
    if spec["kind"] == "train":
        B, S = spec["batch"]["tokens"].shape
        return 3.0 * B * S       # fwd + bwd ≈ 3× forward FLOPs
    if spec["kind"] == "prefill":
        B, S = spec["batch"]["tokens"].shape
        return float(B * S)
    return float(spec["tokens"].shape[0])   # decode: one token per row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SP.INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf variants)")
    ap.add_argument("--variant", default=None,
                    help="variant label recorded with the results")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in getattr(args, "set"))
    variant = args.variant or ("baseline" if not overrides else
                               ",".join(f"{k}={v}"
                                        for k, v in overrides.items()))

    assert len(jax.devices()) == 512, (
        "dry-run requires the 512 placeholder devices; do not strip "
        "XLA_FLAGS from the top of this file")

    archs = configs.all_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SP.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, multi_pod=mp,
                              overrides=overrides, variant=variant)
                records.append(rec)
                if rec["status"] == "FAILED":
                    print(f"[dryrun] FAILED {arch} × {shape} "
                          f"(multi_pod={mp}): {rec['error']}")
                # append incrementally so long sweeps are resumable
                _merge_out(args.out, records)
    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    print(f"[dryrun] done: {ok} ok, {sk} skipped, "
          f"{len(records) - ok - sk} failed → {args.out}")


def _merge_out(path: str, records: list[dict]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    keyed = {(r["arch"], r["shape"], r["multi_pod"],
              r.get("variant", "baseline")): r for r in existing}
    for r in records:
        keyed[(r["arch"], r["shape"], r["multi_pod"],
               r.get("variant", "baseline"))] = r
    with open(path, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)


if __name__ == "__main__":
    main()
