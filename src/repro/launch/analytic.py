"""Analytic per-device roofline terms from first principles.

Why this exists: XLA's CPU ``cost_analysis()`` counts while-loop bodies
**once** — with scan-over-layers that underreports a 62-layer stack by
~62×. The HLO numbers stay in the records (they are exact *per-body*
measurements and are what §Perf A/Bs against, same method on both sides);
this module provides the absolute terms the roofline table reports.

Model (per device, per step):

* FLOPs — matmul-dominated: 2·N_active·T_tokens (×3 for fwd+bwd) + exact
  attention-score FLOPs (windowed layers counted at their window).
* HBM bytes — weights read (TP-sharded once per step; ×(1+2) for train
  where grads+optimizer are touched), KV/state cache read+write, residual
  activations (remat-aware: one carry per layer, ×microbatching).
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def _attn_flops(cfg: ModelConfig, T_q: float, ctx: float, causal_frac: float
                ) -> float:
    """Score+PV flops across layers, window-aware. Per *global* step."""
    if cfg.family == "ssm":
        return 0.0
    total = 0.0
    hd, H = cfg.head_dim, cfg.num_heads
    layers = (cfg.layer_blocks() if cfg.family == "hybrid"
              else ["a"] * cfg.num_layers)
    windows = cfg.layer_windows()
    wi = 0
    for b in layers:
        if b != "a":
            continue
        w = windows[wi % len(windows)]
        wi += 1
        eff_ctx = min(ctx, w) if w > 0 else ctx
        if cfg.decode_window > 0 and w == 0 and T_q == 1:
            eff_ctx = min(ctx, cfg.decode_window)
        total += 4.0 * T_q * eff_ctx * H * hd * causal_frac
    if cfg.family == "audio":
        total += cfg.encoder_layers * 4.0 * cfg.encoder_frames ** 2 * H * hd
        total += cfg.num_layers * 4.0 * T_q * cfg.encoder_frames * H * hd
    return total


def analytic_terms(cfg: ModelConfig, kind: str, batch: int, seq: int,
                   n_chips: int, grad_accum: int = 1) -> dict:
    """Per-device flops and HBM bytes for one step of ``kind``."""
    N = cfg.active_param_count()
    w_bytes = 2.0 * cfg.param_count()          # bf16 weights
    model_shards = 16                          # TP degree on every mesh

    if kind == "train":
        tokens = batch * seq
        flops = 6.0 * N * tokens + 3.0 * _attn_flops(cfg, seq, seq, 0.5) \
            * batch
        # weights+grads+adam state touched once; activations: remat carry
        # per layer per microbatch + recompute reads
        act = (batch / 16) * seq * cfg.d_model * 2 * \
            (cfg.num_layers + cfg.encoder_layers) / max(grad_accum, 1)
        # weights + f32 master/mu/nu (14 B/param, FSDP-sharded) touched per
        # microbatch (the re-gathered weights), activations written+read+
        # recomputed under remat
        hbm_per_dev = 14.0 * cfg.param_count() / n_chips * grad_accum \
            + act * 3
        return {"flops_per_device": flops / n_chips,
                "hbm_bytes_per_device": hbm_per_dev}

    if kind == "prefill":
        tokens = batch * seq
        flops = 2.0 * N * tokens + _attn_flops(cfg, seq, seq, 0.5) * batch
        cache = _cache_bytes(cfg, batch, seq)
        hbm_per_dev = w_bytes / model_shards + cache / n_chips + \
            (batch / 16) * seq * cfg.d_model * 2 * 2
        return {"flops_per_device": flops / n_chips,
                "hbm_bytes_per_device": hbm_per_dev}

    # decode: one token per row against a `seq`-entry cache
    flops = 2.0 * N * batch + _attn_flops(cfg, 1, seq, 1.0) * batch
    cache = _cache_bytes(cfg, batch, seq)
    hbm_per_dev = w_bytes / model_shards + cache / n_chips * 2  # r+w
    return {"flops_per_device": flops / n_chips,
            "hbm_bytes_per_device": hbm_per_dev}


def _cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Global cache bytes (bf16 KV / f32 SSD state), ring-aware."""
    M = seq
    if cfg.ring_cache and cfg.decode_window > 0:
        M = min(seq, cfg.decode_window)
    kv_layers = {"dense": cfg.num_layers, "moe": cfg.num_layers,
                 "vlm": cfg.num_layers, "audio": cfg.num_layers,
                 "hybrid": cfg.layer_blocks().count("a"),
                 "ssm": 0}[cfg.family]
    kv = kv_layers * batch * M * cfg.num_kv_heads * cfg.head_dim * 2 * 2
    if cfg.family == "ssm":
        kv += cfg.num_layers * batch * cfg.ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4
    if cfg.family == "hybrid":
        n_rec = cfg.layer_blocks().count("r")
        kv += n_rec * batch * cfg.d_rnn * 4
    if cfg.family == "audio":
        kv += cfg.num_layers * batch * cfg.encoder_frames * \
            cfg.num_kv_heads * cfg.head_dim * 2 * 2
    return kv
