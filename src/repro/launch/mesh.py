"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these meshes can be built from host placeholder devices.

Target hardware: TPU v5e, 256 chips/pod (16×16), 2 pods.
  peak 197 TFLOP/s bf16/chip · 819 GB/s HBM/chip · ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline (see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
