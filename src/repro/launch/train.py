"""Training launcher.

Two modes:
* ``--arch <id> --smoke``: CPU-runnable reduced-config training (the
  per-arch smoke path; also what examples/train_weak_fm.py drives).
* ``--arch <id>``: full-config training under the production mesh — on
  this CPU container use ``--dry-run`` (via repro.launch.dryrun) to verify
  the distributed step; on a real v5e slice this entry point runs it.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 100 --batch 16 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import init_params
from repro.training import AdamWConfig, init_opt_state, make_train_step
from repro.training.checkpoint import save_checkpoint


def synthetic_lm_batch(rng: np.random.Generator, vocab: int, batch: int,
                       seq: int, cfg) -> dict:
    """Structured synthetic LM data (Zipf-ish marginals + copy structure so
    the loss actually falls during smoke training)."""
    base = rng.zipf(1.5, size=(batch, seq)).astype(np.int64)
    tokens = np.minimum(base, vocab - 1).astype(np.int32)
    # periodic copy structure: second half repeats the first half
    tokens[:, seq // 2:] = tokens[:, :seq - seq // 2]
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    out = {"tokens": tokens, "labels": labels.astype(np.int32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = rng.normal(
            size=(batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        out["frames"] = rng.normal(
            size=(batch, cfg.encoder_frames, cfg.d_model)).astype(np.float32)
    return out


def train(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
          lr: float, ckpt: str | None, log_every: int = 10) -> dict:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    print(f"[train] {cfg.name}: {cfg.param_count():,} params "
          f"({cfg.active_param_count():,} active)")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_cfg = AdamWConfig(learning_rate=lr, warmup_steps=min(20, steps // 5),
                          total_steps=steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    metrics = {}
    for i in range(steps):
        b = synthetic_lm_batch(rng, cfg.vocab_size, batch, seq, cfg)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, metrics = step_fn(params, opt_state, b)
        if (i + 1) % log_every == 0 or i == 0:
            print(f"  step {i + 1}/{steps} loss={float(metrics['loss']):.4f}"
                  f" acc={float(metrics['accuracy']):.3f}"
                  f" lr={float(metrics['lr']):.2e}"
                  f" ({(time.perf_counter() - t0) / (i + 1) * 1e3:.0f} ms/step)")
    if ckpt:
        save_checkpoint(ckpt, {"params": params, "cfg_name": cfg.name})
        print(f"[train] checkpoint → {ckpt}")
    return {k: float(v) for k, v in metrics.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
