"""Serving launcher — run the RAR layered system over a request stream.

This is the paper's deployment shape: a weak tier + strong tier behind the
adaptive router, serving batched requests. On CPU it runs the trained
synthetic-suite system end-to-end; production zoo archs slot in as tiers
via --weak-arch/--strong-arch in dry-run form (see repro.launch.dryrun for
the distributed serve_step itself).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --requests 200 --domain 0
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.rar_system import make_rar_config
from repro.experiments.setup import build_system, failing_pool
from repro.experiments.stages import run_rar_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--domain", type=int, default=0)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--microbatch", type=int, default=1,
                    help="requests per controller step (1 = the paper's "
                         "sequential stream; >1 = batched data plane)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve replicas behind the request dispatcher "
                         "(serving fabric): microbatches round-robin "
                         "across replica worker threads sharing one "
                         "commit stream, a single learn replica drains "
                         "all shadow work. 1 = the single-controller "
                         "data plane (bit-identical through the fabric)")
    ap.add_argument("--router", default="oracle",
                    choices=["oracle", "learned"])
    ap.add_argument("--sim-threshold", type=float, default=0.2)
    ap.add_argument("--retrieval-k", type=int, default=1,
                    help="memory entries retrieved per query (one store "
                         "pass regardless of k); >1 enables multi-guide "
                         "serving")
    ap.add_argument("--max-guides", type=int, default=None,
                    help="retrieved guides spliced into the weak FM's "
                         "prompt (default: --retrieval-k)")
    ap.add_argument("--shadow-mode", default="inline",
                    choices=["inline", "deferred", "async"],
                    help="where shadow inference (weak probes, guide "
                         "generation, memory commits) runs relative to "
                         "the serve sweep: 'inline' = inside every "
                         "controller step (the reference behaviour); "
                         "'deferred' = queued and drained synchronously "
                         "every --shadow-flush-every batches; 'async' = "
                         "drained by a background thread so user-facing "
                         "latency pays for the serve sweep alone. "
                         "Requires --microbatch > 1.")
    ap.add_argument("--shadow-flush-every", type=int, default=1,
                    help="drain the shadow queue every N batches "
                         "(deferred/async modes; 0 = only at stage-end "
                         "barriers). Larger values amortize drains at "
                         "the cost of memory staleness: a request cannot "
                         "hit a skill whose shadow pass has not drained "
                         "yet")
    ap.add_argument("--shadow-dedup-sim", type=float, default=None,
                    help="coalesce queued shadow items whose embedding "
                         "cosine reaches this threshold: one probe pass "
                         "resolves the whole near-duplicate group, "
                         "reclaiming duplicate-skill strong calls "
                         "(pays off with deferred/async drains, where "
                         "duplicates pile up between barriers; default "
                         "off)")
    ap.add_argument("--log-every", type=int, default=64,
                    help="serve-loop progress every N requests (0 = off); "
                         "throttled because the memory-occupancy read "
                         "syncs a device scalar — per-request logging "
                         "would stall the pipeline on every request")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    system = build_system()
    pool = failing_pool(system, args.domain, n=args.requests)
    print(f"[serve] {len(pool)} requests (weak-FM-failing pool, "
          f"domain {args.domain}); router={args.router}, "
          f"retrieval_k={args.retrieval_k}, shadow={args.shadow_mode}, "
          f"replicas={args.replicas}")

    if args.shadow_mode != "inline" and args.microbatch <= 1:
        ap.error("--shadow-mode deferred/async requires --microbatch > 1 "
                 "(the sequential reference interleaves shadow inference "
                 "per request)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    cfg = make_rar_config(sim_threshold=args.sim_threshold,
                          retrieval_k=args.retrieval_k,
                          max_guides=args.max_guides,
                          shadow_mode=args.shadow_mode,
                          shadow_flush_every=args.shadow_flush_every,
                          shadow_dedup_sim=args.shadow_dedup_sim,
                          reprobe_period=2 * len(pool))
    t0 = time.time()
    results, rar = run_rar_experiment(
        system, pool, n_stages=args.stages, rar_cfg=cfg,
        router_kind=args.router, microbatch=args.microbatch,
        replicas=args.replicas, verbose=True,
        progress_every=args.log_every)
    rar.close_shadow()
    dt = time.time() - t0

    total = args.stages * len(pool)
    aligned = sum(r.aligned for r in results)
    strong = sum(r.strong_calls for r in results)
    print(f"[serve] {total} requests in {dt:.1f}s "
          f"({1e3 * dt / total:.1f} ms/request)")
    print(f"[serve] aligned {aligned}/{total} ({100 * aligned / total:.1f}%)"
          f", strong-FM calls {strong} ({100 * strong / total:.1f}% of "
          f"requests), memory size {rar.memory.size_fast}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in results], f, indent=1,
                      default=str)


if __name__ == "__main__":
    main()
