"""Serving launcher — run the RAR layered system over a request stream.

This is the paper's deployment shape: a weak tier + strong tier behind the
adaptive router, serving batched requests. On CPU it runs the trained
synthetic-suite system end-to-end; production zoo archs slot in as tiers
via --weak-arch/--strong-arch in dry-run form (see repro.launch.dryrun for
the distributed serve_step itself).

Recovery plane: the launcher exposes the fault-tolerance stack of
``repro.serving`` — tier-call retries with exponential backoff
(--tier-max-retries/--tier-timeout), a strong-tier circuit breaker that
degrades routing to weak-only while open (--breaker-threshold/
--breaker-cooldown; suppressed shadow probes are deferred and replayed
when the breaker closes; --breaker-adaptive derives the effective knobs
from an EWMA of observed error rates), bounded crash redispatch across
serve replicas (--max-redispatch), process-per-replica serving with
heartbeat-lease supervision (--transport process: a hung or SIGKILL'd
worker is detected, respawned, and its in-flight work redispatched
byte-identically), and a crash-consistent guide store via write-ahead
journaling + snapshots (--journal-path/--snapshot-every: restart with the
same path and the pre-crash memory — plus the engine-state manifest:
clock, counters, breaker state — is recovered byte-identically). All
default OFF; with the defaults the serve path is byte-identical to the
pre-resilience launcher.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --requests 200 --domain 0
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.rar_system import make_rar_config
from repro.experiments.setup import build_system, failing_pool
from repro.experiments.stages import run_rar_experiment


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Serve a request stream through the RAR layered "
                    "system (weak/strong tiers + adaptive router + "
                    "guide memory), with optional replication and a "
                    "recovery plane: tier retries, circuit-breaker "
                    "degraded routing, crash redispatch, and "
                    "journaled crash-consistent memory.")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--domain", type=int, default=0)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--microbatch", type=int, default=1,
                    help="requests per controller step (1 = the paper's "
                         "sequential stream; >1 = batched data plane)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve replicas behind the request dispatcher "
                         "(serving fabric): microbatches round-robin "
                         "across replica worker threads sharing one "
                         "commit stream, a single learn replica drains "
                         "all shadow work. 1 = the single-controller "
                         "data plane (bit-identical through the fabric)")
    ap.add_argument("--transport", default="thread",
                    choices=["thread", "process"],
                    help="how serve replicas are hosted (--replicas > 1 "
                         "only): 'thread' = worker threads in this "
                         "process; 'process' = one OS process per "
                         "replica behind the same submit/join boundary "
                         "— a crashed or SIGKILL'd worker is detected "
                         "by heartbeat leases, respawned, and its in-"
                         "flight microbatches redispatch byte-"
                         "identically (requires --router oracle)")
    ap.add_argument("--router", default="oracle",
                    choices=["oracle", "learned"])
    ap.add_argument("--arrival-pattern", default="closed",
                    choices=["closed", "poisson", "bursty"],
                    help="traffic shape: 'closed' (default) offers pre-"
                         "partitioned microbatches back-to-back; "
                         "'poisson'/'bursty' switch to open-loop "
                         "admission (--replicas > 1): each stage's "
                         "requests become a seeded arrival trace (one "
                         "stream per replica) admitted one by one "
                         "through the continuous batcher, which forms "
                         "microbatches with the size-or-deadline close "
                         "rule and reports queueing-delay / end-to-end "
                         "p50/p99 per stream in the metrics registry")
    ap.add_argument("--arrival-rate", type=float, default=64.0,
                    help="aggregate offered load in requests/second for "
                         "open-loop --arrival-pattern (virtual time: "
                         "the rate shapes batch formation and queueing "
                         "delay, not wall-clock pacing)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request queueing-delay budget in ms for "
                         "open-loop admission: a forming batch closes "
                         "early when its oldest member's budget is "
                         "about to breach (priority p tightens the "
                         "budget to slo/(1+p)); default: size-only "
                         "closes")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated per-stream priorities for "
                         "open-loop admission, cycled across streams "
                         "(e.g. '0,1,2'); higher priority = tighter "
                         "SLO budget. Default: all zero")
    ap.add_argument("--sim-threshold", type=float, default=0.2)
    ap.add_argument("--retrieval-k", type=int, default=1,
                    help="memory entries retrieved per query (one store "
                         "pass regardless of k); >1 enables multi-guide "
                         "serving")
    ap.add_argument("--max-guides", type=int, default=None,
                    help="retrieved guides spliced into the weak FM's "
                         "prompt (default: --retrieval-k)")
    ap.add_argument("--retrieval-clusters", type=int, default=0,
                    help="two-level (IVF) retrieval plane: cluster the "
                         "memory into this many online-k-means centroids "
                         "and scan only the probed clusters' rows per "
                         "query (sub-linear in capacity). 0 (default) = "
                         "the exact full scan")
    ap.add_argument("--retrieval-probes", type=int, default=4,
                    help="clusters probed per query when "
                         "--retrieval-clusters is on: the recall-vs-"
                         "latency knob (probing all clusters reproduces "
                         "the exact scan)")
    ap.add_argument("--shadow-mode", default="inline",
                    choices=["inline", "deferred", "async", "adaptive"],
                    help="where shadow inference (weak probes, guide "
                         "generation, memory commits) runs relative to "
                         "the serve sweep: 'inline' = inside every "
                         "controller step (the reference behaviour); "
                         "'deferred' = queued and drained synchronously "
                         "every --shadow-flush-every batches; 'async' = "
                         "drained by a background thread so user-facing "
                         "latency pays for the serve sweep alone; "
                         "'adaptive' = a cost model fitted online from "
                         "drain-cost observations drains exactly when "
                         "estimated staleness cost (pending re-shadow "
                         "probability x probe cost) exceeds the "
                         "amortized drain overhead — with replicas, one "
                         "shared policy sees every replica's staleness. "
                         "Requires --microbatch > 1.")
    ap.add_argument("--shadow-flush-every", type=int, default=1,
                    help="drain the shadow queue every N batches "
                         "(deferred/async modes; 0 = only at stage-end "
                         "barriers). Larger values amortize drains at "
                         "the cost of memory staleness: a request cannot "
                         "hit a skill whose shadow pass has not drained "
                         "yet. In adaptive mode this is a hard staleness "
                         "cap on top of the cost model (0 = uncapped)")
    ap.add_argument("--shadow-dedup-sim", type=float, default=None,
                    help="coalesce queued shadow items whose embedding "
                         "cosine reaches this threshold: one probe pass "
                         "resolves the whole near-duplicate group, "
                         "reclaiming duplicate-skill strong calls "
                         "(pays off with deferred/async drains, where "
                         "duplicates pile up between barriers; default "
                         "off)")
    # -- recovery plane (all default off; off = byte-identical serve) --
    ap.add_argument("--tier-max-retries", type=int, default=0,
                    help="retries per FM tier call on transient failure "
                         "(exponential backoff + jitter); 0 = off — a "
                         "tier exception propagates as before")
    ap.add_argument("--tier-timeout", type=float, default=None,
                    help="per-call tier timeout in seconds (counts as a "
                         "transient failure toward retries/breaker); "
                         "default: no timeout")
    ap.add_argument("--breaker-threshold", type=int, default=0,
                    help="consecutive tier failures that open the "
                         "circuit breaker; while the STRONG breaker is "
                         "open, routing degrades to weak-only (memory-"
                         "hard served weak, shadow probes deferred and "
                         "replayed once a half-open probe closes the "
                         "breaker). 0 = no breaker")
    ap.add_argument("--breaker-cooldown", type=float, default=1.0,
                    help="seconds an open breaker waits before the "
                         "half-open probe call")
    ap.add_argument("--breaker-adaptive", action="store_true",
                    help="derive the breaker's effective threshold/"
                         "cooldown from an EWMA of observed tier error "
                         "rates: a tier seen to be flaky opens after "
                         "fewer consecutive failures and cools down "
                         "longer; a clean history keeps the configured "
                         "knobs exactly (default: static knobs)")
    ap.add_argument("--breaker-ewma-alpha", type=float, default=0.2,
                    help="error-rate EWMA smoothing factor in (0, 1] "
                         "for --breaker-adaptive (higher = reacts "
                         "faster, forgets faster)")
    ap.add_argument("--max-redispatch", type=int, default=2,
                    help="times a crashed replica's microbatch is re-"
                         "dispatched to a surviving replica before its "
                         "ticket surfaces the error (fabric mode; the "
                         "crash point precedes all side effects, so a "
                         "redispatched run is byte-identical)")
    ap.add_argument("--journal-path", default=None,
                    help="directory for the guide store's write-ahead "
                         "log + snapshots; every commit epoch is "
                         "journaled before it applies, and a restart "
                         "with the same path recovers the pre-crash "
                         "store byte-identically (default: no journal)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot the journaled store every N commit "
                         "epochs (bounds WAL replay length at recovery)")
    ap.add_argument("--log-every", type=int, default=64,
                    help="serve-loop progress every N requests (0 = off); "
                         "throttled because the memory-occupancy read "
                         "syncs a device scalar — per-request logging "
                         "would stall the pipeline on every request")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a one-line metrics summary (commit "
                         "epoch, queue depth, shadow staleness, drain "
                         "counts) every N served requests (0 = off). "
                         "Reads the controller's host-side metrics "
                         "snapshot — zero device syncs")
    ap.add_argument("--metrics-json", default=None,
                    help="write the final metrics snapshot "
                         "(per-replica queue depth / shadow staleness / "
                         "drain cost / commit lag, engine + breaker "
                         "counters, supervision events, drain-policy "
                         "cost model, raw registry) to this JSON file")
    ap.add_argument("--metrics-prom", default=None,
                    help="write the final metrics-registry snapshot in "
                         "Prometheus/OpenMetrics text exposition format "
                         "to this file (counters/gauges plus summary "
                         "quantiles for every histogram — scrape-ready)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    system = build_system()
    pool = failing_pool(system, args.domain, n=args.requests)
    print(f"[serve] {len(pool)} requests (weak-FM-failing pool, "
          f"domain {args.domain}); router={args.router}, "
          f"retrieval_k={args.retrieval_k}, shadow={args.shadow_mode}, "
          f"replicas={args.replicas}")

    if args.shadow_mode != "inline" and args.microbatch <= 1:
        ap.error("--shadow-mode deferred/async requires --microbatch > 1 "
                 "(the sequential reference interleaves shadow inference "
                 "per request)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.transport == "process":
        if args.replicas <= 1:
            ap.error("--transport process requires --replicas > 1")
        if args.router != "oracle":
            ap.error("--transport process requires --router oracle (the "
                     "learned router is not shipped to worker processes)")
    priorities = None
    if args.arrival_pattern != "closed":
        if args.replicas <= 1:
            ap.error("--arrival-pattern poisson/bursty admits through "
                     "the serving fabric; use --replicas > 1")
        if args.arrival_rate <= 0:
            ap.error("--arrival-rate must be positive")
        if args.slo_ms is not None and args.slo_ms <= 0:
            ap.error("--slo-ms must be positive")
        if args.priorities:
            try:
                priorities = [int(p) for p in args.priorities.split(",")]
            except ValueError:
                ap.error(f"--priorities must be comma-separated ints, "
                         f"got {args.priorities!r}")
    elif args.priorities or args.slo_ms is not None:
        ap.error("--priorities/--slo-ms only apply to open-loop "
                 "--arrival-pattern poisson/bursty")
    cfg = make_rar_config(sim_threshold=args.sim_threshold,
                          retrieval_k=args.retrieval_k,
                          max_guides=args.max_guides,
                          retrieval_clusters=args.retrieval_clusters,
                          retrieval_probes=args.retrieval_probes,
                          shadow_mode=args.shadow_mode,
                          shadow_flush_every=args.shadow_flush_every,
                          shadow_dedup_sim=args.shadow_dedup_sim,
                          reprobe_period=2 * len(pool),
                          tier_max_retries=args.tier_max_retries,
                          tier_timeout=args.tier_timeout,
                          breaker_threshold=args.breaker_threshold,
                          breaker_cooldown=args.breaker_cooldown,
                          breaker_adaptive=args.breaker_adaptive,
                          breaker_ewma_alpha=args.breaker_ewma_alpha,
                          max_redispatch=args.max_redispatch,
                          journal_path=args.journal_path,
                          snapshot_every=args.snapshot_every)
    # perf_counter, not time.time(): wall-clock steps (NTP slew, DST)
    # must not corrupt the reported interval
    t0 = time.perf_counter()
    results, rar = run_rar_experiment(
        system, pool, n_stages=args.stages, rar_cfg=cfg,
        router_kind=args.router, microbatch=args.microbatch,
        replicas=args.replicas, transport=args.transport,
        arrival_pattern=args.arrival_pattern,
        arrival_rate=args.arrival_rate, slo_ms=args.slo_ms,
        priorities=priorities, verbose=True,
        progress_every=args.log_every,
        metrics_every=args.metrics_every)
    rar.close_shadow()
    # snapshot AFTER the final flush so drain counters are complete and
    # nothing is pending; metrics() stays valid on a closed fabric (all
    # counters are plain host-side state)
    final_metrics = rar.metrics() if hasattr(rar, "metrics") else None
    dt = time.perf_counter() - t0

    total = args.stages * len(pool)
    aligned = sum(r.aligned for r in results)
    strong = sum(r.strong_calls for r in results)
    print(f"[serve] {total} requests in {dt:.1f}s "
          f"({1e3 * dt / total:.1f} ms/request)")
    print(f"[serve] aligned {aligned}/{total} ({100 * aligned / total:.1f}%)"
          f", strong-FM calls {strong} ({100 * strong / total:.1f}% of "
          f"requests), memory size {rar.memory.size_fast}")
    if args.metrics_json and final_metrics is not None:
        with open(args.metrics_json, "w") as f:
            json.dump(final_metrics, f, indent=1, default=str)
        print(f"[serve] metrics snapshot -> {args.metrics_json}")
    if args.metrics_prom:
        registry = getattr(rar, "metrics_registry", None)
        if registry is None:
            registry = getattr(getattr(rar, "shadow", None),
                               "metrics", None)
        if registry is not None:
            with open(args.metrics_prom, "w") as f:
                f.write(registry.to_openmetrics())
            print(f"[serve] OpenMetrics exposition -> {args.metrics_prom}")
        else:
            print("[serve] --metrics-prom skipped: controller exposes "
                  "no metrics registry")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in results], f, indent=1,
                      default=str)


if __name__ == "__main__":
    main()
