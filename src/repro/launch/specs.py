"""ShapeDtypeStruct stand-ins for every (architecture × input shape) pair.

``input_specs`` returns abstract values only — weak-type-correct,
shardable, no device allocation — plus which step function the pair
lowers (``train_step`` / ``prefill`` / ``serve_step``).

Modality frontends are stubs per the brief: VLM pairs get precomputed
patch embeddings, audio pairs get precomputed frame embeddings, both at
d_model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.training.optimizer import init_opt_state

INPUT_SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# long_500k policy (DESIGN.md §7): sub-quadratic attention required.
# SSM / hybrid / native-sliding-window run as-is; dense/moe/vlm run the
# sliding-window decode variant; whisper (enc-dec, 448-token decoder
# context by construction) is skipped.
LONG_SKIP = {"whisper-medium"}
LONG_DECODE_WINDOW = 4096


def shape_kind(shape_name: str) -> str:
    return INPUT_SHAPES[shape_name][2]


ACT_BUDGET_BYTES = 1e9      # live-activation budget per device (16 GB HBM
                            # minus weights / optimizer shards / FSDP
                            # gather buffers / XLA slack)
DATA_SHARDS = 16            # single-pod data-axis size (worst case)


def auto_grad_accum(cfg: ModelConfig, global_batch: int, seq: int) -> int:
    """Microbatch count so per-device live activations (one residual
    carry per remat'd layer) fit the budget. See §Perf hillclimb-2."""
    layers = cfg.num_layers + cfg.encoder_layers
    act_per_row = seq * cfg.d_model * 2 * max(layers, 1)
    if cfg.family == "moe":
        # expert dispatch buffers scale with k: ≈ (1 + k·cf) residual-widths
        act_per_row *= 1 + cfg.experts_per_token
    rows_per_device = max(global_batch // DATA_SHARDS, 1)
    need = act_per_row * rows_per_device / ACT_BUDGET_BYTES
    accum = 1
    while accum < need and accum < global_batch // DATA_SHARDS:
        accum *= 2
    return accum


def supported(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k" and cfg.name in LONG_SKIP:
        return False
    return True


def config_for_shape(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Apply shape-specific config adjustments (the sliding-window decode
    variant for long-context decode on attention archs)."""
    if (shape_name == "long_500k" and cfg.family in
            ("dense", "moe", "vlm", "hybrid")):
        return dataclasses.replace(cfg, decode_window=LONG_DECODE_WINDOW)
    return cfg


def _abstract(tree: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def opt_specs(params: Any) -> Any:
    return jax.eval_shape(init_opt_state, params)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, jnp.bfloat16))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """Returns {kind, params, and kind-specific abstract inputs}."""
    seq, batch, kind = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(cfg, shape_name)
    params = param_specs(cfg)
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)       # noqa: E731
    emb = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)    # noqa: E731

    if kind == "train":
        data = {"tokens": tok(batch, seq), "labels": tok(batch, seq)}
        if cfg.family == "vlm":
            data["patch_embeds"] = emb(batch, cfg.num_patches, cfg.d_model)
        if cfg.family == "audio":
            data["frames"] = emb(batch, cfg.encoder_frames, cfg.d_model)
        return {"kind": kind, "cfg": cfg, "params": params,
                "opt_state": opt_specs(params), "batch": data,
                "grad_accum": auto_grad_accum(cfg, batch, seq)}

    if kind == "prefill":
        data = {"tokens": tok(batch, seq)}
        extra = 0
        if cfg.family == "vlm":
            data["patch_embeds"] = emb(batch, cfg.num_patches, cfg.d_model)
            extra = cfg.num_patches
        if cfg.family == "audio":
            data["frames"] = emb(batch, cfg.encoder_frames, cfg.d_model)
        return {"kind": kind, "cfg": cfg, "params": params, "batch": data,
                "max_len": seq + extra}

    # decode: ONE new token against a cache of seq_len entries
    return {"kind": kind, "cfg": cfg, "params": params,
            "tokens": tok(batch),
            "cache": cache_specs(cfg, batch, seq),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
