"""Train-step factory shared by the CPU training loop and the multi-pod
dry-run (the dry-run lowers exactly this function under pjit).

Supports microbatched gradient accumulation (``grad_accum > 1``): the
global batch is split into ``grad_accum`` microbatches scanned
sequentially, with gradients accumulated in f32. This bounds live
activation memory at train_4k scale — without it the per-layer scan
carries of a 62-layer model at 16 rows/device (≈58 GB for
deepseek-coder-33b) cannot fit 16 GB of HBM. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import loss_fn
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_update

METRIC_KEYS = ("ce", "aux", "accuracy")


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    *, grad_accum: int = 1,
                    batch_axes: Any = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Pure function of its inputs — safe to pjit/lower.

    ``batch_axes``: mesh axis (or tuple) carrying the batch dimension —
    used to keep each microbatch sharded across data after the reshape.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    grad_fn = jax.value_and_grad(partial(loss_fn, cfg), has_aux=True)

    def train_step(params: Any, opt_state: dict, batch: dict):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def to_micro(x):
                y = x.reshape((grad_accum, x.shape[0] // grad_accum)
                              + x.shape[1:])
                if batch_axes is not None:
                    spec = P(None, batch_axes, *([None] * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(y, spec)
                return y

            micro = jax.tree.map(to_micro, batch)

            def body(carry, mb):
                gsum, msum = carry
                (l, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), gsum, g)
                msum = dict(
                    {k: msum[k] + m[k] for k in METRIC_KEYS},
                    loss=msum["loss"] + l)
                return (gsum, msum), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mzero = {k: jnp.zeros((), jnp.float32) for k in
                     METRIC_KEYS + ("loss",)}
            (gsum, msum), _ = jax.lax.scan(body, (gzero, mzero), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            metrics = {k: msum[k] / grad_accum for k in METRIC_KEYS}
            loss = msum["loss"] / grad_accum

        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads,
                                                      opt_state, like=params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
