from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_schedule)
from repro.training.train_step import make_train_step
from repro.training.checkpoint import load_checkpoint, save_checkpoint

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule",
           "make_train_step", "load_checkpoint", "save_checkpoint"]
