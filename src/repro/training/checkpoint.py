"""Minimal pytree checkpointing (numpy .npz + structure pickle).

Orbax is not available offline; this covers the framework's needs: save /
restore params, optimizer state, and RAR memory snapshots atomically.
"""
from __future__ import annotations

import os
import pickle
import tempfile
from typing import Any

import jax
import numpy as np


def save_checkpoint(path: str, tree: Any) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(treedef, f)
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str) -> Any:
    with open(path, "rb") as f:
        treedef = pickle.load(f)
        data = np.load(f)
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    return jax.tree.unflatten(treedef, leaves)
