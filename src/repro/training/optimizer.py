"""AdamW with f32 master weights, implemented directly in JAX.

Mixed-precision layout (standard TPU practice, and what the FSDP sharding
math in DESIGN.md §4 budgets for):

* model params live in bf16 (compute dtype),
* optimizer state carries f32 ``master`` weights plus f32 ``mu``/``nu``
  moments — 14 bytes/param total.

State and params share sharding specs leaf-for-leaf, so the FSDP rules in
:mod:`repro.models.sharding` apply unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio``."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_opt_state(params: Any) -> dict:
    # copy=True: an f32 param's .astype would alias the same buffer, and
    # donating params+master together would then double-donate it.
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)  # noqa: E731
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict,
                 like: Any = None) -> tuple[Any, dict, dict]:
    """Returns (new params cast to the dtype of ``like`` — or of the
    grads when ``like`` is None — plus new opt state and metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return m, v, p - lr * delta

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_mu = treedef.unflatten([o[0] for o in out])
    new_nu = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    ref = like if like is not None else grads
    cast = jax.tree.map(
        lambda mp, old: mp.astype(old.dtype), new_master, ref)
    new_state = {"step": step, "master": new_master,
                 "mu": new_mu, "nu": new_nu}
    return cast, new_state, {"lr": lr, "grad_norm": gnorm}
