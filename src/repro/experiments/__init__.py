from repro.experiments.setup import TrainedSystem, build_system
from repro.experiments.stages import (StageResult, run_baselines,
                                      run_rar_experiment)

__all__ = ["TrainedSystem", "build_system", "StageResult",
           "run_rar_experiment", "run_baselines"]
