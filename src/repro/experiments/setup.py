"""Train and assemble the full RAR evaluation system.

Everything the paper's experiment needs, built with the framework's own
substrates: the weak/strong FMs (trained with ``repro.training``), the
contrastive embedder, the static routers, and the evaluation pools
("failing samples" subsets mirroring the paper's MMLU selection, Fig. 3).

Artifacts are checkpointed under ``.cache/rar_system/`` so tests,
benchmarks and examples share one trained system.
"""
from __future__ import annotations

import dataclasses
import os
import functools
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import rar_system
from repro.core import embedder as emb
from repro.core.fm import FMTier
from repro.core.router import LearnedRouter, OracleRouter, train_router
from repro.data.tasks import TaskSuite, TaskSuiteConfig
from repro.training import (AdamWConfig, init_opt_state, load_checkpoint,
                            make_train_step, save_checkpoint)

CACHE_DIR = os.environ.get("REPRO_CACHE", ".cache/rar_system")

print = functools.partial(print, flush=True)  # noqa: A001 — logs stream to files


@dataclasses.dataclass
class TrainedSystem:
    suite: TaskSuite
    weak: FMTier
    strong: FMTier
    embedder_params: Any
    router: LearnedRouter
    embed_batch_fn: Any            # (B, L) tokens -> (B, 384)

    # ------------------------------------------------------------------
    def embed_one(self, prompt: np.ndarray) -> np.ndarray:
        L = self.suite.cfg.seq_len
        padded = np.full((1, L), 0, np.int32)
        padded[0, :len(prompt)] = prompt
        return np.asarray(self.embed_batch_fn(jnp.asarray(padded))[0])

    def embed_many(self, prompts: list[np.ndarray]) -> np.ndarray:
        L = self.suite.cfg.seq_len
        padded = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            padded[i, :len(p)] = p
        return np.asarray(self.embed_batch_fn(jnp.asarray(padded)))


# ---------------------------------------------------------------------------
# FM training
# ---------------------------------------------------------------------------


def _train_lm(cfg, batch_fn, steps: int, batch_size: int, seed: int,
              lr: float = 1e-3, log_every: int = 200) -> Any:
    from repro.models import init_params
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt_cfg = AdamWConfig(learning_rate=lr, warmup_steps=50,
                          total_steps=steps, weight_decay=0.01,
                          beta2=0.98)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for i in range(steps):
        batch = batch_fn(rng, batch_size)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if log_every and (i + 1) % log_every == 0:
            print(f"  [{cfg.name}] step {i + 1}/{steps} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"({time.perf_counter() - t0:.0f}s)")
    return params


def _train_embedder(ecfg, suite: TaskSuite, steps: int, batch_pairs: int,
                    seed: int) -> Any:
    key = jax.random.PRNGKey(seed + 7)
    params = emb.init_params(ecfg, key)
    opt = emb.init_opt(params)
    step = emb.make_train_step(ecfg)
    rng = np.random.default_rng(seed + 7)
    for i in range(steps):
        toks, sids = suite.embedder_batch(rng, batch_pairs)
        params, opt, loss = step(params, opt, jnp.asarray(toks),
                                 jnp.asarray(sids))
        if (i + 1) % 200 == 0:
            print(f"  [embedder] step {i + 1}/{steps} "
                  f"ntxent={float(loss):.4f}")
    return params


# ---------------------------------------------------------------------------
# System assembly
# ---------------------------------------------------------------------------


def build_system(suite_cfg: TaskSuiteConfig = TaskSuiteConfig(), *,
                 weak_steps: int = 900, strong_steps: int = 1100,
                 embedder_steps: int = 400, batch_size: int = 96,
                 seed: int = 0, cache: bool = True,
                 verbose: bool = True) -> TrainedSystem:
    suite = TaskSuite(suite_cfg)
    ckpt = os.path.join(
        CACHE_DIR,
        f"sys_{suite_cfg.seed}_{suite_cfg.guide_train_frac}_{weak_steps}_{strong_steps}_{seed}.npz")

    if cache and os.path.exists(ckpt):
        if verbose:
            print(f"[setup] loading cached system from {ckpt}")
        blob = jax.tree.map(jnp.asarray, load_checkpoint(ckpt))
        weak_params, strong_params = blob["weak"], blob["strong"]
        embedder_params = blob["embedder"]
        router = LearnedRouter(w=jnp.asarray(blob["router_w"]),
                               b=jnp.asarray(blob["router_b"]))
    else:
        if verbose:
            print("[setup] training weak FM "
                  f"({rar_system.WEAK.param_count():,} params)")
        weak_params = _train_lm(rar_system.WEAK, suite.weak_train_batch,
                                weak_steps, batch_size, seed)
        if verbose:
            print("[setup] training strong FM "
                  f"({rar_system.STRONG.param_count():,} params)")
        strong_params = _train_lm(rar_system.STRONG, suite.strong_train_batch,
                                  strong_steps, batch_size, seed + 1)
        if verbose:
            print("[setup] training contrastive embedder")
        embedder_params = _train_embedder(rar_system.EMBEDDER, suite,
                                          embedder_steps, 48, seed)
        router = None  # built below, needs the weak FM

    embed_fn = jax.jit(partial(emb.embed, rar_system.EMBEDDER,
                               embedder_params))
    weak = FMTier.create("weak", rar_system.WEAK, weak_params, suite.vocab)
    strong = FMTier.create("strong", rar_system.STRONG, strong_params,
                           suite.vocab)

    if router is None:
        if verbose:
            print("[setup] profiling weak FM + training static router")
        router = _build_learned_router(suite, weak, embed_fn, seed)
        if cache:
            save_checkpoint(ckpt, {
                "weak": weak_params, "strong": strong_params,
                "embedder": embedder_params,
                "router_w": router.w, "router_b": router.b})
            if verbose:
                print(f"[setup] cached system at {ckpt}")

    return TrainedSystem(suite=suite, weak=weak, strong=strong,
                         embedder_params=embedder_params, router=router,
                         embed_batch_fn=embed_fn)


def _build_learned_router(suite: TaskSuite, weak: FMTier, embed_fn,
                          seed: int, n_profile: int = 600) -> LearnedRouter:
    """RouteLLM analog: profile the weak FM on held-out questions and fit
    a logistic router on (embedding → success)."""
    rng = np.random.default_rng(seed + 100)
    prompts, labels = [], []
    L = suite.cfg.seq_len
    for _ in range(n_profile):
        d = int(rng.integers(0, suite.cfg.n_domains))
        s = int(rng.choice(suite.domain_skills[d]))
        x = int(rng.integers(0, suite.cfg.max_operand))
        prompts.append(np.asarray(suite.vocab.question(d, s, x), np.int32))
        labels.append(suite.answer(s, x))
    maxlen = max(len(p) for p in prompts)
    batch = np.zeros((n_profile, maxlen), np.int32)
    for i, p in enumerate(prompts):
        batch[i, :len(p)] = p
    # uniform length in this suite → answer in one batched call
    ans = weak.answer_batch(batch)
    success = (ans == np.asarray(labels)).astype(np.float32)
    padded = np.zeros((n_profile, L), np.int32)
    padded[:, :maxlen] = batch
    embs = np.asarray(embed_fn(jnp.asarray(padded)))
    return train_router(embs, success)


# ---------------------------------------------------------------------------
# Evaluation pools — the paper's "failing samples" subsets (Fig. 3)
# ---------------------------------------------------------------------------

POOL_SIZES = {0: 754, 1: 359, 2: 675}   # prof. law / HS psych / moral scen.
POOL_NAMES = {0: "professional_law", 1: "high_school_psychology",
              2: "moral_scenarios"}


def failing_pool(system: TrainedSystem, domain: int, *,
                 n: int | None = None, seed: int = 1234
                 ) -> list[tuple[int, int, int]]:
    """Questions of one domain that the weak FM fails unaided — the
    paper's data selection (weak-FM-failed subsets of MMLU)."""
    n = n or POOL_SIZES[domain]
    suite = system.suite
    cands = suite.question_pool(domain, int(n * 2.2), seed)
    prompts = np.stack([
        np.asarray(suite.vocab.question(d, s, x), np.int32)
        for d, s, x in cands])
    ans = system.weak.answer_batch(prompts)
    truth = np.asarray([suite.answer(s, x) for _, s, x in cands])
    failing = [c for c, a, t in zip(cands, ans, truth) if a != t]
    assert len(failing) >= n, (len(failing), n)
    return failing[:n]
