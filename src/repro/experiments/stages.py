"""The paper's experiment procedure (§IV-A3): multi-stage sequential
serving over shuffled "failing sample" pools, plus all comparison methods
of RQ1 (standalone weak/strong, weak+CoT, oracle static router).

A *stage* = one sequential pass over the pool (RAR's memory persists
across stages); an *experiment* = ``n_stages`` stages over one shuffle;
results are reported mean±std over ``n_shuffles`` shuffles, exactly like
Figs. 4–6.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core.pipeline import MicrobatchRAR
from repro.core.rar import RAR, RARConfig, splice_guide
from repro.experiments.setup import TrainedSystem

Sample = tuple[int, int, int]   # (domain, skill, operand)


@dataclasses.dataclass
class StageResult:
    n: int
    aligned: int
    strong_calls: int
    guides_from_memory: int = 0
    guides_fresh: int = 0
    cases: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _prompts(system: TrainedSystem, pool: list[Sample]):
    v = system.suite.vocab
    prompts = [np.asarray(v.question(d, s, x), np.int32) for d, s, x in pool]
    greqs = [np.asarray(v.guide_request(d, s), np.int32) for d, s, _ in pool]
    return prompts, greqs


def _batched_answers(tier, prompts: list[np.ndarray]) -> np.ndarray:
    return tier.answer_batch(np.stack(prompts))


# ---------------------------------------------------------------------------
# Process-transport replica factory (module-level: must pickle into
# spawned worker processes — see repro.serving.procfabric)
# ---------------------------------------------------------------------------


def _tier_spec(tier):
    """Picklable rebuild recipe for an :class:`FMTier`: params are pulled
    to host memory so the spec crosses the process boundary without a
    device handle."""
    import jax

    from repro.core.fm import ResilientTier
    if isinstance(tier, ResilientTier):
        tier = tier.inner
    return (tier.name, tier.cfg, jax.device_get(tier.engine.params),
            tier.vocab)


def _proc_no_embed(prompt):
    # fabric mode ships embeddings with every dispatch (``submit(...,
    # embs=...)``), so neither the parent's learn plane nor the workers
    # ever call embed_fn
    return None


def _proc_oracle_route(weak_ok, emb, key):
    return key in weak_ok


def _proc_replica_parts(weak_spec, strong_spec, weak_ok):
    """Replica factory for :class:`ProcessServingFabric`: rebuilds both
    FM tiers from host-side params — deterministically identical in the
    parent and in every worker process."""
    from repro.core.fm import FMTier
    return {"weak": FMTier.create(*weak_spec),
            "strong": FMTier.create(*strong_spec),
            "embed_fn": _proc_no_embed,
            "route_weak_fn": functools.partial(_proc_oracle_route,
                                               weak_ok)}


# ---------------------------------------------------------------------------
# RAR experiment
# ---------------------------------------------------------------------------


def run_rar_experiment(system: TrainedSystem, pool: list[Sample], *,
                       n_stages: int = 5, seed: int = 0,
                       rar_cfg: RARConfig | None = None,
                       router_kind: str = "oracle",
                       strong_tier=None,
                       prepopulate_from: list[Sample] | None = None,
                       microbatch: int = 1,
                       replicas: int = 1,
                       transport: str = "thread",
                       retrieval_k: int | None = None,
                       max_guides: int | None = None,
                       shadow_mode: str | None = None,
                       shadow_flush_every: int | None = None,
                       shadow_dedup_sim: float | None = None,
                       fault_plan=None,
                       arrival_pattern: str | None = None,
                       arrival_rate: float = 64.0,
                       slo_ms: float | None = None,
                       priorities=None,
                       verbose: bool = False,
                       progress_every: int = 0,
                       metrics_every: int = 0
                       ) -> tuple[list[StageResult], RAR]:
    """One experiment (one shuffle). Returns per-stage results + the RAR
    instance (memory inspectable).

    ``prepopulate_from``: RQ2 inter-domain setting — run a silent warm-up
    experiment on another domain's pool first so the guide memory is
    populated with out-of-domain guides.

    ``microbatch``: requests served per controller step. 1 (default) is
    the paper's sequential stream via ``RAR.process``; > 1 routes through
    the batched data plane (``MicrobatchRAR.process_batch``) with
    microbatch-commit memory semantics.

    ``replicas``: serve replicas behind the request dispatcher
    (:class:`repro.serving.fabric.ServingFabric`). 1 keeps the
    single-controller data plane; > 1 dispatches microbatches round-robin
    across replica worker threads sharing one commit stream, with a
    single learn replica draining all shadow work (stage-end barriers
    keep StageResults exact, as in the shadow modes). Replica placement
    widens the same staleness window as deferred shadow drains — a
    request on one replica cannot hit a skill whose shadow pass has not
    committed yet. Not combinable with ``prepopulate_from`` (the RQ2
    warm-up is a sequential protocol).

    ``transport``: how replicas are hosted (replicas > 1 only).
    ``"thread"`` (default) is the in-process fabric; ``"process"``
    spawns one OS process per replica
    (:class:`repro.serving.procfabric.ProcessServingFabric`) — the tiers
    are rebuilt from host-side params inside every worker, the parent
    keeps all authoritative state, and a SIGKILL'd worker is respawned
    with its in-flight microbatches redispatched byte-identically.
    Requires ``router_kind="oracle"`` (the learned router is not shipped
    across the process boundary).

    ``retrieval_k``/``max_guides``: override the multi-guide knobs of
    ``rar_cfg`` — every memory read returns the top-k entries and up to
    ``max_guides`` (default: follow retrieval_k) retrieved guides are
    spliced into the weak FM's prompt. ``None`` keeps what ``rar_cfg``
    says (top-1 by default, the paper's procedure).

    ``shadow_mode``/``shadow_flush_every``/``shadow_dedup_sim``: override
    the shadow-plane scheduling of ``rar_cfg`` (microbatch > 1 only):
    ``"inline"`` runs shadow inference inside every controller step (the
    default), ``"deferred"``/``"async"`` take it off the serve path and
    drain every ``shadow_flush_every`` batches, and ``shadow_dedup_sim``
    coalesces near-duplicate queued shadow items into one probe pass
    (see :mod:`repro.core.shadow`). A flush barrier runs at every stage
    end, so per-stage results are exact (all provisional shadow outcomes
    resolved before tallying) in every mode.

    ``fault_plan``: a :class:`repro.serving.faults.FaultPlan` threaded
    into the controller/fabric — deterministic fault injection (replica
    crashes, tier outages, drain/WAL faults) for soak and recovery
    experiments. ``None`` (default) is a strict no-op. The resilience
    *response* knobs (retries, breaker, journal) live on ``rar_cfg``.

    ``arrival_pattern``: traffic shape for the serve loop. ``None`` /
    ``"closed"`` (default) is the closed-loop protocol above:
    pre-partitioned microbatches, the next one offered when the fabric
    accepts it. ``"poisson"`` / ``"bursty"`` switch to **open-loop**
    admission (replicas > 1 only): each stage's requests become a
    seeded arrival trace (:mod:`repro.serving.loadgen`) with one stream
    per replica, admitted one by one through a
    :class:`repro.serving.scheduler.ContinuousBatcher` that forms
    microbatches with the size-or-deadline close rule. ``arrival_rate``
    is the aggregate offered load in requests/second (virtual time —
    batch formation and routing are a pure function of the trace);
    ``slo_ms`` is the per-request queueing budget driving early closes
    (``None`` = size-only closes); ``priorities`` is an optional
    per-stream priority list (cycled across streams; priority ``p``
    tightens the budget to ``slo_ms / (1 + p)``). Queueing-delay and
    end-to-end p50/p99 per stream land in the fabric's metrics registry
    (``sched/...`` names), so ``metrics()`` and ``--metrics-json``
    surface them. Stage results remain exact: the same stage-end
    flush barrier runs before tallying.

    ``progress_every``: print a throughput/memory-occupancy line every N
    served requests (0 = off). The occupancy read is the controller's
    host-side commit counter (``rar.memory_occupancy``), so progress
    logging never syncs a device scalar into the serve loop.

    ``metrics_every``: print a one-line metrics summary (commit epoch,
    shadow pending/staleness, drain counts) every N served requests
    (0 = off). Reads the controller's host-side ``metrics()`` snapshot —
    like ``progress_every``, never a device sync.
    """
    suite = system.suite
    strong = strong_tier or system.strong
    rar_cfg = rar_cfg or RARConfig(
        reprobe_period=2 * len(pool))  # re-probe roughly every other stage
    if retrieval_k is not None:
        rar_cfg = dataclasses.replace(
            rar_cfg, retrieval_k=retrieval_k,
            max_guides=max_guides if max_guides is not None
            else retrieval_k)
    elif max_guides is not None:
        rar_cfg = dataclasses.replace(rar_cfg, max_guides=max_guides)
    if shadow_mode is not None:
        rar_cfg = dataclasses.replace(
            rar_cfg, shadow_mode=shadow_mode,
            shadow_flush_every=shadow_flush_every
            if shadow_flush_every is not None
            else rar_cfg.shadow_flush_every)
    elif shadow_flush_every is not None:
        rar_cfg = dataclasses.replace(rar_cfg,
                                      shadow_flush_every=shadow_flush_every)
    if shadow_dedup_sim is not None:
        rar_cfg = dataclasses.replace(rar_cfg,
                                      shadow_dedup_sim=shadow_dedup_sim)
    prompts, greqs = _prompts(system, pool)

    # scoring reference: the strong FM's answers (quality is measured as
    # alignment with the strong tier, §III-A) — scoring only, not charged.
    strong_ref = _batched_answers(strong, prompts)

    # embeddings are state-independent → compute once, look up by sample.
    embs = system.embed_many(prompts)
    emb_by_key = {i: embs[i] for i in range(len(pool))}
    current: dict = {}

    def embed_fn(prompt: np.ndarray) -> np.ndarray:
        return current["emb"]

    # static router
    if router_kind == "oracle":
        weak_ref = _batched_answers(system.weak, prompts)
        weak_ok = {i for i in range(len(pool))
                   if weak_ref[i] == strong_ref[i] and weak_ref[i] >= 0}
        route_fn = lambda emb, key: key in weak_ok            # noqa: E731
    else:
        route_fn = lambda emb, key: system.router.route_weak(emb)  # noqa: E731

    if transport not in ("thread", "process"):
        raise ValueError(f"unknown transport {transport!r} "
                         "(expected 'thread' or 'process')")
    open_loop = arrival_pattern not in (None, "closed")
    if open_loop:
        if arrival_pattern not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival_pattern "
                             f"{arrival_pattern!r} (expected 'closed', "
                             f"'poisson' or 'bursty')")
        if replicas <= 1:
            raise ValueError("open-loop arrivals admit through the "
                             "serving fabric; use replicas > 1")
        if arrival_rate <= 0:
            raise ValueError(f"arrival_rate={arrival_rate} must be "
                             f"positive")
    if replicas > 1:
        if prepopulate_from is not None:
            raise ValueError("replicas > 1 is not combinable with "
                             "prepopulate_from (the RQ2 warm-up is a "
                             "sequential protocol); warm up at replicas=1")
        if transport == "process":
            if router_kind != "oracle":
                raise ValueError("transport='process' requires "
                                 "router_kind='oracle': the learned "
                                 "router is not shipped to worker "
                                 "processes")
            from repro.serving.procfabric import ProcessServingFabric
            factory = functools.partial(
                _proc_replica_parts, _tier_spec(system.weak),
                _tier_spec(strong), frozenset(weak_ok))
            rar = ProcessServingFabric(factory, rar_cfg,
                                       workers=replicas,
                                       fault_plan=fault_plan)
        else:
            from repro.serving.fabric import ServingFabric
            rar = ServingFabric(system.weak, strong, embed_fn, route_fn,
                                rar_cfg, replicas=replicas,
                                fault_plan=fault_plan)
    else:
        if transport == "process":
            raise ValueError("transport='process' requires replicas > 1 "
                             "(the single-controller data plane serves "
                             "in-process)")
        controller_cls = MicrobatchRAR if microbatch > 1 else RAR
        rar = controller_cls(system.weak, strong, embed_fn, route_fn,
                             rar_cfg, fault_plan=fault_plan)

    if prepopulate_from is not None:
        pre_prompts, pre_greqs = _prompts(system, prepopulate_from)
        pre_embs = system.embed_many(pre_prompts)
        for i in range(len(prepopulate_from)):
            current["emb"] = pre_embs[i]
            rar.process(pre_prompts[i], pre_greqs[i], key=None)
        # freeze: RQ2 only re-uses existing guides, no fresh generation
        rar.cfg = dataclasses.replace(rar.cfg, allow_fresh_guides=False)
        rar.weak.engine.calls = 0
        rar.strong.engine.calls = 0

    rng = np.random.default_rng(seed)
    order = rng.permutation(len(pool))

    served = 0
    t_serve = time.perf_counter()

    def progress(batch: int) -> None:
        """Throttled serve-loop reporting. The occupancy figure comes
        from the controller's host-side commit counter
        (``memory_occupancy`` — fed by the shadow commit buffer on the
        batched path), so this is transfer-free: no device-scalar sync
        ever lands in the serve loop, at any ``progress_every``."""
        nonlocal served
        before = served
        served += batch
        if not progress_every:
            return
        if served // progress_every > before // progress_every:
            dt = time.perf_counter() - t_serve
            print(f"      [{served}/{n_stages * len(pool)}] "
                  f"{1e3 * dt / served:.1f} ms/request, "
                  f"memory {rar.memory_occupancy}/"
                  f"{rar.cfg.memory.capacity}")

    def metrics_line(batch: int) -> None:
        """Periodic one-line metrics summary off the controller's
        host-side snapshot (no device syncs, same contract as
        ``progress``). Called with the same served-counter cadence."""
        if not metrics_every or not hasattr(rar, "metrics"):
            return
        if served // metrics_every <= (served - batch) // metrics_every:
            return
        met = rar.metrics()
        commit = met.get("commit", {})
        line = (f"      [metrics] epoch {commit.get('epoch', 0)}, "
                f"entries {commit.get('entries_applied', 0)}")
        reps = met.get("replicas")
        if reps:
            pending = sum(r["shadow_pending"] for r in reps)
            stale = max(r["shadow_staleness_batches"] for r in reps)
            drains = sum(r["drains"] for r in reps)
            line += (f", shadow pending {pending} "
                     f"(staleness {stale} batches), drains {drains}")
        pol = met.get("drain_policy")
        if pol:
            line += (f", policy drains {pol.get('cost_drains', 0)}cost"
                     f"+{pol.get('coldstart_drains', 0)}cold")
        print(line)

    results = []
    for stage in range(n_stages):
        aligned = strong_calls = gmem = gfresh = 0
        cases: dict = {}

        def tally(i: int, out) -> None:
            nonlocal aligned, strong_calls, gmem, gfresh
            ok = int(out.response == strong_ref[i])
            aligned += ok
            strong_calls += out.strong_calls
            cases[out.case] = cases.get(out.case, 0) + 1
            # Fig. 7 accounting: aligned *guided* responses by guide source
            if ok and out.guide_source == "memory":
                gmem += 1
            elif ok and out.guide_source == "fresh":
                gfresh += 1

        if open_loop:
            # open-loop admission: this stage's shuffled pool becomes a
            # seeded arrival trace (one stream per replica, round-robin
            # shard of the stage order — same shard rule as closed-loop
            # replica scaling), admitted request-by-request through the
            # continuous batcher. Formation runs in virtual time, so
            # routing is a pure function of (order, trace seed).
            from repro.serving import loadgen
            from repro.serving.scheduler import serve_trace
            streams = replicas
            seqs = [[int(order[p]) for p in range(len(order))
                     if p % streams == j] for j in range(streams)]
            counts = [len(s) for s in seqs]
            gen = (loadgen.poisson_trace if arrival_pattern == "poisson"
                   else loadgen.bursty_trace)
            trace = gen(counts, arrival_rate, seed=seed * 10007 + stage,
                        streams=streams, priorities=priorities)
            cursors = [0] * streams
            admitted_keys: list[int] = []

            def make_request(ev):
                i = seqs[ev.stream][cursors[ev.stream]]
                cursors[ev.stream] += 1
                admitted_keys.append(i)
                return prompts[i], greqs[i], i, embs[i]

            outcomes, batcher = serve_trace(
                rar, trace, make_request, microbatch=microbatch,
                slo_ms=slo_ms, replica_fn=lambda s: s % replicas,
                registry=rar.metrics_registry)
            # stage-end barrier before tallying, as in every other mode
            rar.flush_shadow()
            for i, out in zip(admitted_keys, outcomes):
                tally(i, out)
                progress(1)
                metrics_line(1)
            if verbose:
                bs = batcher.stats()
                reg = rar.metrics_registry.snapshot()
                qd = reg.get("sched/queue_delay_ms", {})
                print(f"      [open-loop] {arrival_pattern} "
                      f"@{arrival_rate:g} req/s, batches {bs['batches']} "
                      f"(closes {bs['closes']}), queue-delay "
                      f"p50 {qd.get('p50', 0):.1f} ms / "
                      f"p99 {qd.get('p99', 0):.1f} ms")
        elif replicas > 1:
            # dispatch every microbatch to the fabric's replica workers
            # (round-robin, concurrent serving), then one stage-end
            # barrier: all microbatches served, all shadow work drained
            tickets: list[tuple[list[int], object]] = []
            for start in range(0, len(order), microbatch):
                chunk = [int(i) for i in order[start:start + microbatch]]
                tickets.append((chunk, rar.submit(
                    [prompts[i] for i in chunk],
                    [greqs[i] for i in chunk],
                    keys=chunk, embs=embs[chunk])))
            rar.flush_shadow()
            # progress is tallied as tickets resolve (after the barrier),
            # not at submit time — enqueueing is near-instant and would
            # make the ms/request line meaningless in fabric mode
            for chunk, t in tickets:
                for i, out in zip(chunk, t.wait()):
                    tally(i, out)
                progress(len(chunk))
                metrics_line(len(chunk))
        elif microbatch > 1:
            stage_outs: list[tuple[int, object]] = []
            for start in range(0, len(order), microbatch):
                chunk = [int(i) for i in order[start:start + microbatch]]
                outs = rar.process_batch(
                    [prompts[i] for i in chunk],
                    [greqs[i] for i in chunk],
                    keys=chunk, embs=embs[chunk])
                stage_outs += zip(chunk, outs)
                progress(len(chunk))
                metrics_line(len(chunk))
            # stage-end barrier: deferred/async shadow outcomes are
            # provisional until their drain; flush before tallying so
            # StageResults are exact in every shadow mode (no-op inline)
            rar.flush_shadow()
            for i, out in stage_outs:
                tally(i, out)
        else:
            for i in order:
                current["emb"] = emb_by_key[int(i)]
                out = rar.process(prompts[int(i)], greqs[int(i)], key=int(i))
                tally(int(i), out)
                progress(1)
                metrics_line(1)
        results.append(StageResult(
            n=len(pool), aligned=aligned, strong_calls=strong_calls,
            guides_from_memory=gmem, guides_fresh=gfresh, cases=cases))
        if verbose:
            r = results[-1]
            print(f"    stage {stage + 1}: aligned {r.aligned}/{r.n}, "
                  f"strong calls {r.strong_calls}, cases {r.cases}")
    return results, rar


# ---------------------------------------------------------------------------
# RQ1 baselines
# ---------------------------------------------------------------------------


def run_baselines(system: TrainedSystem, pool: list[Sample], *,
                  n_stages: int = 5, rar_cfg: RARConfig | None = None
                  ) -> dict[str, list[StageResult]]:
    """Standalone weak, weak + zero-shot CoT, standalone strong, oracle
    static router — each as per-stage results over the pool. ``rar_cfg``
    supplies the guide format (``memory.guide_len``) so the CoT comparator
    matches the configuration RAR itself runs with."""
    suite = system.suite
    rar_cfg = rar_cfg or RARConfig()
    prompts, greqs = _prompts(system, pool)
    strong_ref = _batched_answers(system.strong, prompts)
    n = len(pool)
    out: dict[str, list[StageResult]] = {}

    # standalone weak
    weak_ans = _batched_answers(system.weak, prompts)
    aligned = int(np.sum((weak_ans == strong_ref) & (weak_ans >= 0)))
    out["weak"] = [StageResult(n, aligned, 0) for _ in range(n_stages)]

    # weak + zero-shot CoT: the weak FM generates its own guide, then
    # answers with it in-context (the paper's CoT comparator).
    self_guides = system.weak.generate_guides(np.stack(greqs),
                                              rar_cfg.memory.guide_len)
    guided = [splice_guide(p, g) for p, g in zip(prompts, self_guides)]
    cot_ans = _batched_answers(system.weak, guided)
    aligned = int(np.sum((cot_ans == strong_ref) & (cot_ans >= 0)))
    out["weak_cot"] = [StageResult(n, aligned, 0) for _ in range(n_stages)]

    # standalone strong: perfect alignment by definition, n strong calls
    out["strong"] = [StageResult(n, n, n) for _ in range(n_stages)]

    # oracle static router: weak serves exactly the samples it aligned on
    # during profiling; the rest go strong — static across stages.
    weak_ok = (weak_ans == strong_ref) & (weak_ans >= 0)
    strong_calls = int(np.sum(~weak_ok))
    out["oracle_router"] = [StageResult(n, n, strong_calls)
                            for _ in range(n_stages)]
    return out


# ---------------------------------------------------------------------------
# Aggregation over shuffles (the paper's mean ± std presentation)
# ---------------------------------------------------------------------------


def aggregate_shuffles(per_shuffle: list[list[StageResult]]
                       ) -> list[dict[str, float]]:
    """[shuffle][stage] → per-stage mean/std of cumulative metrics."""
    n_stages = len(per_shuffle[0])
    rows = []
    for s in range(n_stages):
        cum_aligned = [sum(r[i].aligned for i in range(s + 1))
                       for r in per_shuffle]
        cum_strong = [sum(r[i].strong_calls for i in range(s + 1))
                      for r in per_shuffle]
        rows.append({
            "stage": s + 1,
            "cum_aligned_mean": float(np.mean(cum_aligned)),
            "cum_aligned_std": float(np.std(cum_aligned)),
            "cum_strong_calls_mean": float(np.mean(cum_strong)),
            "cum_strong_calls_std": float(np.std(cum_strong)),
        })
    return rows
