"""Pallas TPU kernel: fused cosine-similarity + masked top-1 over the RAR
skill/guide memory.

This is the per-request critical path of the paper's system (§III-F): every
incoming request queries the vector store before any FM inference. The
kernel streams the (capacity, E) store through VMEM in row blocks, computes
the similarity on the MXU, and carries the running (best sim, best index)
in SMEM across grid steps — one HBM pass, no (capacity,) score vector ever
written back.

Padded-layout invariant (the zero-copy contract)
------------------------------------------------
The hot-path entry points (:func:`memory_top1_padded_pallas`,
:func:`memory_top1_batch_padded_pallas`) take the store **already in kernel
layout** and touch each store byte exactly once per query:

* ``mem`` is (Cp, Ep) f32 with rows padded to a multiple of 8 (f32 sublane
  tile) and lanes to a multiple of 128; padding rows/lanes are zero.
* ``mask`` is a (Cp, 1) int32 *bit plane*: bit 0 = valid, bit 1 =
  has_guide (:data:`MASK_VALID`/:data:`MASK_GUIDE`). Padding rows are 0,
  i.e. never valid. A query passes ``required`` — the bit set a row must
  carry to participate — so the ``guides_only`` view costs nothing (no
  per-query (C,) mask combine).

:class:`repro.core.memory.MemoryState` maintains this layout persistently
and incrementally (scatters update rows in place), so no per-query
re-padding copy of the store exists anywhere on the dispatch path. The
legacy wrappers (:func:`memory_top1_pallas`,
:func:`memory_top1_batch_pallas`) keep the old compact-layout signature for
shape sweeps and one-off calls; they convert eagerly via
:func:`to_padded_layout` *outside* any jitted function and are not the
serving path.

Two kernel bodies share the streaming layout:

* single query — running best carried in SMEM;
* multi-query (the microbatched data plane, ``core.pipeline``) — all B
  queries stay resident in VMEM while the store makes the same single HBM
  pass; each (BLOCK_C, E)×(B, E)ᵀ product lands on the MXU and the
  per-query running (best sim, best index) pair is a (1, B) VMEM
  accumulator updated with a vector compare. Microbatch-commit semantics
  (reads at batch start, writes once at batch end) live in
  ``core.memory.add_batch``; this kernel is the read side.

Sharding: the same kernels run per-shard under ``shard_map`` in
``core.memory_sharded`` — each device streams only its (Cp/S, Ep) shard and
an all-gather/argmax combine produces the global (sim, idx).

Top-k retrieval (the multi-guide read path)
-------------------------------------------
:func:`memory_topk_padded_pallas` / :func:`memory_topk_batch_padded_pallas`
generalize the same one-pass contract to k > 1 results per query (the
guided in-context serving path splices several retrieved guides into one
weak-FM prompt, ``core.rar.splice_guides``).

Accumulator layout: the running best-k is a **(k, B) pair of VMEM
accumulators** (sims f32, global row idx int32) revisited on every grid
step — row j holds the j-th best candidate seen so far, kept
insertion-sorted by the total order

    (sim descending, global row ascending)

so equal similarities (duplicate store rows) deterministically rank by
lowest global row, exactly like the top-1 kernels' tie-break. Slots that
no store row has filled yet carry the below-any-data sentinel
(-3.0, 2**30); masked-out rows enter at sim -2.0, so "fewer than k rows
in the view" degrades to -2.0 sentinel results exactly like the top-1
kernels' empty-view case.

Merge rule per grid step: the (BLOCK_C, B) masked block similarities are
concatenated with the (k, B) accumulator and the new best-k is re-selected
by k selection-extraction rounds — take the column max, resolve ties to
the lowest global row, consume that candidate (set its sim to -3.0),
repeat. Every round is a vectorized compare over the candidate axis (no
data-dependent control flow), the merged result is written back sorted,
and because each round applies the same (sim desc, row asc) order the
final (k, B) output is **bit-identical** to the reference oracle's global
selection — including the order of tied entries (property-swept in
``tests/test_kernels.py``). ``core.memory_sharded`` reuses the identical
rule to merge per-shard top-k candidates into the global top-k, which is
what keeps the sharded result bit-identical to single-device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 1024

# mask bit plane (shared with core.memory / kernels.ref)
MASK_VALID = 1
MASK_GUIDE = 2

_ROW_TILE = 8        # f32 sublane tile: padded row counts are multiples


def padded_rows(c: int, block_c: int = DEFAULT_BLOCK_C) -> int:
    """Row count of the persistent kernel layout for a capacity-``c``
    store: always a multiple of the row tile (so a block size exists for
    any ``block_c``), up to one full block."""
    tile = min(block_c, _round_up(c, _ROW_TILE))
    return _round_up(c, _round_up(tile, _ROW_TILE))


def padded_lanes(e: int) -> int:
    """Lane count of the persistent kernel layout for embed dim ``e``."""
    return _round_up(e, 128)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pick_block(cp: int, block_c: int) -> int:
    """Largest row-tile multiple ≤ block_c that divides the padded row
    count (cp being a multiple of the tile guarantees a solution — at
    worst one tile per block)."""
    if cp % _ROW_TILE:
        raise ValueError(f"padded row count {cp} is not a multiple of the "
                         f"row tile {_ROW_TILE}; build the store with "
                         f"padded_rows()/to_padded_layout()")
    bc = max(min(block_c, cp) // _ROW_TILE * _ROW_TILE, _ROW_TILE)
    while cp % bc:
        bc -= _ROW_TILE
    return bc


def to_padded_layout(mem: jax.Array, mask: jax.Array,
                     *, block_c: int = DEFAULT_BLOCK_C
                     ) -> tuple[jax.Array, jax.Array]:
    """One-time layout conversion: compact (C, E) store + (C,) mask →
    padded (Cp, Ep) store + (Cp, 1) int32 bit plane. This is the *only*
    place the full store is copied; it runs at init/import time (or in the
    legacy wrappers), never per query."""
    C, E = mem.shape
    Cp = padded_rows(C, block_c)
    Ep = padded_lanes(E)
    memp = jnp.pad(mem, ((0, Cp - C), (0, Ep - E)))
    if mask.dtype == jnp.bool_ or mask.dtype == bool:
        bits = mask.astype(jnp.int32) * MASK_VALID
    else:
        bits = mask.astype(jnp.int32)
    maskp = jnp.pad(bits, (0, Cp - C))[:, None]
    return memp, maskp


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _top1_kernel(q_ref, mem_ref, mask_ref, sim_ref, idx_ref, *,
                 block_c: int, required: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sim_ref[0, 0] = -2.0
        idx_ref[0, 0] = 0

    block = mem_ref[...].astype(jnp.float32)          # (BC, E)
    q = q_ref[...].astype(jnp.float32)                # (1, E)
    sims = jax.lax.dot_general(block, q, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (BC, 1)
    valid = (mask_ref[...] & required) == required    # (BC, 1)
    sims = jnp.where(valid, sims, -2.0)

    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0)
    best = jnp.max(sims)
    # lowest row index achieving the max (deterministic tie-break)
    best_row = jnp.min(jnp.where(sims >= best, rows, jnp.int32(2 ** 30)))

    @pl.when(best > sim_ref[0, 0])
    def _update():
        sim_ref[0, 0] = best
        idx_ref[0, 0] = (i * block_c + best_row).astype(jnp.int32)


def _top1_batch_kernel(q_ref, mem_ref, mask_ref, sim_ref, idx_ref, *,
                       block_c: int, required: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sim_ref[...] = jnp.full(sim_ref.shape, -2.0, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    block = mem_ref[...].astype(jnp.float32)          # (BC, E)
    qs = q_ref[...].astype(jnp.float32)               # (B, E)
    sims = jax.lax.dot_general(block, qs, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (BC, B)
    valid = (mask_ref[...] & required) == required    # (BC, 1)
    sims = jnp.where(valid, sims, -2.0)

    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0)
    best = jnp.max(sims, axis=0)                      # (B,)
    # lowest row index achieving each column's max (deterministic tie-break)
    best_row = jnp.min(jnp.where(sims >= best[None, :], rows,
                                 jnp.int32(2 ** 30)), axis=0)       # (B,)
    prev = sim_ref[0, :]
    take = best > prev
    sim_ref[0, :] = jnp.where(take, best, prev)
    idx_ref[0, :] = jnp.where(take,
                              (i * block_c + best_row).astype(jnp.int32),
                              idx_ref[0, :])


def _select_topk(sims, rows, k: int):
    """k selection-extraction rounds over the leading candidate axis: each
    round takes the max sim, resolves ties to the lowest row, then consumes
    that candidate. Returns ((k, ...) sims, (k, ...) rows) sorted by
    (sim desc, row asc) — THE top-k total order, shared verbatim with the
    reference oracle and the sharded cross-device merge so all three
    produce bit-identical results (ties included)."""
    out_s, out_r = [], []
    for _ in range(k):
        best = jnp.max(sims, axis=0)
        best_row = jnp.min(jnp.where(sims >= best[None], rows,
                                     jnp.int32(2 ** 30)), axis=0)
        out_s.append(best)
        out_r.append(best_row)
        consumed = (sims >= best[None]) & (rows == best_row[None])
        sims = jnp.where(consumed, jnp.float32(-3.0), sims)
    return jnp.stack(out_s), jnp.stack(out_r)


def _topk_batch_kernel(q_ref, mem_ref, mask_ref, sim_ref, idx_ref, *,
                       block_c: int, k: int, required: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sim_ref[...] = jnp.full(sim_ref.shape, -3.0, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, 2 ** 30, jnp.int32)

    block = mem_ref[...].astype(jnp.float32)          # (BC, E)
    qs = q_ref[...].astype(jnp.float32)               # (B, E)
    sims = jax.lax.dot_general(block, qs, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (BC, B)
    valid = (mask_ref[...] & required) == required    # (BC, 1)
    sims = jnp.where(valid, sims, -2.0)
    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0) + i * block_c

    # merge block candidates into the (k, B) running-best accumulator
    cand_s = jnp.concatenate([sim_ref[...], sims], axis=0)   # (k + BC, B)
    cand_r = jnp.concatenate([idx_ref[...], rows], axis=0)
    new_s, new_r = _select_topk(cand_s, cand_r, k)
    sim_ref[...] = new_s
    idx_ref[...] = new_r


# ---------------------------------------------------------------------------
# Zero-copy entry points — store already in kernel layout
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("required", "block_c", "interpret"))
def memory_top1_padded_pallas(mem: jax.Array, q: jax.Array, mask: jax.Array,
                              *, required: int = MASK_VALID,
                              block_c: int = DEFAULT_BLOCK_C,
                              interpret: bool = False
                              ) -> tuple[jax.Array, jax.Array]:
    """mem: (Cp, Ep) padded store; q: (E,); mask: (Cp, 1) int32 bit plane
    → (sim (), idx ()). Zero-copy: only the (1, E) query is padded."""
    Cp, Ep = mem.shape
    E = q.shape[0]
    qp = jnp.zeros((1, Ep), jnp.float32).at[0, :E].set(q.astype(jnp.float32))
    bc = _pick_block(Cp, block_c)

    grid = (Cp // bc,)
    sim, idx = pl.pallas_call(
        functools.partial(_top1_kernel, block_c=bc, required=required),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bc, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1, 1),
                         index_map=lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1, 1),
                         index_map=lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(qp, mem, mask)
    return sim[0, 0], idx[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("required", "block_c", "interpret"))
def memory_top1_batch_padded_pallas(mem: jax.Array, qs: jax.Array,
                                    mask: jax.Array,
                                    *, required: int = MASK_VALID,
                                    block_c: int = DEFAULT_BLOCK_C,
                                    interpret: bool = False
                                    ) -> tuple[jax.Array, jax.Array]:
    """mem: (Cp, Ep) padded store; qs: (B, E); mask: (Cp, 1) int32 bit
    plane → (sims (B,), idx (B,)). Zero-copy: only the (B, E) query block
    is padded — O(B·E), independent of capacity.

    The B queries are VMEM-resident for the whole store pass; the running
    per-query best is a (1, B) VMEM accumulator revisited every grid step.
    """
    Cp, Ep = mem.shape
    B, E = qs.shape
    Bp = _round_up(B, 128)
    qp = jnp.zeros((Bp, Ep), jnp.float32).at[:B, :E].set(
        qs.astype(jnp.float32))
    bc = _pick_block(Cp, block_c)

    grid = (Cp // bc,)
    sims, idx = pl.pallas_call(
        functools.partial(_top1_batch_kernel, block_c=bc, required=required),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bp, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bc, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Bp), lambda i: (0, 0)),
            pl.BlockSpec((1, Bp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Bp), jnp.float32),
            jax.ShapeDtypeStruct((1, Bp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, mem, mask)
    return sims[0, :B], idx[0, :B]


@functools.partial(jax.jit,
                   static_argnames=("k", "required", "block_c", "interpret"))
def memory_topk_batch_padded_pallas(mem: jax.Array, qs: jax.Array,
                                    mask: jax.Array, *, k: int,
                                    required: int = MASK_VALID,
                                    block_c: int = DEFAULT_BLOCK_C,
                                    interpret: bool = False
                                    ) -> tuple[jax.Array, jax.Array]:
    """mem: (Cp, Ep) padded store; qs: (B, E); mask: (Cp, 1) int32 bit
    plane → (sims (B, k), idx (B, k)) sorted by (sim desc, row asc).
    Same zero-copy single-pass contract as the top-1 batch kernel; the
    running best-k is a (k, B) VMEM accumulator pair (see module
    docstring for the layout and merge rule)."""
    Cp, Ep = mem.shape
    B, E = qs.shape
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    bc = _pick_block(Cp, block_c)
    if k > bc:
        raise ValueError(f"k={k} exceeds the kernel block of {bc} rows; "
                         f"raise block_c (or shrink k)")
    Bp = _round_up(B, 128)
    qp = jnp.zeros((Bp, Ep), jnp.float32).at[:B, :E].set(
        qs.astype(jnp.float32))

    grid = (Cp // bc,)
    sims, idx = pl.pallas_call(
        functools.partial(_topk_batch_kernel, block_c=bc, k=k,
                          required=required),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bp, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bc, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, Bp), lambda i: (0, 0)),
            pl.BlockSpec((k, Bp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, Bp), jnp.float32),
            jax.ShapeDtypeStruct((k, Bp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, mem, mask)
    return sims[:, :B].T, idx[:, :B].T


def memory_topk_padded_pallas(mem: jax.Array, q: jax.Array, mask: jax.Array,
                              *, k: int, required: int = MASK_VALID,
                              block_c: int = DEFAULT_BLOCK_C,
                              interpret: bool = False
                              ) -> tuple[jax.Array, jax.Array]:
    """Single-query top-k: mem (Cp, Ep); q (E,); mask (Cp, 1) →
    (sims (k,), idx (k,)) sorted by (sim desc, row asc). Shares the batch
    kernel body (one query column resident in VMEM); the jit cache is the
    batch entry's. The result order is bit-identical to the matvec-shaped
    reference oracle; the sim *values* may differ in the last ulp on CPU
    hosts (the lane-padded query block takes BLAS's gemm path where a bare
    (E,) query takes gemv) — ties can't be affected, since tied rows are
    bitwise-equal dot products within either path."""
    sims, idx = memory_topk_batch_padded_pallas(
        mem, q[None, :], mask, k=k, required=required, block_c=block_c,
        interpret=interpret)
    return sims[0], idx[0]


# ---------------------------------------------------------------------------
# Legacy compact-layout wrappers (shape sweeps / one-off calls only).
# Deliberately NOT jitted: the layout conversion runs eagerly, outside any
# per-query jitted function — the serving path never goes through here.
# ---------------------------------------------------------------------------


def memory_top1_pallas(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       *, block_c: int = DEFAULT_BLOCK_C,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E); q: (E,); mask: (C,) bool → (sim (), idx ())."""
    memp, maskp = to_padded_layout(mem, mask, block_c=block_c)
    return memory_top1_padded_pallas(memp, q, maskp, block_c=block_c,
                                     interpret=interpret)


def memory_top1_batch_pallas(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             *, block_c: int = DEFAULT_BLOCK_C,
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E); qs: (B, E); mask: (C,) bool → (sims (B,), idx (B,))."""
    memp, maskp = to_padded_layout(mem, mask, block_c=block_c)
    return memory_top1_batch_padded_pallas(memp, qs, maskp, block_c=block_c,
                                           interpret=interpret)
