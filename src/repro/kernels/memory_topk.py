"""Pallas TPU kernel: fused cosine-similarity + masked top-1 over the RAR
skill/guide memory.

This is the per-request critical path of the paper's system (§III-F): every
incoming request queries the vector store before any FM inference. The
kernel streams the (capacity, E) store through VMEM in row blocks, computes
the similarity on the MXU, and carries the running (best sim, best index)
in SMEM across grid steps — one HBM pass, no (capacity,) score vector ever
written back.

Block shape: (BLOCK_C, E). E is 384 → zero-padded to 512 by the wrapper so
the lane dim is a multiple of 128; BLOCK_C defaults to 1024 rows →
1024×512×4 B = 2 MiB per block in VMEM.

Two entry points share the streaming layout:

* :func:`memory_top1_pallas` — one query, running best carried in SMEM.
* :func:`memory_top1_batch_pallas` — the microbatched data plane
  (``core.pipeline``): all B queries stay resident in VMEM while the store
  makes the same single HBM pass; each (BLOCK_C, E)×(B, E)ᵀ product lands
  on the MXU and the per-query running (best sim, best index) pair is a
  (1, B) VMEM accumulator updated with a vector compare. One pass serves
  the whole microbatch — the HBM traffic is amortised B-fold, which is
  exactly the paper's per-request vector-DB lookup cost divided by the
  serving batch size. Microbatch-commit semantics (reads at batch start,
  writes once at batch end) live in ``core.memory.add_batch``; this kernel
  is the read side.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 1024


def _top1_kernel(q_ref, mem_ref, mask_ref, sim_ref, idx_ref, *, block_c: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sim_ref[0, 0] = -2.0
        idx_ref[0, 0] = 0

    block = mem_ref[...].astype(jnp.float32)          # (BC, E)
    q = q_ref[...].astype(jnp.float32)                # (1, E)
    sims = jax.lax.dot_general(block, q, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (BC, 1)
    valid = mask_ref[...] != 0                        # (BC, 1)
    sims = jnp.where(valid, sims, -2.0)

    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0)
    best = jnp.max(sims)
    # lowest row index achieving the max (deterministic tie-break)
    best_row = jnp.min(jnp.where(sims >= best, rows, jnp.int32(2 ** 30)))

    @pl.when(best > sim_ref[0, 0])
    def _update():
        sim_ref[0, 0] = best
        idx_ref[0, 0] = (i * block_c + best_row).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def memory_top1_pallas(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       *, block_c: int = DEFAULT_BLOCK_C,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E); q: (E,); mask: (C,) bool → (sim (), idx ())."""
    C, E = mem.shape
    bc = min(block_c, C)
    # pad rows to a multiple of the block, lanes to a multiple of 128
    Cp = ((C + bc - 1) // bc) * bc
    Ep = ((E + 127) // 128) * 128
    memp = jnp.zeros((Cp, Ep), mem.dtype).at[:C, :E].set(mem)
    qp = jnp.zeros((1, Ep), jnp.float32).at[0, :E].set(q.astype(jnp.float32))
    maskp = jnp.zeros((Cp, 1), jnp.int32).at[:C, 0].set(mask.astype(jnp.int32))

    grid = (Cp // bc,)
    sim, idx = pl.pallas_call(
        functools.partial(_top1_kernel, block_c=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bc, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1, 1),
                         index_map=lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1, 1),
                         index_map=lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(qp, memp, maskp)
    return sim[0, 0], idx[0, 0]


# ---------------------------------------------------------------------------
# Multi-query top-1 — the batched data plane
# ---------------------------------------------------------------------------


def _top1_batch_kernel(q_ref, mem_ref, mask_ref, sim_ref, idx_ref, *,
                       block_c: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sim_ref[...] = jnp.full(sim_ref.shape, -2.0, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    block = mem_ref[...].astype(jnp.float32)          # (BC, E)
    qs = q_ref[...].astype(jnp.float32)               # (B, E)
    sims = jax.lax.dot_general(block, qs, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (BC, B)
    valid = mask_ref[...] != 0                        # (BC, 1)
    sims = jnp.where(valid, sims, -2.0)

    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0)
    best = jnp.max(sims, axis=0)                      # (B,)
    # lowest row index achieving each column's max (deterministic tie-break)
    best_row = jnp.min(jnp.where(sims >= best[None, :], rows,
                                 jnp.int32(2 ** 30)), axis=0)       # (B,)
    prev = sim_ref[0, :]
    take = best > prev
    sim_ref[0, :] = jnp.where(take, best, prev)
    idx_ref[0, :] = jnp.where(take,
                              (i * block_c + best_row).astype(jnp.int32),
                              idx_ref[0, :])


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def memory_top1_batch_pallas(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             *, block_c: int = DEFAULT_BLOCK_C,
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E); qs: (B, E); mask: (C,) bool → (sims (B,), idx (B,)).

    The B queries are VMEM-resident for the whole store pass; the running
    per-query best is a (1, B) VMEM accumulator revisited every grid step.
    """
    C, E = mem.shape
    B = qs.shape[0]
    bc = min(block_c, C)
    # rows to a multiple of the block; lanes (E and B) to multiples of 128
    Cp = ((C + bc - 1) // bc) * bc
    Ep = ((E + 127) // 128) * 128
    Bp = ((B + 127) // 128) * 128
    memp = jnp.zeros((Cp, Ep), mem.dtype).at[:C, :E].set(mem)
    qp = jnp.zeros((Bp, Ep), jnp.float32).at[:B, :E].set(
        qs.astype(jnp.float32))
    maskp = jnp.zeros((Cp, 1), jnp.int32).at[:C, 0].set(mask.astype(jnp.int32))

    grid = (Cp // bc,)
    sims, idx = pl.pallas_call(
        functools.partial(_top1_batch_kernel, block_c=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bp, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bc, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Bp), lambda i: (0, 0)),
            pl.BlockSpec((1, Bp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Bp), jnp.float32),
            jax.ShapeDtypeStruct((1, Bp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, memp, maskp)
    return sims[0, :B], idx[0, :B]
