"""Pallas TPU kernel: blocked online-softmax (flash) attention with causal
masking, optional sliding window, and GQA.

Serving hot spot: prefill at 32k context. The (Sq, Sk) score matrix never
leaves VMEM; fully-masked KV blocks are *skipped* — for a window-1024 layer
at 32k context that's a ~32× reduction in attended blocks, which is exactly
the gemma3 local-layer win the §Perf log quantifies.

Layout: wrapper transposes to head-major (B, H, S, hd) so each grid step
owns one (q-block, k-block) tile per head. Grid = (B, H, nq, nk), k-block
innermost (TPU grids iterate the last axis fastest) with the running
(m, l, acc) state carried in VMEM scratch across k-steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = iq * block_q
    k0 = ik * block_k
    # block-level skip: any (qi, kj) with kj <= qi (causal) and
    # qi - kj < window (sliding window) inside this tile?
    needed = jnp.bool_(True)
    if causal:
        needed &= k0 <= q0 + block_q - 1
    if window > 0:
        needed &= q0 - (k0 + block_k - 1) < window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with Sq == Sk (prefill
    self-attention). Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)

    qt = jnp.moveaxis(q, 2, 1)   # (B, H, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1)   # (B, KV, Sk, hd)
    vt = jnp.moveaxis(v, 2, 1)

    grid = (B, H, Sq // bq, Sk // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=s, causal=causal,
                          window=window, block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
