"""Pallas TPU kernel: flash-decode — one query token against a long KV
cache, blocked over cache length, GQA-aware, with optional sliding window.

Powers ``decode_32k`` / ``long_500k``: at 500k cache entries the score
vector alone is 500k floats per head — this kernel streams the cache in
(BLOCK_M, hd) tiles, keeps the online-softmax state for all G query heads
of one KV group in VMEM, and (with ``window > 0``) skips every block
entirely outside the attention window — the sliding-window decode variant
reduces the memory term from O(cache) to O(window).

Grid = (B, KV, nm), cache blocks innermost. cache_len rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
DEFAULT_BLOCK_M = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, window: int, block_m: int):
    im = pl.program_id(2)
    nm = pl.num_programs(2)

    @pl.when(im == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    m0 = im * block_m
    needed = m0 < cache_len
    if window > 0:
        needed &= m0 + block_m > cache_len - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (BM, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, BM)
        pos = m0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < cache_len
        if window > 0:
            mask &= pos >= cache_len - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (BM, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(im == nm - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_m", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            cache_len: jax.Array, *, window: int = 0,
                            scale: float | None = None,
                            block_m: int = DEFAULT_BLOCK_M,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k, v: (B, M, KV, hd); cache_len: () int32 shared
    across the batch. Returns (B, H, hd)."""
    B, H, hd = q.shape
    M, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    bm = min(block_m, M)
    assert M % bm == 0, (M, bm)

    qt = q.reshape(B, KV, G, hd)
    kt = jnp.moveaxis(k, 2, 1)   # (B, KV, M, hd)
    vt = jnp.moveaxis(v, 2, 1)
    clen = jnp.reshape(cache_len.astype(jnp.int32), (1,))

    grid = (B, KV, M // bm)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=s, window=window,
                          block_m=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1,),
                         index_map=lambda b, h, i: (0,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bm, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bm, hd), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(clen, qt, kt, vt)
    return out.reshape(B, H, hd)
