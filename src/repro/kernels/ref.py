"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: kernel tests sweep shapes/dtypes
and assert_allclose against these, and the CPU execution path of the
framework routes through them (Pallas TPU kernels run in interpret mode
only under tests on this host).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# memory_top1: fused cosine similarity + masked argmax over the memory store
# ---------------------------------------------------------------------------


def memory_top1(mem: jax.Array, q: jax.Array, mask: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E) rows assumed unit-or-zero norm; q: (E,) unit norm;
    mask: (C,) bool. Returns (best sim () f32 — -2.0 if mask empty,
    best index () int32)."""
    sims = mem.astype(jnp.float32) @ q.astype(jnp.float32)
    sims = jnp.where(mask, sims, -2.0)
    idx = jnp.argmax(sims).astype(jnp.int32)
    return sims[idx], idx


def memory_top1_padded(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       required: int = 1) -> tuple[jax.Array, jax.Array]:
    """Padded-layout oracle (the zero-copy contract of
    ``kernels.memory_topk``): mem (Cp, Ep) with zero padding rows/lanes;
    q (E,) — zero-padded to Ep here, an O(E) copy; mask (Cp, 1) int32 bit
    plane. A row participates iff it carries every bit of ``required``
    (padding rows are 0 → never valid). Ties break to the lowest row."""
    Ep = mem.shape[1]
    qp = jnp.zeros((Ep,), jnp.float32).at[:q.shape[0]].set(
        q.astype(jnp.float32))
    sims = mem.astype(jnp.float32) @ qp
    sims = jnp.where((mask[:, 0] & required) == required, sims, -2.0)
    idx = jnp.argmax(sims).astype(jnp.int32)
    return sims[idx], idx


def memory_top1_batch_padded(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             required: int = 1
                             ) -> tuple[jax.Array, jax.Array]:
    """Padded-layout multi-query oracle: qs (B, E) → (sims (B,), idx (B,)).
    Only the query block is padded (O(B·E), capacity-independent)."""
    B, E = qs.shape
    Ep = mem.shape[1]
    qp = jnp.zeros((B, Ep), jnp.float32).at[:, :E].set(
        qs.astype(jnp.float32))
    sims = qp @ mem.astype(jnp.float32).T                       # (B, Cp)
    sims = jnp.where(((mask[:, 0] & required) == required)[None, :],
                     sims, -2.0)
    idx = jnp.argmax(sims, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(sims, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0], idx


def memory_top1_batch(mem: jax.Array, qs: jax.Array, mask: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Multi-query variant: qs (B, E) unit-norm rows. Returns
    (sims (B,) f32 — -2.0 where mask empty, idx (B,) int32). Ties break to
    the lowest row index, matching the blocked kernel."""
    sims = qs.astype(jnp.float32) @ mem.astype(jnp.float32).T   # (B, C)
    sims = jnp.where(mask[None, :], sims, -2.0)
    idx = jnp.argmax(sims, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(sims, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0], idx


def _topk_select(sims: jax.Array, rows: jax.Array, k: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Select the top-k candidates over the leading axis by the order
    (sim descending, row ascending): k rounds of max → lowest-row
    tie-break → consume. This is the ground-truth definition of the top-k
    total order; the Pallas kernel's (k, B) accumulator merge and the
    sharded cross-device combine must both reproduce it bit-for-bit
    (±0.0 similarities compare equal, so only the row decides their
    order — IEEE compare, not the total-order sort of ``lax.top_k``)."""
    out_s, out_r = [], []
    for _ in range(k):
        best = jnp.max(sims, axis=0)
        at_best = sims >= best[None]
        best_row = jnp.min(jnp.where(at_best, rows, jnp.int32(2 ** 30)),
                           axis=0)
        out_s.append(best)
        out_r.append(best_row)
        sims = jnp.where(at_best & (rows == best_row[None]),
                         jnp.float32(-3.0), sims)
    return jnp.stack(out_s), jnp.stack(out_r)


def memory_topk(mem: jax.Array, q: jax.Array, mask: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Compact-layout top-k: mem (C, E); q (E,); mask (C,) bool →
    (sims (k,), idx (k,)) sorted by (sim desc, row asc)."""
    sims = mem.astype(jnp.float32) @ q.astype(jnp.float32)
    sims = jnp.where(mask, sims, -2.0)
    rows = jnp.arange(sims.shape[0], dtype=jnp.int32)
    top_sims, top_idx = _topk_select(sims, rows, k)
    return top_sims, top_idx


def memory_topk_padded(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       k: int, required: int = 1
                       ) -> tuple[jax.Array, jax.Array]:
    """Padded-layout top-k oracle: mem (Cp, Ep) zero-padded; q (E,);
    mask (Cp, 1) int32 bit plane → (sims (k,), idx (k,)) sorted by
    (sim desc, row asc). Slots past the view's population surface as the
    -2.0 sentinel on the lowest masked-out rows (same degradation as the
    top-1 oracle's empty-view case)."""
    Ep = mem.shape[1]
    qp = jnp.zeros((Ep,), jnp.float32).at[:q.shape[0]].set(
        q.astype(jnp.float32))
    sims = mem.astype(jnp.float32) @ qp
    sims = jnp.where((mask[:, 0] & required) == required, sims, -2.0)
    rows = jnp.arange(sims.shape[0], dtype=jnp.int32)
    return _topk_select(sims, rows, k)


def memory_topk_batch_padded(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             k: int, required: int = 1
                             ) -> tuple[jax.Array, jax.Array]:
    """Padded-layout multi-query top-k oracle: qs (B, E) →
    (sims (B, k), idx (B, k)), each query's k results sorted by
    (sim desc, row asc)."""
    B, E = qs.shape
    Ep = mem.shape[1]
    qp = jnp.zeros((B, Ep), jnp.float32).at[:, :E].set(
        qs.astype(jnp.float32))
    sims = mem.astype(jnp.float32) @ qp.T                       # (Cp, B)
    sims = jnp.where(((mask[:, 0] & required) == required)[:, None],
                     sims, -2.0)
    rows = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 0)
    top_sims, top_idx = _topk_select(sims, rows, k)             # (k, B)
    return top_sims.T, top_idx.T


# ---------------------------------------------------------------------------
# ivf_route: centroid routing for the two-level (IVF) retrieval plane
# ---------------------------------------------------------------------------


def ivf_route_padded(cent: jax.Array, q: jax.Array, cmask: jax.Array,
                     n_probe: int, required: int = 1
                     ) -> tuple[jax.Array, jax.Array]:
    """Centroid-routing oracle: cent (Pp, Ep) padded centroid plane;
    q (E,); cmask (Pp, 1) int32 bit plane → (scores (n_probe,),
    cids (n_probe,)) sorted by (score desc, centroid row asc). The
    routing selection is the *same* top-k total order as the store scan
    (:func:`_topk_select`), which is what makes per-shard centroid-subset
    routes merge bit-identically into the global route."""
    return memory_topk_padded(cent, q, cmask, n_probe, required)


def ivf_route_batch_padded(cent: jax.Array, qs: jax.Array, cmask: jax.Array,
                           n_probe: int, required: int = 1
                           ) -> tuple[jax.Array, jax.Array]:
    """Multi-query centroid-routing oracle: qs (B, E) →
    (scores (B, n_probe), cids (B, n_probe))."""
    return memory_topk_batch_padded(cent, qs, cmask, n_probe, required)


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window, GQA)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Positions are aligned to
    the sequence end (self-attention: Sq == Sk)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) * s
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    diff = qpos - kpos
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention: one query position against a long KV cache
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     scale: float | None = None) -> jax.Array:
    """q: (B, H, hd) single position; k, v: (B, M, KV, hd) cache;
    cache_len: () or (B,) valid entries (query at position cache_len-1).
    """
    B, H, hd = q.shape
    M, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * s
    scores = jnp.einsum("bkgh,bmkh->bkgm", qg, k.astype(jnp.float32))
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    kpos = jnp.arange(M)[None, :]
    mask = kpos < cl[:, None]
    if window > 0:
        mask &= kpos >= cl[:, None] - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgm,bmkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
