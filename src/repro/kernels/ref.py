"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: kernel tests sweep shapes/dtypes
and assert_allclose against these, and the CPU execution path of the
framework routes through them (Pallas TPU kernels run in interpret mode
only under tests on this host).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# memory_top1: fused cosine similarity + masked argmax over the memory store
# ---------------------------------------------------------------------------


def memory_top1(mem: jax.Array, q: jax.Array, mask: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """mem: (C, E) rows assumed unit-or-zero norm; q: (E,) unit norm;
    mask: (C,) bool. Returns (best sim () f32 — -2.0 if mask empty,
    best index () int32)."""
    sims = mem.astype(jnp.float32) @ q.astype(jnp.float32)
    sims = jnp.where(mask, sims, -2.0)
    idx = jnp.argmax(sims).astype(jnp.int32)
    return sims[idx], idx


def memory_top1_padded(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       required: int = 1) -> tuple[jax.Array, jax.Array]:
    """Padded-layout oracle (the zero-copy contract of
    ``kernels.memory_topk``): mem (Cp, Ep) with zero padding rows/lanes;
    q (E,) — zero-padded to Ep here, an O(E) copy; mask (Cp, 1) int32 bit
    plane. A row participates iff it carries every bit of ``required``
    (padding rows are 0 → never valid). Ties break to the lowest row."""
    Ep = mem.shape[1]
    qp = jnp.zeros((Ep,), jnp.float32).at[:q.shape[0]].set(
        q.astype(jnp.float32))
    sims = mem.astype(jnp.float32) @ qp
    sims = jnp.where((mask[:, 0] & required) == required, sims, -2.0)
    idx = jnp.argmax(sims).astype(jnp.int32)
    return sims[idx], idx


def memory_top1_batch_padded(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             required: int = 1
                             ) -> tuple[jax.Array, jax.Array]:
    """Padded-layout multi-query oracle: qs (B, E) → (sims (B,), idx (B,)).
    Only the query block is padded (O(B·E), capacity-independent)."""
    B, E = qs.shape
    Ep = mem.shape[1]
    qp = jnp.zeros((B, Ep), jnp.float32).at[:, :E].set(
        qs.astype(jnp.float32))
    sims = qp @ mem.astype(jnp.float32).T                       # (B, Cp)
    sims = jnp.where(((mask[:, 0] & required) == required)[None, :],
                     sims, -2.0)
    idx = jnp.argmax(sims, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(sims, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0], idx


def memory_top1_batch(mem: jax.Array, qs: jax.Array, mask: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Multi-query variant: qs (B, E) unit-norm rows. Returns
    (sims (B,) f32 — -2.0 where mask empty, idx (B,) int32). Ties break to
    the lowest row index, matching the blocked kernel."""
    sims = qs.astype(jnp.float32) @ mem.astype(jnp.float32).T   # (B, C)
    sims = jnp.where(mask[None, :], sims, -2.0)
    idx = jnp.argmax(sims, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(sims, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0], idx


def memory_topk(mem: jax.Array, q: jax.Array, mask: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Top-k variant. Returns (sims (k,), idx (k,)) sorted descending."""
    sims = mem.astype(jnp.float32) @ q.astype(jnp.float32)
    sims = jnp.where(mask, sims, -2.0)
    top_sims, top_idx = jax.lax.top_k(sims, k)
    return top_sims, top_idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window, GQA)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Positions are aligned to
    the sequence end (self-attention: Sq == Sk)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) * s
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    diff = qpos - kpos
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention: one query position against a long KV cache
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     scale: float | None = None) -> jax.Array:
    """q: (B, H, hd) single position; k, v: (B, M, KV, hd) cache;
    cache_len: () or (B,) valid entries (query at position cache_len-1).
    """
    B, H, hd = q.shape
    M, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * s
    scores = jnp.einsum("bkgh,bmkh->bkgm", qg, k.astype(jnp.float32))
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    kpos = jnp.arange(M)[None, :]
    mask = kpos < cl[:, None]
    if window > 0:
        mask &= kpos >= cl[:, None] - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgm,bmkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
