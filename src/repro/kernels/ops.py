"""Jit'd dispatch layer over the Pallas kernels and their jnp oracles.

Selection order:
* ``REPRO_KERNEL_IMPL=ref|pallas|interpret`` env var wins,
* otherwise: ``pallas`` on TPU backends, ``ref`` elsewhere (this CPU
  container). ``interpret`` runs the Pallas kernel bodies in Python — used
  by the test suite to validate the TPU kernels against the oracles.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.memory_topk import (memory_top1_batch_pallas,
                                       memory_top1_pallas)


def _default_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    try:
        platform = jax.default_backend()
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def memory_top1(mem: jax.Array, q: jax.Array, mask: jax.Array,
                impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1(mem, q, mask)
    return memory_top1_pallas(mem, q, mask, interpret=(impl == "interpret"))


def memory_top1_batch(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                      impl: str | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Multi-query top-1: qs (B, E) against mem (C, E) in one store pass."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1_batch(mem, qs, mask)
    return memory_top1_batch_pallas(mem, qs, mask,
                                    interpret=(impl == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale,
                                  interpret=(impl == "interpret"))


def decode_attention(q, k, v, cache_len, *, window=0, scale=None,
                     impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.decode_attention(q, k, v, cache_len, window=window,
                                    scale=scale)
    return decode_attention_pallas(q, k, v, cache_len, window=window,
                                   scale=scale,
                                   interpret=(impl == "interpret"))
