"""Jit'd dispatch layer over the Pallas kernels and their jnp oracles.

Selection order:
* an explicit :func:`set_impl` override (tests) wins,
* then ``REPRO_KERNEL_IMPL=ref|pallas|interpret`` env var,
* otherwise: ``pallas`` on TPU backends, ``ref`` elsewhere (this CPU
  container). ``interpret`` runs the Pallas kernel bodies in Python — used
  by the test suite to validate the TPU kernels against the oracles.

The selection is resolved **once** and memoized: the old per-dispatch
``os.environ`` read + ``jax.default_backend()`` probe sat on the hot loop
(every memory query / attention call paid it). Resolution is lazy — first
dispatch, not import — so importing this module never touches jax backend
state. Tests flip implementations via :func:`set_impl`; ``set_impl(None)``
re-resolves from the environment.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.memory_ivf import (ivf_route_batch_padded_pallas,
                                      ivf_route_padded_pallas)
from repro.kernels.memory_topk import (MASK_VALID,
                                       memory_top1_batch_padded_pallas,
                                       memory_top1_batch_pallas,
                                       memory_top1_padded_pallas,
                                       memory_top1_pallas,
                                       memory_topk_batch_padded_pallas,
                                       memory_topk_padded_pallas)

_impl_cache: str | None = None


def set_impl(impl: str | None) -> None:
    """Override the kernel implementation (``ref``/``pallas``/
    ``interpret``), or ``None`` to re-resolve from the environment on the
    next dispatch. The explicit hook for tests — mutating
    ``REPRO_KERNEL_IMPL`` after the first dispatch has no effect."""
    global _impl_cache
    if impl not in (None, "ref", "pallas", "interpret"):
        raise ValueError(f"unknown kernel impl {impl!r}")
    _impl_cache = impl


def _default_impl() -> str:
    global _impl_cache
    if _impl_cache is None:
        env = os.environ.get("REPRO_KERNEL_IMPL")
        if env:
            if env not in ("ref", "pallas", "interpret"):
                raise ValueError(
                    f"REPRO_KERNEL_IMPL={env!r}: expected "
                    f"ref|pallas|interpret")
            _impl_cache = env
        else:
            try:
                platform = jax.default_backend()
            except RuntimeError:
                platform = "cpu"
            _impl_cache = "pallas" if platform == "tpu" else "ref"
    return _impl_cache


def memory_top1(mem: jax.Array, q: jax.Array, mask: jax.Array,
                impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1(mem, q, mask)
    return memory_top1_pallas(mem, q, mask, interpret=(impl == "interpret"))


def memory_top1_batch(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                      impl: str | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Multi-query top-1: qs (B, E) against mem (C, E) in one store pass."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1_batch(mem, qs, mask)
    return memory_top1_batch_pallas(mem, qs, mask,
                                    interpret=(impl == "interpret"))


def memory_top1_padded(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       required: int = MASK_VALID,
                       impl: str | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Zero-copy top-1 over a store already in kernel layout: mem (Cp, Ep),
    mask (Cp, 1) int32 bit plane, ``required`` the bit set a row must carry
    (see ``kernels.memory_topk``). The serving dispatch path."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1_padded(mem, q, mask, required)
    return memory_top1_padded_pallas(mem, q, mask, required=required,
                                     interpret=(impl == "interpret"))


def memory_top1_batch_padded(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             required: int = MASK_VALID,
                             impl: str | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Zero-copy multi-query top-1 over the padded kernel layout."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_top1_batch_padded(mem, qs, mask, required)
    return memory_top1_batch_padded_pallas(mem, qs, mask, required=required,
                                           interpret=(impl == "interpret"))


def memory_topk_padded(mem: jax.Array, q: jax.Array, mask: jax.Array,
                       k: int, required: int = MASK_VALID,
                       impl: str | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Zero-copy top-k over the padded kernel layout: (sims (k,),
    idx (k,)) sorted by (sim desc, row asc). The multi-guide serving
    dispatch path (``core.memory.query_topk``)."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_topk_padded(mem, q, mask, k, required)
    return memory_topk_padded_pallas(mem, q, mask, k=k, required=required,
                                     interpret=(impl == "interpret"))


def memory_topk_batch_padded(mem: jax.Array, qs: jax.Array, mask: jax.Array,
                             k: int, required: int = MASK_VALID,
                             impl: str | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Zero-copy multi-query top-k over the padded kernel layout:
    (sims (B, k), idx (B, k))."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.memory_topk_batch_padded(mem, qs, mask, k, required)
    return memory_topk_batch_padded_pallas(mem, qs, mask, k=k,
                                           required=required,
                                           interpret=(impl == "interpret"))


def ivf_route_padded(cent: jax.Array, q: jax.Array, cmask: jax.Array,
                     n_probe: int, required: int = MASK_VALID,
                     impl: str | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Level-1 centroid route over the padded centroid plane:
    (scores (n_probe,), cids (n_probe,)) sorted by (score desc, row asc).
    The IVF dispatch path (``core.memory_ivf``)."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.ivf_route_padded(cent, q, cmask, n_probe, required)
    return ivf_route_padded_pallas(cent, q, cmask, n_probe=n_probe,
                                   required=required,
                                   interpret=(impl == "interpret"))


def ivf_route_batch_padded(cent: jax.Array, qs: jax.Array, cmask: jax.Array,
                           n_probe: int, required: int = MASK_VALID,
                           impl: str | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Multi-query level-1 centroid route: (scores (B, n_probe),
    cids (B, n_probe))."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.ivf_route_batch_padded(cent, qs, cmask, n_probe, required)
    return ivf_route_batch_padded_pallas(cent, qs, cmask, n_probe=n_probe,
                                         required=required,
                                         interpret=(impl == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale,
                                  interpret=(impl == "interpret"))


def decode_attention(q, k, v, cache_len, *, window=0, scale=None,
                     impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.decode_attention(q, k, v, cache_len, window=window,
                                    scale=scale)
    return decode_attention_pallas(q, k, v, cache_len, window=window,
                                   scale=scale,
                                   interpret=(impl == "interpret"))
