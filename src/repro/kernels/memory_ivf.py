"""Pallas TPU kernel: centroid routing for the IVF two-level memory plane.

Level 1 of the sub-linear retrieval path (``core.memory_ivf``): score the
query against the P cluster centroids and pick the top-P' clusters to
probe. Level 2 then gathers only the probed clusters' member rows and
reuses the existing zero-copy top-k kernel (``kernels.memory_topk``) over
the gathered buffer — the store pass shrinks from O(C) to
O(P + P'·bucket) rows.

Centroid-plane layout — the same zero-copy contract as the store
----------------------------------------------------------------
The centroid plane mirrors the store's padded kernel layout exactly:

* ``cent`` is (Pp, Ep) f32 — one L2-normalized centroid per row, rows
  padded to a multiple of 8 (f32 sublane tile) and lanes to a multiple of
  128; padding/unseeded rows are zero.
* ``cmask`` is a (Pp, 1) int32 bit plane: bit 0 (:data:`MASK_VALID`) set
  iff the cluster has been seeded. Padding rows are 0, never routed to.

``core.memory_ivf.IVFMemory`` maintains this plane persistently
(incremental online-k-means updates scatter single centroid rows), so the
route never re-pads anything per query.

The routing selection is THE top-k total order — (score descending,
centroid row ascending), via the shared :func:`_select_topk` rounds — so
a route over per-shard centroid *subsets* merged under the same order is
bit-identical to the direct global route (``core.memory_ivf`` composes
cluster→shard placement this way, pinned in ``tests/test_memory_ivf.py``).
Sentinel semantics match the store kernels: unseeded/padding centroids
enter at -2.0, unfilled accumulator slots at (-3.0, 2**30) — so asking
for more probes than seeded clusters degrades exactly like an
under-populated store view.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.memory_topk import (DEFAULT_BLOCK_C, MASK_VALID,
                                       _pick_block, _round_up, _select_topk)


def _route_batch_kernel(q_ref, cent_ref, cmask_ref, score_ref, cid_ref, *,
                        block_p: int, n_probe: int, required: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.full(score_ref.shape, -3.0, jnp.float32)
        cid_ref[...] = jnp.full(cid_ref.shape, 2 ** 30, jnp.int32)

    block = cent_ref[...].astype(jnp.float32)         # (BP, Ep)
    qs = q_ref[...].astype(jnp.float32)               # (B, Ep)
    scores = jax.lax.dot_general(block, qs, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seeded = (cmask_ref[...] & required) == required  # (BP, 1)
    scores = jnp.where(seeded, scores, -2.0)
    cids = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) + i * block_p

    # merge the block into the (n_probe, B) running-best accumulator with
    # the shared (score desc, row asc) selection rounds
    cand_s = jnp.concatenate([score_ref[...], scores], axis=0)
    cand_c = jnp.concatenate([cid_ref[...], cids], axis=0)
    new_s, new_c = _select_topk(cand_s, cand_c, n_probe)
    score_ref[...] = new_s
    cid_ref[...] = new_c


@functools.partial(jax.jit, static_argnames=("n_probe", "required",
                                             "block_p", "interpret"))
def ivf_route_batch_padded_pallas(cent: jax.Array, qs: jax.Array,
                                  cmask: jax.Array, *, n_probe: int,
                                  required: int = MASK_VALID,
                                  block_p: int = DEFAULT_BLOCK_C,
                                  interpret: bool = False
                                  ) -> tuple[jax.Array, jax.Array]:
    """cent: (Pp, Ep) padded centroid plane; qs: (B, E); cmask: (Pp, 1)
    int32 bit plane → (scores (B, n_probe), cids (B, n_probe)) sorted by
    (score desc, centroid row asc). Zero-copy: only the query block is
    padded. One centroid-plane pass, (n_probe, B) VMEM accumulator — the
    exact structure of ``memory_topk_batch_padded_pallas`` with the store
    swapped for the centroid plane."""
    Pp, Ep = cent.shape
    B, E = qs.shape
    if n_probe < 1:
        raise ValueError(f"n_probe must be >= 1, got {n_probe}")
    bp = _pick_block(Pp, block_p)
    if n_probe > bp:
        raise ValueError(f"n_probe={n_probe} exceeds the kernel block of "
                         f"{bp} centroid rows; raise block_p")
    Bp = _round_up(B, 128)
    qp = jnp.zeros((Bp, Ep), jnp.float32).at[:B, :E].set(
        qs.astype(jnp.float32))

    grid = (Pp // bp,)
    scores, cids = pl.pallas_call(
        functools.partial(_route_batch_kernel, block_p=bp, n_probe=n_probe,
                          required=required),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bp, Ep), lambda i: (0, 0)),
            pl.BlockSpec((bp, Ep), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_probe, Bp), lambda i: (0, 0)),
            pl.BlockSpec((n_probe, Bp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_probe, Bp), jnp.float32),
            jax.ShapeDtypeStruct((n_probe, Bp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, cent, cmask)
    return scores[:, :B].T, cids[:, :B].T


def ivf_route_padded_pallas(cent: jax.Array, q: jax.Array, cmask: jax.Array,
                            *, n_probe: int, required: int = MASK_VALID,
                            block_p: int = DEFAULT_BLOCK_C,
                            interpret: bool = False
                            ) -> tuple[jax.Array, jax.Array]:
    """Single-query route: cent (Pp, Ep); q (E,); cmask (Pp, 1) →
    (scores (n_probe,), cids (n_probe,)). Shares the batch kernel body
    (and its jit cache), like the store top-k single wrapper."""
    scores, cids = ivf_route_batch_padded_pallas(
        cent, q[None, :], cmask, n_probe=n_probe, required=required,
        block_p=block_p, interpret=interpret)
    return scores[0], cids[0]
