"""Logical sharding rules with divisibility fallback.

MaxText-style: every parameter / activation gets an ordered list of
``(dim, mesh_axis)`` preferences; an assignment is taken greedily when the
dim size divides the mesh axis size and neither the dim nor the axis is
already used. Anything that doesn't divide cleanly is replicated on that
axis — this is what keeps odd configs (granite's 40 experts / 24 heads,
49155-token vocab) lowering on a 16×16 mesh without GSPMD padding surprises.

Two modes:
* ``serve`` — tensor-parallel on "model", batch on ("pod","data"),
  weights replicated over "data".
* ``train`` — FSDP: same "model" assignments, plus the other major dim of
  every weight sharded on "data" so AdamW state fits (33B-param configs
  need ~460 GB of optimizer+weights → 1.8 GB/chip at 256-way).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Prefs = list[tuple[int, str]]

STACKED_GROUPS = ("layers", "attn_layers", "rglru_layers", "enc_layers")


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def spec_from_prefs(shape: tuple[int, ...], prefs: Prefs, mesh: Mesh,
                    offset: int = 0) -> P:
    """Greedy assignment of mesh axes to dims with divisibility checks."""
    assigned: dict[int, Any] = {}
    used: set = set()          # individual mesh-axis names already taken
    for dim, axis in prefs:
        dim += offset
        parts = axis if isinstance(axis, tuple) else (axis,)
        if dim in assigned or any(a in used for a in parts) or \
                dim >= len(shape):
            continue
        if not all(a in mesh.shape for a in parts):
            continue
        if shape[dim] % _axis_size(mesh, axis) == 0 and shape[dim] > 0:
            assigned[dim] = axis
            used.update(parts)
    return P(*[assigned.get(i) for i in range(len(shape))])


def batch_axes(mesh: Mesh):
    """('pod','data') on the multi-pod mesh, 'data' on the single-pod one."""
    return ("pod", "data") if "pod" in mesh.shape else "data"


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _param_prefs(leaf_name: str, ndim: int, mode: str, mesh: Mesh) -> Prefs:
    """Preferences per parameter kind (dims are *after* stripping any
    stacked layer axis)."""
    fsdp = mode == "train"
    d = "data"
    if leaf_name in ("embed", "unembed"):            # (V, D)
        return [(0, "model")] + ([(1, d)] if fsdp else [])
    if leaf_name in ("wq", "wk", "wv"):              # (D, H, hd)
        return [(1, "model"), (0, "model")] + ([(0, d)] if fsdp else [])
    if leaf_name == "wo":                            # (H, hd, D)
        return [(0, "model"), (2, "model")] + ([(2, d)] if fsdp else [])
    if leaf_name in ("w_up", "w_gate") and ndim == 2:   # (D, F)
        return [(1, "model")] + ([(0, d)] if fsdp else [])
    if leaf_name == "w_down" and ndim == 2:          # (F, D)
        return [(0, "model")] + ([(1, d)] if fsdp else [])
    if leaf_name in ("w_up", "w_gate") and ndim == 3:   # MoE (E, D, F)
        return [(0, "model"), (2, "model")] + ([(2, d), (1, d)] if fsdp else [])
    if leaf_name == "w_down" and ndim == 3:          # MoE (E, F, D)
        return [(0, "model"), (1, "model")] + ([(1, d), (2, d)] if fsdp else [])
    if leaf_name == "router":                        # (D, E)
        return []
    if leaf_name == "in_proj":                       # (D, Din)
        return [(1, "model")] + ([(0, d)] if fsdp else [])
    if leaf_name == "out_proj":                      # (Din, D)
        return [(0, "model")] + ([(1, d)] if fsdp else [])
    if leaf_name in ("w_gate_branch", "w_rnn_branch"):  # (D, R)
        return [(1, "model")] + ([(0, d)] if fsdp else [])
    if leaf_name in ("w_a", "w_i"):                  # (R, R)
        return [(1, "model")] + ([(0, d)] if fsdp else [])
    if leaf_name == "w" and ndim == 2:               # conv (k, C)
        return [(1, "model")]
    # 1-D params (norm scales, biases, A_log, D, dt_bias, lambda) replicate.
    return []


def param_shardings(params_shape: Any, mesh: Mesh, mode: str) -> Any:
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs."""

    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        leaf_name = keys[-1]
        stacked = any(k in STACKED_GROUPS for k in keys[:-1])
        offset = 1 if stacked else 0
        ndim = len(leaf.shape) - offset
        prefs = _param_prefs(leaf_name, ndim, mode, mesh)
        spec = spec_from_prefs(leaf.shape, prefs, mesh, offset=offset)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    """tokens/labels (B, S), frames/patches (B, S, D): batch → data axes."""
    b = batch_axes(mesh)

    def one(leaf):
        return NamedSharding(mesh, spec_from_prefs(leaf.shape, [(0, b)], mesh))

    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(cache_shape: Any, mesh: Mesh) -> Any:
    """KV / state caches. Preference order: batch→data, heads→model, then
    (for batch=1 long-context) sequence→data: context-parallel decode."""
    b = batch_axes(mesh)

    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = keys[-1]
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, M, KV, hd)
            prefs = [(1, b), (3, "model"), (2, "model"), (2, "data")]
        elif name == "ssd":
            # (L, B, H, P, N)
            prefs = [(1, b), (2, "model")]
        elif name == "h":
            # (L, B, R)
            prefs = [(1, b), (2, "model")]
        elif name == "conv":
            # (L, B, k-1, C)
            prefs = [(1, b), (3, "model")]
        else:
            prefs = [(1, b)]
        return NamedSharding(mesh, spec_from_prefs(leaf.shape, prefs, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
