from repro.models.config import ModelConfig, assert_valid
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_shapes,
    prefill,
)

__all__ = [
    "ModelConfig", "assert_valid", "decode_step", "forward", "init_cache",
    "init_params", "loss_fn", "param_shapes", "prefill",
]
