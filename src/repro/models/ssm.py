"""State-space and linear-recurrent layers.

* Mamba-2 SSD (state-space duality) block [arXiv:2405.21060] — the chunked
  "dual form": intra-chunk quadratic (MXU-friendly masked matmul) +
  inter-chunk linear recurrence over chunk states.
* RG-LRU (Real-Gated Linear Recurrent Unit) from RecurrentGemma / Griffin
  [arXiv:2402.19427] — implemented with an associative scan for
  train/prefill and a single fused step for decode.

Both expose a (sequence-mode, step-mode) pair so the serving engine can run
prefill with the parallel form and decode with the O(1)-state recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init

# ---------------------------------------------------------------------------
# Depthwise causal conv1d (shared by Mamba2 and RG-LRU branches)
# ---------------------------------------------------------------------------


def conv1d_init(key: jax.Array, channels: int, kernel: int,
                dtype=jnp.bfloat16) -> Params:
    return {"w": dense_init(key, (kernel, channels), dtype=dtype),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv1d(params: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, C) -> (B, S, C), depthwise causal convolution."""
    k = params["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # k is tiny (4); unrolled adds, no gather
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * params["w"][i]
    return jax.nn.silu(out + params["b"]).astype(x.dtype)


def causal_conv1d_step(params: Params, x_t: jax.Array,
                       buf: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One decode step. x_t: (B, C); buf: (B, k-1, C) previous inputs."""
    k = params["w"].shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)  # (B, k, C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), params["w"])
    out = jax.nn.silu(out + params["b"]).astype(x_t.dtype)
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def mamba2_init(key: jax.Array, d_model: int, *, d_state: int, head_dim: int,
                expand: int = 2, n_groups: int = 1, d_conv: int = 4,
                dtype=jnp.bfloat16) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    keys = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return {
        "in_proj": dense_init(keys[0], (d_model, d_in_proj), dtype=dtype),
        "conv": conv1d_init(keys[1], d_inner + 2 * n_groups * d_state, d_conv,
                            dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(keys[2], (d_inner, d_model), dtype=dtype),
    }


def _ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, chunk: int,
                 h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """SSD dual form. x: (B,S,H,P); dt: (B,S,H); A: (H,) <0; B,C: (B,S,G,N).

    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    nc = S // chunk
    rep = H // G

    xd = (x * dt[..., None]).astype(jnp.float32)          # dt-weighted input
    a = A[None, None, :] * dt                              # (B,S,H) log-decay <0
    # reshape into chunks
    xc = xd.reshape(Bsz, nc, chunk, H, P)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = B.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    Cc = C.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    Bh = jnp.repeat(Bc, rep, axis=3)                       # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    cum = jnp.cumsum(ac, axis=2)                           # (B,nc,Q,H)
    # intra-chunk: L[q,s] = exp(cum[q]-cum[s]) for q>=s.
    # Mask BEFORE the exp: exp of a large positive (q<s) value would be inf,
    # and `where(mask, inf, 0)` is fine forward but NaNs the backward pass.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # rel[b,c,q,s,h]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    L = jnp.exp(rel)
    scores = jnp.einsum("bcqhn,bcshn->bcqsh", Ch, Bh,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bcqsh,bcqsh,bcshp->bcqhp", scores, L, xc,
                        preferred_element_type=jnp.float32)

    # chunk-final states: states[c] = sum_s exp(cum[last]-cum[s]) B_s x_s
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Bh, decay_to_end, xc,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over nc chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    hinit = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0
    h_last, h_prevs = jax.lax.scan(
        scan_fn, hinit,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,P,N) state entering chunk c

    # inter-chunk contribution: y_off[q] = C_q · (exp(cum[q]) * h_prev)
    in_decay = jnp.exp(cum)                                # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Ch, in_decay, h_prevs,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, h_last


def mamba2_seq(params: Params, x: jax.Array, *, d_state: int, head_dim: int,
               n_groups: int = 1, chunk: int = 256,
               state: Params | None = None) -> tuple[jax.Array, Params]:
    """Sequence mode (train / prefill). x: (B, S, D) -> (B, S, D), cache.

    Lengths that don't divide the chunk are zero-padded; padded positions
    get dt = 0, which makes them exact no-ops on the recurrent state
    (decay exp(0·A) = 1, input contribution dt·B·x = 0)."""
    Bsz, S, D = x.shape
    d_inner = params["out_proj"].shape[0]
    H = d_inner // head_dim
    GN = n_groups * d_state

    chunk = min(chunk, max(S, 1))
    Sp = ((S + chunk - 1) // chunk) * chunk
    if Sp != S:
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xin, Bmat, Cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + GN, 2 * d_inner + 2 * GN],
        axis=-1)
    xbc = jnp.concatenate([xin, Bmat, Cmat], axis=-1)
    xbc = causal_conv1d(params["conv"], xbc)
    xin, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + GN], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,Sp,H)
    if Sp != S:
        valid = (jnp.arange(Sp) < S)[None, :, None]
        dt = dt * valid                       # padded steps: state no-op
    A = -jnp.exp(params["A_log"])                                     # (H,)
    xh = xin.reshape(Bsz, Sp, H, head_dim)
    Bh = Bmat.reshape(Bsz, Sp, n_groups, d_state)
    Ch = Cmat.reshape(Bsz, Sp, n_groups, d_state)

    h0 = state["ssd"] if state is not None else None
    y, h_last = _ssd_chunked(xh, dt, A, Bh, Ch, chunk, h0)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, Sp, d_inner).astype(x.dtype)
    if Sp != S:
        y = y[:, :S]
        z = z[:, :S]
        zxbcdt = zxbcdt[:, :S]
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    # conv cache holds the last (k-1) PRE-activation conv inputs
    k = params["conv"]["w"].shape[0]
    zxbcdt_tail = zxbcdt[:, -(k - 1):, :]
    if zxbcdt_tail.shape[1] < k - 1:   # very short prompts: left-pad
        zxbcdt_tail = jnp.pad(
            zxbcdt_tail,
            ((0, 0), (k - 1 - zxbcdt_tail.shape[1], 0), (0, 0)))
    pre = jnp.concatenate([
        zxbcdt_tail[..., d_inner:2 * d_inner],
        zxbcdt_tail[..., 2 * d_inner:2 * d_inner + 2 * GN]], axis=-1)
    return out, {"ssd": h_last, "conv": pre.astype(x.dtype)}


def mamba2_step(params: Params, x_t: jax.Array, state: Params, *,
                d_state: int, head_dim: int, n_groups: int = 1
                ) -> tuple[jax.Array, Params]:
    """Decode step. x_t: (B, D); state: {'ssd': (B,H,P,N), 'conv': (B,k-1,C)}."""
    Bsz, D = x_t.shape
    d_inner = params["out_proj"].shape[0]
    H = d_inner // head_dim
    GN = n_groups * d_state

    zxbcdt = jnp.einsum("bd,de->be", x_t, params["in_proj"],
                        preferred_element_type=jnp.float32).astype(x_t.dtype)
    z, xin, Bmat, Cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + GN, 2 * d_inner + 2 * GN],
        axis=-1)
    xbc = jnp.concatenate([xin, Bmat, Cmat], axis=-1)
    xbc, conv_buf = causal_conv1d_step(params["conv"], xbc, state["conv"])
    xin, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + GN], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    xh = (xin.reshape(Bsz, H, head_dim) * dt[..., None]).astype(jnp.float32)
    Bh = jnp.repeat(Bmat.reshape(Bsz, n_groups, d_state), H // n_groups, axis=1)
    Ch = jnp.repeat(Cmat.reshape(Bsz, n_groups, d_state), H // n_groups, axis=1)

    decay = jnp.exp(A[None, :] * dt)                       # (B,H)
    h = state["ssd"] * decay[..., None, None] + \
        xh[..., :, None] * Bh.astype(jnp.float32)[:, :, None, :]  # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xin.reshape(Bsz, H, head_dim).astype(jnp.float32)
    y = y.reshape(Bsz, d_inner).astype(x_t.dtype)
    y = rmsnorm(params["norm"],
                (y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype))[:, None, :])[:, 0]
    out = jnp.einsum("be,ed->bd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x_t.dtype)
    return out, {"ssd": h, "conv": conv_buf}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_block_init(key: jax.Array, d_model: int, d_rnn: int, *,
                     d_conv: int = 4, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) roughly (griffin appendix)
    u = jax.random.uniform(keys[0], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1((-jnp.log(u)) / RGLRU_C))  # softplus^-1(-log u / c)
    return {
        "w_gate_branch": dense_init(keys[1], (d_model, d_rnn), dtype=dtype),
        "w_rnn_branch": dense_init(keys[2], (d_model, d_rnn), dtype=dtype),
        "conv": conv1d_init(keys[3], d_rnn, d_conv, dtype=dtype),
        "w_a": dense_init(keys[4], (d_rnn, d_rnn), dtype=dtype),
        "w_i": dense_init(keys[5], (d_rnn, d_rnn), dtype=dtype),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lambda": lam,
        "out_proj": dense_init(jax.random.fold_in(key, 7), (d_rnn, d_model),
                               dtype=dtype),
    }


def _rglru_gates(params: Params, x: jax.Array):
    r = jax.nn.sigmoid(jnp.einsum("...c,cd->...d", x, params["w_a"],
                                  preferred_element_type=jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...c,cd->...d", x, params["w_i"],
                                  preferred_element_type=jnp.float32)
                       + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lambda"]) * r   # <= 0
    a = jnp.exp(log_a)
    gated_in = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated_in
    return a, b


def rglru_seq(params: Params, x: jax.Array,
              state: Params | None = None) -> tuple[jax.Array, Params]:
    """Full recurrent block, sequence mode. x: (B,S,D) -> (B,S,D), cache."""
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,de->bse", x, params["w_gate_branch"],
        preferred_element_type=jnp.float32)).astype(x.dtype)
    u = jnp.einsum("bsd,de->bse", x, params["w_rnn_branch"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = params["conv"]["w"].shape[0]
    if state is not None:
        u_ext = jnp.concatenate([state["conv"], u], axis=1)
        uc = causal_conv1d(params["conv"], u_ext)[:, k - 1:, :]
    else:
        uc = causal_conv1d(params["conv"], u)
    a, b = _rglru_gates(params, uc)                         # (B,S,C) each

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if state is not None:
        # inject h0 by prepending an element (a=0 ⇒ resets, b=h0)
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([state["h"][:, None, :], b], axis=1)
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = h[:, 1:, :]
    else:
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)

    y = (h.astype(x.dtype) * gate)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    conv_buf = u[:, -(k - 1):, :] if u.shape[1] >= k - 1 else jnp.pad(
        u, ((0, 0), (k - 1 - u.shape[1], 0), (0, 0)))
    return out, {"h": h[:, -1, :].astype(jnp.float32), "conv": conv_buf}


def rglru_step(params: Params, x_t: jax.Array,
               state: Params) -> tuple[jax.Array, Params]:
    """Decode step. x_t: (B,D); state: {'h': (B,C) f32, 'conv': (B,k-1,C)}."""
    gate = jax.nn.gelu(jnp.einsum(
        "bd,de->be", x_t, params["w_gate_branch"],
        preferred_element_type=jnp.float32)).astype(x_t.dtype)
    u = jnp.einsum("bd,de->be", x_t, params["w_rnn_branch"],
                   preferred_element_type=jnp.float32).astype(x_t.dtype)
    uc, conv_buf = causal_conv1d_step(params["conv"], u, state["conv"])
    a, b = _rglru_gates(params, uc)
    h = a * state["h"] + b
    y = (h.astype(x_t.dtype) * gate)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x_t.dtype)
    return out, {"h": h, "conv": conv_buf}
