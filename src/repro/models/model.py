"""Unified model definition for all six architecture families.

Design notes
------------
* **Scan-over-layers with stacked parameters** for every homogeneous stack
  (dense / moe / ssm / vlm / audio-encoder / audio-decoder). HLO size — and
  therefore 512-device dry-run compile time — is independent of depth.
* The **hybrid** family (RecurrentGemma) has a static 2:1 recurrent:attention
  pattern; it is unrolled with the two block kinds kept in *separate* stacked
  groups, so no ``lax.cond`` appears in the HLO and the roofline reflects
  exactly the executed compute.
* Three entry points per model: ``forward`` (teacher forcing),
  ``prefill`` (sequence mode, builds a cache), ``decode_step`` (one token
  against the cache). Decode shapes in the dry-run lower ``decode_step``.
* Heterogeneous attention patterns (gemma3's 5:1 local:global) ride through
  the layer scan as a per-layer ``window`` array; masking is dynamic, which
  keeps the stack scannable. (The §Perf log shows the static-window variant
  that recovers the skipped-block compute.)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig, assert_valid

Params = dict[str, Any]

AUX_LOSS_WEIGHT = 0.01


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# Parameter initialization
# ===========================================================================


def _attn_layer_init(cfg: ModelConfig, key: jax.Array, *,
                     cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p = {
        "ln1": L.norm_init(cfg.norm_type, cfg.d_model),
        "attn": L.attention_block_init(ks[0], cfg.d_model, cfg.num_heads,
                                       cfg.num_kv_heads, cfg.head_dim,
                                       dtype=_dt(cfg)),
        "ln2": L.norm_init(cfg.norm_type, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = L.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts,
                              dtype=_dt(cfg))
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                              gated=cfg.gated_mlp, dtype=_dt(cfg))
    if cross:
        p["ln_cross"] = L.norm_init(cfg.norm_type, cfg.d_model)
        p["cross_attn"] = L.attention_block_init(
            ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            dtype=_dt(cfg))
    return p


def _ssm_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    return {
        "ln1": L.norm_init(cfg.norm_type, cfg.d_model),
        "mixer": S.mamba2_init(key, cfg.d_model, d_state=cfg.ssm_state,
                               head_dim=cfg.ssm_head_dim,
                               expand=cfg.ssm_expand, n_groups=cfg.ssm_groups,
                               d_conv=cfg.d_conv, dtype=_dt(cfg)),
    }


def _rglru_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.norm_init(cfg.norm_type, cfg.d_model),
        "rglru": S.rglru_block_init(ks[0], cfg.d_model, cfg.d_rnn,
                                    d_conv=cfg.d_conv, dtype=_dt(cfg)),
        "ln2": L.norm_init(cfg.norm_type, cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                          dtype=_dt(cfg)),
    }


def _hybrid_attn_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    p = _attn_layer_init(cfg, key)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    assert_valid(cfg)
    k_embed, k_layers, k_extra = jax.random.split(key, 3)
    params: Params = {
        "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model),
                              dtype=_dt(cfg)),
        "final_norm": L.norm_init(cfg.norm_type, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(
            jax.random.fold_in(k_embed, 1), (cfg.vocab_size, cfg.d_model),
            in_axis=1, dtype=_dt(cfg))

    if cfg.family in ("dense", "moe", "vlm"):
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _attn_layer_init(cfg, k))(keys)
    elif cfg.family == "ssm":
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(lambda k: _ssm_layer_init(cfg, k))(keys)
    elif cfg.family == "hybrid":
        blocks = cfg.layer_blocks()
        n_attn = blocks.count("a")
        n_rec = blocks.count("r")
        ka, kr = jax.random.split(k_layers)
        params["attn_layers"] = jax.vmap(
            lambda k: _hybrid_attn_layer_init(cfg, k))(
                jax.random.split(ka, n_attn))
        params["rglru_layers"] = jax.vmap(
            lambda k: _rglru_layer_init(cfg, k))(jax.random.split(kr, n_rec))
    elif cfg.family == "audio":
        ke, kd = jax.random.split(k_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _attn_layer_init(cfg, k))(
                jax.random.split(ke, cfg.encoder_layers))
        params["enc_norm"] = L.norm_init(cfg.norm_type, cfg.d_model)
        params["layers"] = jax.vmap(
            lambda k: _attn_layer_init(cfg, k, cross=True))(
                jax.random.split(kd, cfg.num_layers))
    else:
        raise ValueError(cfg.family)
    return params


def param_shapes(cfg: ModelConfig) -> Params:
    """Abstract init — ShapeDtypeStructs only, no device allocation."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ===========================================================================
# Layer meta (per-layer static pattern, carried through the scan)
# ===========================================================================


def _layer_meta(cfg: ModelConfig) -> dict[str, jax.Array]:
    return {"window": jnp.asarray(cfg.layer_windows(), jnp.int32)}


# ===========================================================================
# Block bodies
# ===========================================================================


def _attn_block_seq(cfg: ModelConfig, lp: Params, x: jax.Array,
                    positions: jax.Array, window, *, causal: bool,
                    kv_cache: Params | None, chunk_size: int = 1024):
    """Attention + FFN residual block, sequence mode."""
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x)
    q, k, v = L.attention_qkv(lp["attn"], h, positions, cfg.rope_theta)
    new_cache = None
    if kv_cache is not None:
        new_cache = {"k": k, "v": v}
    attn = L.attention(q, k, v, q_positions=positions, k_positions=positions,
                       causal=causal, window=window, chunk_size=chunk_size)
    x = x + L.attention_out(lp["attn"], attn)

    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg.norm_type, lp.get("ln2"), x)
    if "moe" in lp:
        f, aux = L.moe(lp["moe"], h, experts_per_token=cfg.experts_per_token,
                       capacity_factor=cfg.moe_capacity_factor,
                       dispatch=cfg.moe_dispatch)
    else:
        f = L.mlp(lp["mlp"], h)
    return x + f, new_cache, aux


def _attn_block_step(cfg: ModelConfig, lp: Params, x_t: jax.Array,
                     pos: jax.Array, window, kv_cache: Params):
    """One-token decode: write kv at ``pos``, attend over the cache.

    Ring mode (cfg.ring_cache, §Perf variant): the cache holds only
    ``decode_window`` slots; slot i currently stores absolute position
    ``pos - ((pos - i) mod W)`` — reconstructed below so masking and the
    sliding window work unchanged (negative = not yet written)."""
    B = x_t.shape[0]
    ring = cfg.ring_cache and cfg.decode_window > 0
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x_t[:, None, :])
    qpos = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = L.attention_qkv(lp["attn"], h, qpos, cfg.rope_theta)
    M = kv_cache["k"].shape[1]
    slot = jnp.mod(pos, M) if ring else pos
    ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, slot, 0, 0))
    slots = jnp.arange(M, dtype=jnp.int32)[None]
    if ring:
        kpos = pos - jnp.mod(pos - slots, M)     # absolute pos per slot
    else:
        kpos = slots
    kpos = jnp.broadcast_to(kpos, (B, M))
    w = window
    if cfg.decode_window > 0:
        w = jnp.where(jnp.asarray(window) > 0, window, cfg.decode_window)
    attn = L.attention(q, ck, cv, q_positions=qpos, k_positions=kpos,
                       causal=True, window=w)
    x_t = x_t + L.attention_out(lp["attn"], attn)[:, 0]

    h = L.apply_norm(cfg.norm_type, lp.get("ln2"), x_t[:, None, :])
    if "moe" in lp:
        f, _ = L.moe(lp["moe"], h, experts_per_token=cfg.experts_per_token,
                     capacity_factor=cfg.moe_capacity_factor,
                     dispatch=cfg.moe_dispatch)
    else:
        f = L.mlp(lp["mlp"], h)
    return x_t + f[:, 0], {"k": ck, "v": cv}


def _cross_block(cfg: ModelConfig, lp: Params, x: jax.Array,
                 enc_k: jax.Array, enc_v: jax.Array):
    """Cross-attention sub-block (audio decoder). enc_k/v precomputed."""
    B, Sq = x.shape[0], x.shape[1]
    h = L.apply_norm(cfg.norm_type, lp.get("ln_cross"), x)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    qpos = jnp.zeros((B, Sq), jnp.int32)
    kpos = jnp.zeros((B, enc_k.shape[1]), jnp.int32)
    attn = L.attention(q, enc_k, enc_v, q_positions=qpos, k_positions=kpos,
                       causal=False, window=0)
    return x + L.attention_out(lp["cross_attn"], attn)


def _ssm_block_seq(cfg: ModelConfig, lp: Params, x: jax.Array,
                   state: Params | None):
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x)
    y, new_state = S.mamba2_seq(lp["mixer"], h, d_state=cfg.ssm_state,
                                head_dim=cfg.ssm_head_dim,
                                n_groups=cfg.ssm_groups, chunk=cfg.ssm_chunk,
                                state=state)
    return x + y, new_state


def _ssm_block_step(cfg: ModelConfig, lp: Params, x_t: jax.Array,
                    state: Params):
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x_t[:, None, :])[:, 0]
    y, new_state = S.mamba2_step(lp["mixer"], h, state, d_state=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim,
                                 n_groups=cfg.ssm_groups)
    return x_t + y, new_state


def _rglru_block_seq(cfg: ModelConfig, lp: Params, x: jax.Array,
                     state: Params | None):
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x)
    y, new_state = S.rglru_seq(lp["rglru"], h, state)
    x = x + y
    h = L.apply_norm(cfg.norm_type, lp.get("ln2"), x)
    return x + L.mlp(lp["mlp"], h), new_state


def _rglru_block_step(cfg: ModelConfig, lp: Params, x_t: jax.Array,
                      state: Params):
    h = L.apply_norm(cfg.norm_type, lp.get("ln1"), x_t[:, None, :])[:, 0]
    y, new_state = S.rglru_step(lp["rglru"], h, state)
    x_t = x_t + y
    h = L.apply_norm(cfg.norm_type, lp.get("ln2"), x_t[:, None, :])
    return x_t + L.mlp(lp["mlp"], h)[:, 0], new_state


# ===========================================================================
# Embedding & head
# ===========================================================================


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array):
    x = params["embed"][tokens].astype(_dt(cfg))
    return x * jnp.asarray(cfg.d_model ** 0.5, _dt(cfg))


def output_logits(cfg: ModelConfig, params: Params, x: jax.Array):
    x = L.apply_norm(cfg.norm_type, params.get("final_norm"), x)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(w, x)


# ===========================================================================
# Full-sequence forward (train / prefill)
# ===========================================================================


def _build_inputs(cfg: ModelConfig, params: Params, batch: dict):
    """Token (+frontend stub) embedding; returns (x, positions, text_start)."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        # Precomputed patch embeddings from the (stubbed) vision tower are
        # prepended to the text tokens; attention is causal over the result.
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    B, Stot = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Stot, dtype=jnp.int32)[None],
                                 (B, Stot))
    text_start = Stot - tokens.shape[1]
    return x, positions, text_start


def _run_encoder(cfg: ModelConfig, params: Params, frames: jax.Array):
    """Audio encoder over precomputed (stub) frame embeddings."""
    B, F = frames.shape[0], frames.shape[1]
    x = frames.astype(_dt(cfg))
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(carry, lp):
        h, _, _ = _attn_block_seq(cfg, lp, carry, positions, 0,
                                  causal=False, kv_cache=None)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return L.apply_norm(cfg.norm_type, params.get("enc_norm"), x)


def _encoder_cross_kv(cfg: ModelConfig, params: Params, enc_out: jax.Array):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"],
                       preferred_element_type=jnp.float32).astype(enc_out.dtype)
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"],
                       preferred_element_type=jnp.float32).astype(enc_out.dtype)
        return k, v
    return jax.vmap(one)(params["layers"])  # (L,B,F,KV,hd) each


def forward(cfg: ModelConfig, params: Params, batch: dict
            ) -> tuple[jax.Array, jax.Array]:
    """Teacher-forcing forward. Returns (logits over text positions, aux)."""
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, batch["frames"])
        cross_k, cross_v = _encoder_cross_kv(cfg, params, enc_out)
        x, positions, _ = _build_inputs(cfg, params, batch)

        def body(carry, xs):
            lp, ck, cv = xs
            h, _, aux = _attn_block_seq(cfg, lp, carry, positions, 0,
                                        causal=True, kv_cache=None)
            h = _cross_block(cfg, lp, h, ck, cv)
            return h, aux

        fn = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(fn, x, (params["layers"], cross_k, cross_v))
        return output_logits(cfg, params, x), jnp.sum(auxs)

    x, positions, text_start = _build_inputs(cfg, params, batch)

    if cfg.family in ("dense", "moe", "vlm"):
        meta = _layer_meta(cfg)

        def body(carry, xs):
            lp, m = xs
            h, _, aux = _attn_block_seq(cfg, lp, carry, positions,
                                        m["window"], causal=True,
                                        kv_cache=None)
            return h, aux

        fn = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(fn, x, (params["layers"], meta))
        logits = output_logits(cfg, params, x)
        if cfg.family == "vlm":
            logits = logits[:, text_start:]
        return logits, jnp.sum(auxs)

    if cfg.family == "ssm":
        def body(carry, lp):
            h, _ = _ssm_block_seq(cfg, lp, carry, None)
            return h, None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
        return output_logits(cfg, params, x), jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        blocks = cfg.layer_blocks()
        ia = ir = 0
        for b in blocks:
            if b == "a":
                lp = jax.tree.map(lambda p, i=ia: p[i], params["attn_layers"])
                win = cfg.layer_windows()[ia + ir]
                body = lambda h: _attn_block_seq(  # noqa: E731
                    cfg, lp, h, positions, win, causal=True, kv_cache=None)[0]
                x = jax.checkpoint(body)(x) if cfg.remat else body(x)
                ia += 1
            else:
                lp = jax.tree.map(lambda p, i=ir: p[i], params["rglru_layers"])
                body = lambda h: _rglru_block_seq(cfg, lp, h, None)[0]  # noqa: E731
                x = jax.checkpoint(body)(x) if cfg.remat else body(x)
                ir += 1
        return output_logits(cfg, params, x), jnp.zeros((), jnp.float32)

    raise ValueError(cfg.family)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict
            ) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    total = ce + AUX_LOSS_WEIGHT * aux
    return total, {"ce": ce, "aux": aux,
                   "accuracy": jnp.sum(
                       (jnp.argmax(logits, -1) == labels) * mask) / denom}


# ===========================================================================
# KV / state caches
# ===========================================================================


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Concrete zero cache. Use inside jax.eval_shape for dry-run specs.

    Ring mode (§Perf variant): attention caches hold only decode_window
    slots regardless of logical context length."""
    B, M = batch_size, max_len
    if cfg.ring_cache and cfg.decode_window > 0:
        M = min(M, cfg.decode_window)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def kv(n):
        return {"k": jnp.zeros((n, B, M, KV, hd), dtype),
                "v": jnp.zeros((n, B, M, KV, hd), dtype)}

    if cfg.family in ("dense", "moe", "vlm"):
        return kv(cfg.num_layers)
    if cfg.family == "ssm":
        C = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {
            "ssd": jnp.zeros((cfg.num_layers, B, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((cfg.num_layers, B, cfg.d_conv - 1, C), dtype),
        }
    if cfg.family == "hybrid":
        blocks = cfg.layer_blocks()
        n_attn, n_rec = blocks.count("a"), blocks.count("r")
        c = kv(n_attn)
        c["h"] = jnp.zeros((n_rec, B, cfg.d_rnn), jnp.float32)
        c["conv"] = jnp.zeros((n_rec, B, cfg.d_conv - 1, cfg.d_rnn), dtype)
        return c
    if cfg.family == "audio":
        c = kv(cfg.num_layers)
        c["cross_k"] = jnp.zeros((cfg.num_layers, B, cfg.encoder_frames,
                                  KV, hd), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c
    raise ValueError(cfg.family)


# ===========================================================================
# Prefill
# ===========================================================================


def prefill(cfg: ModelConfig, params: Params, batch: dict, max_len: int
            ) -> tuple[jax.Array, Params, jax.Array]:
    """Run the prompt through the model, building a cache.

    Returns (last-position logits (B, V), cache, next position scalar).
    """
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, batch["frames"])
        cross_k, cross_v = _encoder_cross_kv(cfg, params, enc_out)
        x, positions, _ = _build_inputs(cfg, params, batch)
        B, Stot = x.shape[0], x.shape[1]

        def body(carry, xs):
            lp, ck, cv = xs
            h, new_kv, _ = _attn_block_seq(cfg, lp, carry, positions, 0,
                                           causal=True, kv_cache={})
            h = _cross_block(cfg, lp, h, ck, cv)
            return h, new_kv

        x, kv = jax.lax.scan(body, x, (params["layers"], cross_k, cross_v))
        cache = _pad_kv(kv, max_len)
        cache["cross_k"], cache["cross_v"] = cross_k, cross_v
        logits = output_logits(cfg, params, x[:, -1:, :])[:, 0]
        return logits, cache, jnp.asarray(Stot, jnp.int32)

    x, positions, _ = _build_inputs(cfg, params, batch)
    B, Stot = x.shape[0], x.shape[1]

    if cfg.family in ("dense", "moe", "vlm"):
        meta = _layer_meta(cfg)

        def body(carry, xs):
            lp, m = xs
            h, new_kv, _ = _attn_block_seq(cfg, lp, carry, positions,
                                           m["window"], causal=True,
                                           kv_cache={})
            return h, new_kv

        x, kv = jax.lax.scan(body, x, (params["layers"], meta))
        cache = _pad_kv(kv, max_len)
        logits = output_logits(cfg, params, x[:, -1:, :])[:, 0]
        return logits, cache, jnp.asarray(Stot, jnp.int32)

    if cfg.family == "ssm":
        def body(carry, lp):
            h, st = _ssm_block_seq(cfg, lp, carry, None)
            return h, st

        x, states = jax.lax.scan(body, x, params["layers"])
        logits = output_logits(cfg, params, x[:, -1:, :])[:, 0]
        return logits, states, jnp.asarray(Stot, jnp.int32)

    if cfg.family == "hybrid":
        blocks = cfg.layer_blocks()
        ks, vs, hs, convs = [], [], [], []
        ia = ir = 0
        for b in blocks:
            if b == "a":
                lp = jax.tree.map(lambda p, i=ia: p[i], params["attn_layers"])
                win = cfg.layer_windows()[ia + ir]
                x, new_kv, _ = _attn_block_seq(cfg, lp, x, positions, win,
                                               causal=True, kv_cache={})
                ks.append(new_kv["k"])
                vs.append(new_kv["v"])
                ia += 1
            else:
                lp = jax.tree.map(lambda p, i=ir: p[i], params["rglru_layers"])
                x, st = _rglru_block_seq(cfg, lp, x, None)
                hs.append(st["h"])
                convs.append(st["conv"])
                ir += 1
        kv = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        cache = _pad_kv(kv, max_len)
        cache["h"] = jnp.stack(hs)
        cache["conv"] = jnp.stack(convs)
        logits = output_logits(cfg, params, x[:, -1:, :])[:, 0]
        return logits, cache, jnp.asarray(Stot, jnp.int32)

    raise ValueError(cfg.family)


def _pad_kv(kv: Params, max_len: int) -> Params:
    S = kv["k"].shape[2]
    pad = max_len - S
    assert pad >= 0, (S, max_len)
    return {
        "k": jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }


# ===========================================================================
# Decode step
# ===========================================================================


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Params, pos: jax.Array
                ) -> tuple[jax.Array, Params]:
    """One decode step.

    tokens: (B,) int32 — the token at position ``pos`` (cache holds
    positions [0, pos)). Returns (logits (B, V), updated cache).
    """
    x = embed_tokens(cfg, params, tokens[:, None])[:, 0]

    if cfg.family in ("dense", "moe", "vlm"):
        meta = _layer_meta(cfg)

        def body(carry, xs):
            lp, m, kv = xs
            h = _attn_block_step(cfg, lp, carry, pos, m["window"], kv)
            return h[0], h[1]

        x, kv = jax.lax.scan(body, x, (params["layers"], meta, cache))
        return output_logits(cfg, params, x[:, None, :])[:, 0], kv

    if cfg.family == "ssm":
        def body(carry, xs):
            lp, st = xs
            h, new_st = _ssm_block_step(cfg, lp, carry, st)
            return h, new_st

        x, states = jax.lax.scan(body, x, (params["layers"], cache))
        return output_logits(cfg, params, x[:, None, :])[:, 0], states

    if cfg.family == "hybrid":
        blocks = cfg.layer_blocks()
        ks, vs, hs, convs = [], [], [], []
        ia = ir = 0
        for b in blocks:
            if b == "a":
                lp = jax.tree.map(lambda p, i=ia: p[i], params["attn_layers"])
                kv = {"k": cache["k"][ia], "v": cache["v"][ia]}
                win = cfg.layer_windows()[ia + ir]
                x, new_kv = _attn_block_step(cfg, lp, x, pos, win, kv)
                ks.append(new_kv["k"])
                vs.append(new_kv["v"])
                ia += 1
            else:
                lp = jax.tree.map(lambda p, i=ir: p[i], params["rglru_layers"])
                st = {"h": cache["h"][ir], "conv": cache["conv"][ir]}
                x, new_st = _rglru_block_step(cfg, lp, x, st)
                hs.append(new_st["h"])
                convs.append(new_st["conv"])
                ir += 1
        new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                     "h": jnp.stack(hs), "conv": jnp.stack(convs)}
        return output_logits(cfg, params, x[:, None, :])[:, 0], new_cache

    if cfg.family == "audio":
        def body(carry, xs):
            lp, kv, ck, cv = xs
            h = _attn_block_step(cfg, lp, carry, pos, 0,
                                 {"k": kv["k"], "v": kv["v"]})
            x2 = _cross_block(cfg, lp, h[0][:, None, :], ck, cv)[:, 0]
            return x2, h[1]

        x, kv = jax.lax.scan(
            body, x, (params["layers"],
                      {"k": cache["k"], "v": cache["v"]},
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(kv)
        new_cache["cross_k"], new_cache["cross_v"] = (cache["cross_k"],
                                                      cache["cross_v"])
        return output_logits(cfg, params, x[:, None, :])[:, 0], new_cache

    raise ValueError(cfg.family)
